"""Property tests (hypothesis) for the paged-cache block allocator.

Invariants: alloc/free round-trips conserve the pool exactly (no leaks),
live reservations never alias (no block handed out twice), alloc is
all-or-nothing (a refused alloc has zero side effects), and double-frees
/ foreign frees always raise. Driven by a random interleaving of
alloc/free operations — the shape of traffic the paged engine's
admission and deferred-release actually produce.

Skipped (by conftest) when hypothesis isn't installed — it lives in the
``dev`` extra, so the CI no-hypothesis job stays green by skip.
"""
from __future__ import annotations

import pytest

# conftest's source-grep skip covers discovery runs; this covers the file
# being named explicitly on the pytest command line (e.g. the CI lane)
pytest.importorskip("hypothesis")

from hypothesis import given, settings      # noqa: E402
from hypothesis import strategies as st     # noqa: E402

from repro.serving.cache import BlockAllocator      # noqa: E402


@given(st.integers(1, 64), st.lists(st.integers(0, 70), max_size=40),
       st.randoms())
@settings(max_examples=200, deadline=None)
def test_alloc_free_roundtrip_conserves_pool(n_blocks, sizes, rnd):
    """Random alloc/free interleaving: free + live == pool at every step,
    live reservations stay pairwise disjoint, and draining every
    reservation restores the full pool."""
    a = BlockAllocator(n_blocks)
    live: list[list[int]] = []
    for n in sizes:
        if live and rnd.random() < 0.4:
            a.free(live.pop(rnd.randrange(len(live))))
        free_now = n_blocks - sum(map(len, live))
        got = a.alloc(n)
        if n > free_now:
            assert got is None              # over budget: refused...
        if got is None:
            assert a.n_free == free_now     # ...with zero side effects
            continue
        assert len(got) == n
        live.append(got)
        flat = [b for r in live for b in r]
        assert len(flat) == len(set(flat)), "aliased live blocks"
        assert all(0 <= b < n_blocks for b in flat)
        assert a.n_free == n_blocks - len(flat)
    for r in live:
        a.free(r)
    assert a.n_free == n_blocks


@given(st.integers(1, 32), st.integers(1, 32))
@settings(max_examples=100, deadline=None)
def test_all_or_nothing(n_blocks, n):
    a = BlockAllocator(n_blocks)
    got = a.alloc(n)
    if n <= n_blocks:
        assert got is not None and a.n_free == n_blocks - n
    else:
        assert got is None and a.n_free == n_blocks


@given(st.integers(1, 32), st.integers(1, 8))
@settings(max_examples=100, deadline=None)
def test_double_and_foreign_free_raise(n_blocks, n):
    a = BlockAllocator(n_blocks)
    got = a.alloc(min(n, n_blocks))
    assert got is not None
    a.free(got)
    with pytest.raises(ValueError):
        a.free(got)
    with pytest.raises(ValueError):
        a.free([n_blocks + 7])
    assert a.n_free == n_blocks
