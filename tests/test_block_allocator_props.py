"""Property tests (hypothesis) for the paged-cache block allocator.

Invariants: alloc/free round-trips conserve the pool exactly (no leaks),
live reservations never alias (no block handed out twice), alloc is
all-or-nothing (a refused alloc has zero side effects), and double-frees
/ foreign frees always raise. Driven by a random interleaving of
alloc/free operations — the shape of traffic the paged engine's
admission and deferred-release actually produce.

Skipped (by conftest) when hypothesis isn't installed — it lives in the
``dev`` extra, so the CI no-hypothesis job stays green by skip.
"""
from __future__ import annotations

import pytest

# conftest's source-grep skip covers discovery runs; this covers the file
# being named explicitly on the pytest command line (e.g. the CI lane)
pytest.importorskip("hypothesis")

from hypothesis import given, settings      # noqa: E402
from hypothesis import strategies as st     # noqa: E402

from repro.models.cache import PagedLayout          # noqa: E402
from repro.serving.cache import BlockAllocator, PagedCache  # noqa: E402


@given(st.integers(1, 64), st.lists(st.integers(0, 70), max_size=40),
       st.randoms())
@settings(max_examples=200, deadline=None)
def test_alloc_free_roundtrip_conserves_pool(n_blocks, sizes, rnd):
    """Random alloc/free interleaving: free + live == pool at every step,
    live reservations stay pairwise disjoint, and draining every
    reservation restores the full pool."""
    a = BlockAllocator(n_blocks)
    live: list[list[int]] = []
    for n in sizes:
        if live and rnd.random() < 0.4:
            a.free(live.pop(rnd.randrange(len(live))))
        free_now = n_blocks - sum(map(len, live))
        got = a.alloc(n)
        if n > free_now:
            assert got is None              # over budget: refused...
        if got is None:
            assert a.n_free == free_now     # ...with zero side effects
            continue
        assert len(got) == n
        live.append(got)
        flat = [b for r in live for b in r]
        assert len(flat) == len(set(flat)), "aliased live blocks"
        assert all(0 <= b < n_blocks for b in flat)
        assert a.n_free == n_blocks - len(flat)
    for r in live:
        a.free(r)
    assert a.n_free == n_blocks


@given(st.integers(1, 32), st.integers(1, 32))
@settings(max_examples=100, deadline=None)
def test_all_or_nothing(n_blocks, n):
    a = BlockAllocator(n_blocks)
    got = a.alloc(n)
    if n <= n_blocks:
        assert got is not None and a.n_free == n_blocks - n
    else:
        assert got is None and a.n_free == n_blocks


# -- PagedCache release-path conservation -----------------------------------
#
# The engine has three ways to release a row's reservation: completion
# (slot finishes), deadline expiry (engine-side _expire_deadlines) and
# cancellation (Router retry/backstop/cancel via engine.cancel). All
# three funnel into PagedCache.free + a later flush, and any two can
# race on the same row (e.g. the Router's backstop cancels a request the
# engine completed in the same macro-step). The invariant chaos tests
# rely on: ANY interleaving of these releases — duplicates included —
# leaves free + live == pool at every step, zero leaked and zero
# double-freed blocks once flushed. A tree with no paged group keeps the
# whole walk on the host accounting (no jax device ops), which is
# exactly the layer these invariants live in.

@given(st.integers(1, 12),                       # rows
       st.integers(1, 8),                        # block_size
       st.integers(1, 64),                       # max_blocks
       st.lists(st.tuples(st.sampled_from(["admit", "grow", "complete",
                                           "expire", "cancel", "flush"]),
                          st.integers(0, 11),    # row
                          st.integers(1, 24)),   # token count
                max_size=60),
       st.randoms())
@settings(max_examples=200, deadline=None)
def test_release_interleavings_conserve_blocks(n_rows, block_size,
                                               max_blocks, ops, rnd):
    layout = PagedLayout(block_size=block_size, max_blocks=max_blocks)
    max_len = block_size * max_blocks
    cache = PagedCache(tree={}, n_rows=n_rows, layout=layout,
                       max_len=max_len, batch_axes=None, jits={})
    held: set[int] = set()                       # rows with a reservation

    def check():
        assert (cache.allocator.n_free + cache.n_live_blocks
                == max_blocks), "leaked or double-freed blocks"
        flat = [b for r in cache._blocks for b in r]
        assert len(flat) == len(set(flat)), "aliased live blocks"

    for op, row, toks in ops:
        row %= n_rows
        if op == "admit":
            if row in held:
                continue                          # engine never re-admits
            if cache.alloc(row, min(toks, max_len)):
                held.add(row)
        elif op == "grow":
            if row in held and row not in cache._pending:
                cache.append(row, 1)
        elif op == "flush":
            cache.flush()
            held -= {r for r in range(n_rows) if not cache._blocks[r]}
        else:                                    # complete/expire/cancel
            # all three release paths call free(); racing releases of
            # the same row (complete + cancel, expire + cancel...) must
            # be idempotent — model that by freeing 1 or 2 times
            for _ in range(rnd.randint(1, 2)):
                cache.free(row)
        check()
    cache.flush()
    check()
    for row in range(n_rows):
        cache.free(row)
    cache.flush()
    assert cache.allocator.n_free == max_blocks
    assert cache.n_live_blocks == 0


@given(st.integers(1, 32), st.integers(1, 8))
@settings(max_examples=100, deadline=None)
def test_double_and_foreign_free_raise(n_blocks, n):
    a = BlockAllocator(n_blocks)
    got = a.alloc(min(n, n_blocks))
    assert got is not None
    a.free(got)
    with pytest.raises(ValueError):
        a.free(got)
    with pytest.raises(ValueError):
        a.free([n_blocks + 7])
    assert a.n_free == n_blocks


# -- prefix sharing: share/release/cow-fork/evict interleavings --------------
#
# With prefix_cache on, a block can be referenced by several rows AND the
# cache's own index at once; releases come from row frees (flush), CoW
# forks (append into a shared block) and LRU eviction (admission
# pressure). The refcount-aware conservation the engine relies on:
# free + DISTINCT live == pool at every step, the allocator's refcount of
# every block equals exactly (#rows holding it, pending included) +
# (1 if indexed), the LRU holds only index-only residents, and a
# successful append leaves every written block privately owned (ref 1) —
# no aliasing between live rows through a written block.

def _hashes(seq):
    """Stand-in block-hash chain: prefix tuples, so equal leading content
    collides exactly like the engine's chained blake2b does."""
    return [tuple(seq[:i + 1]) for i in range(len(seq))]


@given(st.integers(1, 6),                        # rows
       st.integers(1, 4),                        # block_size
       st.integers(2, 16),                       # max_blocks
       st.lists(st.tuples(st.sampled_from(["admit", "register", "grow",
                                           "release", "flush"]),
                          st.integers(0, 5),     # row
                          st.lists(st.integers(0, 2), min_size=1,
                                   max_size=5),  # block-content ids
                          st.integers(0, 3)),    # tail tokens / grow len
                max_size=50),
       st.randoms())
@settings(max_examples=150, deadline=None)
def test_share_cow_evict_interleavings_conserve_refcounts(
        n_rows, block_size, max_blocks, ops, rnd):
    layout = PagedLayout(block_size=block_size, max_blocks=max_blocks)
    max_len = block_size * max_blocks
    cache = PagedCache(tree={}, n_rows=n_rows, layout=layout,
                       max_len=max_len, batch_axes=None, jits={},
                       prefix_cache=True)
    chains: dict[int, list] = {}                 # row -> its hash chain

    def check():
        assert (cache.allocator.n_free + cache.n_live_blocks
                == max_blocks), "leaked or double-freed blocks"
        assert cache.allocator.n_live == cache.n_live_blocks
        refs: dict[int, int] = {}
        for blocks in cache._blocks:
            for b in blocks:
                refs[b] = refs.get(b, 0) + 1
        for b in cache._block_hash:
            refs[b] = refs.get(b, 0) + 1
        for b, want in refs.items():
            assert cache.allocator.ref(b) == want, "refcount drift"
        assert cache.allocator._ref.keys() == refs.keys()
        for b in cache._lru:                     # LRU ⊆ index-only blocks
            assert b in cache._block_hash
            assert cache.allocator.ref(b) == 1
        # the two index directions stay exact inverses
        assert ({h: b for b, h in cache._block_hash.items()}
                == cache._hash_to_block)

    for op, row, content, extra in ops:
        row %= n_rows
        if op == "admit" and not cache._blocks[row] \
                and row not in cache._pending:
            n_tokens = min(len(content) * block_size + extra, max_len)
            if n_tokens and cache.alloc(row, n_tokens,
                                        block_hashes=_hashes(content)):
                chains[row] = _hashes(content)
        elif op == "register" and cache._blocks[row] \
                and row not in cache._pending and row in chains:
            cache.register_prefix(row, chains[row])
        elif op == "grow" and cache._blocks[row] \
                and row not in cache._pending:
            if cache.append(row, extra + 1):
                # every block the write landed in must now be PRIVATE:
                # refcount 1 and unindexed (CoW forked it away from any
                # other row / the prefix index before the write)
                bs = block_size
                old = cache._tokens[row] - (extra + 1)
                for idx in range(old // bs,
                                 min((cache._tokens[row] - 1) // bs + 1,
                                     len(cache._blocks[row]))):
                    b = cache._blocks[row][idx]
                    assert cache.allocator.ref(b) == 1, \
                        "append left a written block shared"
                    assert b not in cache._block_hash
        elif op == "release":
            for _ in range(rnd.randint(1, 2)):   # racing releases
                cache.free(row)
        elif op == "flush":
            cache.flush()
        check()
    cache.flush()
    for row in range(n_rows):
        cache.free(row)
    cache.flush()
    check()
    # drain the index too: once every row is gone, every indexed block
    # is an LRU resident, and evicting them all restores the full pool
    for b in list(cache._lru):
        cache._evict(b)
    assert cache.n_cached_blocks == 0
    assert cache.allocator.n_free == max_blocks
    assert cache.n_live_blocks == 0
