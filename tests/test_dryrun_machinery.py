"""End-to-end dry-run machinery test on a small fake mesh (subprocess —
the device-count override must precede jax init, so it cannot run in this
process)."""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.configs.base import InputShape
    from repro.configs.registry import get_config
    from repro.core.hlo_analysis import analyze_hlo
    from repro.core.roofline import build_report
    from repro.launch.sharding import ShardingRules
    from repro.launch.specs import lowering_args
    from repro.models.model import Model
    from repro.train.loop import TrainConfig

    from repro.compat import make_mesh, set_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = get_config("qwen3-0.6b-reduced")
    model = Model(cfg)
    results = {}
    for shape in (InputShape("t", 64, 8, "train"),
                  InputShape("p", 64, 8, "prefill"),
                  InputShape("d", 64, 8, "decode")):
        step, args = lowering_args(model, shape, TrainConfig(remat=True))
        rules = ShardingRules(mesh, train=(shape.kind == "train"),
                              decode=(shape.kind == "decode"))
        if shape.kind == "train":
            insh = (rules.params(args[0]), rules.opt_state(args[1]),
                    rules.batch(args[2]))
        elif shape.kind == "prefill":
            insh = (rules.params(args[0]), rules.batch(args[1]))
        else:
            insh = (rules.params(args[0]), rules.cache(args[1], 8),
                    rules.batch(args[2]))
        with set_mesh(mesh):
            compiled = jax.jit(step, in_shardings=insh).lower(*args).compile()
            txt = compiled.as_text()
        cost = analyze_hlo(txt)
        rep = build_report(cfg.name, shape, cfg, "test", 8, cost)
        results[shape.kind] = {
            "flops": cost.flops_per_chip,
            "bytes": cost.bytes_per_chip,
            "step": rep.step_time,
            "dominant": rep.dominant,
        }
    print(json.dumps(results))
""")


def test_lower_compile_roofline_on_fake_mesh():
    # the subprocess doesn't see pytest's pythonpath ini — pass src along
    env = dict(os.environ)
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=480)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert set(out) == {"train", "prefill", "decode"}
    for kind, row in out.items():
        assert row["flops"] > 0, (kind, row)
        assert row["bytes"] > 0, (kind, row)
        assert row["step"] > 0, (kind, row)
        assert row["dominant"] in ("compute", "memory", "collective")
    # a train step does ~3× the FLOPs of the forward-only prefill
    assert out["train"]["flops"] > 1.5 * out["prefill"]["flops"]
    # decoding ONE token is far cheaper than prefilling 64
    assert out["decode"]["flops"] < 0.2 * out["prefill"]["flops"]
