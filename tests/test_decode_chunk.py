"""Fused multi-token decode: parity, in-graph stop conditions, donation.

The chunked decode path (``Model.decode_chunk`` + the macro-step engine)
must be semantically invisible: identical greedy token streams to the
per-token path for every model family, correct mid-chunk finishes, and a
KV cache that is donated (updated in place) rather than copied per token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.roofline import decode_chunk_tokens
from repro.serving.engine import Request, ServingEngine

# one representative per model family (see models/model.py's family table)
FAMILY_ARCHS = [
    "qwen3-0.6b",        # dense
    "gemma3-27b",        # gemma (local/global sliding-window pattern)
    "mixtral-8x22b",     # moe (GQA)
    "mamba2-2.7b",       # ssm
    "zamba2-7b",         # zamba (ssm + shared attention)
    "whisper-large-v3",  # whisper (encoder-decoder, cross-attention)
]


def _requests(cfg, plens_max_new, seed=0):
    """Ragged prompts and ragged budgets; whisper/vlm extras attached."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i, (plen, max_new) in enumerate(plens_max_new):
        extras = {}
        if cfg.n_encoder_layers:
            extras["audio_frames"] = 0.1 * rng.standard_normal(
                (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        if cfg.n_vision_tokens:
            extras["vision_embeds"] = 0.1 * rng.standard_normal(
                (cfg.n_vision_tokens, cfg.vision_embed_dim)).astype(
                    np.float32)
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, (plen,),
                                       dtype=np.int32),
            max_new_tokens=max_new, extras=extras))
    return reqs


def _serve(model, params, reqs, **kw):
    eng = ServingEngine(model, params, n_slots=2, max_len=64, **kw)
    eng.submit_many([Request(r.rid, r.prompt, r.max_new_tokens, r.extras)
                     for r in reqs])
    return {c.rid: c.tokens for c in eng.run()}, eng


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_chunked_matches_per_token_greedy(arch, reduced_models):
    """Identical greedy streams, ragged prompt lengths AND ragged
    ``remaining`` across slots (the chunk clamps to the shortest)."""
    model, params = reduced_models[arch]
    reqs = _requests(model.cfg, [(6, 5), (9, 3), (7, 6), (6, 4)])
    want, _ = _serve(model, params, reqs, chunked=False)
    got, eng = _serve(model, params, reqs, chunked=True, chunk_tokens=3)
    assert got == want
    assert eng.chunks > 0 and eng.chunks < sum(
        m for _, m in [(6, 5), (9, 3), (7, 6), (6, 4)])


def test_chunked_matches_per_token_sampling(reduced_models):
    """The PRNG-carried in-graph categorical splits the key exactly like
    the host-side per-token path, so even sampled streams are identical."""
    model, params = reduced_models["qwen3-0.6b"]
    reqs = _requests(model.cfg, [(6, 6), (8, 4), (7, 5)])
    want, _ = _serve(model, params, reqs, chunked=False, greedy=False,
                     seed=13)
    got, _ = _serve(model, params, reqs, chunked=True, greedy=False,
                    seed=13, chunk_tokens=4)
    assert got == want


def test_decode_chunk_midchunk_finish_matches_sequential(reduced_models):
    """Direct ``decode_chunk`` call with a chunk longer than some slots'
    ``remaining``: finished slots must stop emitting in-graph while the
    others continue — emitted counts and token prefixes match a sequential
    ``decode_step`` loop."""
    model, params = reduced_models["qwen3-0.6b"]
    cfg, ML = model.cfg, 64
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, (5,), dtype=np.int32),
               rng.integers(0, cfg.vocab_size, (5,), dtype=np.int32)]
    batch = {"tokens": jnp.asarray(np.stack(prompts))}
    cache = model.init_cache(2, ML)
    logits, cache = model.prefill(params, batch, cache, logits_at=4)
    first = jnp.argmax(logits, -1).astype(jnp.int32)

    remaining = np.array([2, 5], np.int32)     # slot 0 finishes mid-chunk
    T = 5
    state = {"tokens": first, "pos": jnp.full((2,), 5, jnp.int32),
             "remaining": jnp.asarray(remaining),
             "active": jnp.ones((2,), bool),
             "key": jax.random.PRNGKey(0)}
    block, emitted, out, _ = model.decode_chunk(
        params, jax.tree.map(jnp.copy, cache), state, T, max_len=ML)
    assert emitted.tolist() == remaining.tolist()
    assert out["active"].tolist() == [False, False]
    assert out["pos"].tolist() == [7, 10]
    assert out["remaining"].tolist() == [0, 0]

    # sequential oracle: per-slot decode_step loops over the same cache
    toks = [[int(first[i])] for i in range(2)]
    seq_cache, pos = cache, np.array([5, 5], np.int32)
    done = [False, False]
    for _ in range(T):
        cur = jnp.asarray([[toks[0][-1]], [toks[1][-1]]], jnp.int32)
        lg, seq_cache = model.decode_step(params, cur, seq_cache,
                                          jnp.asarray(pos))
        nxt = np.asarray(jnp.argmax(lg, -1))
        for i in range(2):
            if not done[i] and len(toks[i]) - 1 < remaining[i]:
                toks[i].append(int(nxt[i]))
                pos[i] += 1
                done[i] = len(toks[i]) - 1 >= remaining[i]
    block = np.asarray(block)
    for i in range(2):
        assert block[i, :int(emitted[i])].tolist() == toks[i][1:]


def test_decode_chunk_jit_donates_cache(reduced_models):
    """Acceptance: the chunk executable donates the cache — aliasing is
    present in the lowered HLO and the input buffers are actually freed
    after a call (no per-token/per-chunk full-cache copy)."""
    model, params = reduced_models["qwen3-0.6b"]
    eng = ServingEngine(model, params, n_slots=2, max_len=64)
    fn = eng._chunk_fn(2)
    state = {"tokens": jnp.zeros((2,), jnp.int32),
             "pos": jnp.zeros((2,), jnp.int32),
             "remaining": jnp.zeros((2,), jnp.int32),
             "active": jnp.zeros((2,), bool),
             "key": jax.random.PRNGKey(0)}
    txt = fn.lower(params, eng.cache, state).as_text()
    assert "tf.aliasing_output" in txt          # donation survived lowering
    old = eng.cache
    _, _, _, eng.cache = fn(params, eng.cache, state)
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(old))


def test_admission_scatter_donates_cache(reduced_models):
    """The prefill row-scatter donates the engine cache too: after an
    admission the pre-admission cache buffers are gone, not copied."""
    model, params = reduced_models["qwen3-0.6b"]
    eng = ServingEngine(model, params, n_slots=2, max_len=64)
    old = eng.cache
    eng.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                       max_new_tokens=2))
    eng.step()
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(old))


def test_chunk_clamped_by_remaining_and_headroom(reduced_models):
    """No wasted decode iterations: the per-step chunk never exceeds the
    shortest remaining budget or the cache headroom, and max_len
    truncation still finishes slots correctly."""
    model, params = reduced_models["qwen3-0.6b"]
    eng = ServingEngine(model, params, n_slots=1, max_len=16,
                        chunk_tokens=32)
    eng.submit(Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                       max_new_tokens=100))
    done = eng.run()
    assert len(done) == 1
    assert 0 < len(done[0].tokens) <= 16 - 8
    # headroom 7 → power-of-two chunks 4, 2, 1 — bounded, no spin
    assert 1 <= eng.chunks <= 3


def test_chunk_lengths_bucketed_to_powers_of_two(reduced_models):
    """Ragged budgets must not compile one scan executable per distinct
    remaining-clamp value: the engine buckets chunk lengths to powers of
    two, so the shared jit cache stays logarithmic in max_chunk."""
    model, params = reduced_models["qwen3-0.6b"]
    eng = ServingEngine(model, params, n_slots=2, max_len=64,
                        chunk_tokens=8)
    reqs = _requests(model.cfg, [(6, m) for m in (2, 3, 5, 6, 7, 8)],
                     seed=7)
    eng.submit_many(reqs)
    eng.run()
    lengths = {k[1] for k in eng._jits if isinstance(k, tuple)
               and k[0] == "chunk"}
    assert lengths, "no chunk executables were built"
    assert all(n & (n - 1) == 0 for n in lengths), lengths


def test_roofline_chunk_hook():
    """The cost-model hook scales with model size and respects clamps."""
    small = get_config("qwen3-0.6b-reduced")
    big = get_config("qwen3-8b")
    assert 1 <= decode_chunk_tokens(big) <= decode_chunk_tokens(small) <= 32
    assert decode_chunk_tokens(small, max_chunk=4) == 4
