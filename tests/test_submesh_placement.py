"""Sub-mesh container placement: parity + placement-invariant harness.

The paper's claim is only trustworthy if splitting the device into n
containers is semantically invisible: these tests pin (a) bit-identical
greedy streams between a 1-chip sub-mesh engine and the full-device
engine for every model family, (b) completion-for-completion parity of
n ∈ {1, 2, 4} sub-mesh pools against the single-device baseline over a
ragged request batch, and (c) the physical invariants — per-container
params/caches on pairwise-disjoint device sets, cache donation intact
under a sub-mesh jit, placements reused (not re-done) across waves.

Needs >= 8 jax devices: the CI multi-device lane exports
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before pytest so
the CPU fakes a pod; on a single-device host the whole module skips.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.core.containers import ContainerSpec, container_meshes
from repro.launch.mesh import make_container_meshes, mesh_axis_size
from repro.launch.sharding import tree_device_set
from repro.serving.adaptive import AdaptiveServingPool
from repro.serving.engine import Request, ServingEngine
from repro.serving.pool import ContainerServingPool

POD = 8
pytestmark = pytest.mark.skipif(
    jax.device_count() < POD,
    reason="needs >= 8 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

# one representative per model family (same table as test_decode_chunk)
FAMILY_ARCHS = [
    "qwen3-0.6b",        # dense
    "gemma3-27b",        # gemma (local/global sliding-window pattern)
    "mixtral-8x22b",     # moe (GQA)
    "mamba2-2.7b",       # ssm
    "zamba2-7b",         # zamba (ssm + shared attention)
    "whisper-large-v3",  # whisper (encoder-decoder, cross-attention)
]


def _pod_devices():
    return frozenset(jax.devices()[:POD])


def _requests(cfg, plens_max_new, seed=0):
    """Ragged prompts and ragged budgets; whisper/vlm extras attached."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i, (plen, max_new) in enumerate(plens_max_new):
        extras = {}
        if cfg.n_encoder_layers:
            extras["audio_frames"] = 0.1 * rng.standard_normal(
                (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        if cfg.n_vision_tokens:
            extras["vision_embeds"] = 0.1 * rng.standard_normal(
                (cfg.n_vision_tokens, cfg.vision_embed_dim)).astype(
                    np.float32)
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, (plen,),
                                       dtype=np.int32),
            max_new_tokens=max_new, extras=extras))
    return reqs


def _clone(reqs):
    return [Request(r.rid, r.prompt, r.max_new_tokens, r.extras)
            for r in reqs]


# ---------------------------------------------------------------------------
# mesh construction invariants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 2, 4, 8])
def test_container_meshes_partition_pod(n):
    meshes = make_container_meshes(POD, n)
    assert len(meshes) == n
    sets = [frozenset(m.devices.flat) for m in meshes]
    for i, a in enumerate(sets):
        assert len(a) == POD // n
        for b in sets[i + 1:]:
            assert not (a & b), "sub-meshes share devices"
        assert mesh_axis_size(meshes[i], "data") == 1
        assert mesh_axis_size(meshes[i], "model") == POD // n
    assert frozenset().union(*sets) == _pod_devices()


def test_container_meshes_from_spec_match_launcher():
    spec = ContainerSpec(4, 2, 8)
    a = container_meshes(spec)
    b = make_container_meshes(8, 4)
    assert [frozenset(m.devices.flat) for m in a] == \
           [frozenset(m.devices.flat) for m in b]


def test_indivisible_or_overlapping_placements_rejected(reduced_models):
    model, params = reduced_models["qwen3-0.6b"]
    with pytest.raises(ValueError):
        make_container_meshes(POD, 3)
    meshes = make_container_meshes(POD, 2)
    with pytest.raises(ValueError):        # count/mesh mismatch
        ContainerServingPool(model, params, 3, meshes=meshes)
    with pytest.raises(ValueError):        # overlapping slices
        ContainerServingPool(model, params, 2,
                             meshes=[meshes[0], meshes[0]])


# ---------------------------------------------------------------------------
# parity: the archetype headline
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_single_chip_engine_bit_identical(arch, reduced_models):
    """A single engine pinned to a 1-chip sub-mesh produces bit-identical
    greedy token streams to the full-device engine — for every family."""
    model, params = reduced_models[arch]
    reqs = _requests(model.cfg, [(6, 4), (9, 3)], seed=1)

    base = ServingEngine(model, params, n_slots=2, max_len=64)
    base.submit_many(_clone(reqs))
    want = {c.rid: c.tokens for c in base.run()}

    chip = make_container_meshes(POD, POD)[3]      # an arbitrary 1-chip slice
    pinned = ServingEngine(model, params, n_slots=2, max_len=64, mesh=chip)
    assert tree_device_set(pinned.params) == frozenset(chip.devices.flat)
    pinned.submit_many(_clone(reqs))
    got = {c.rid: c.tokens for c in pinned.run()}
    assert got == want


@pytest.mark.parametrize("n", [1, 2, 4])
def test_submesh_pool_matches_single_device_baseline(n, reduced_models):
    """Acceptance: an n-container sub-mesh pool over a ragged request batch
    returns identical ordered completions to the single-device baseline."""
    model, params = reduced_models["qwen3-0.6b"]
    plens_max_new = [(4, 5), (7, 3), (11, 6), (16, 4),
                     (5, 2), (9, 5), (6, 4), (12, 3)]
    reqs = _requests(model.cfg, plens_max_new, seed=2)

    baseline = ContainerServingPool(model, params, 1,
                                    n_slots_per_container=2, max_len=64)
    want, _ = baseline.serve(_clone(reqs))

    pool = ContainerServingPool(model, params, n,
                                n_slots_per_container=2, max_len=64,
                                meshes=make_container_meshes(POD, n))
    got, per = pool.serve(_clone(reqs))
    assert [(c.rid, c.tokens) for c in got] == \
           [(c.rid, c.tokens) for c in want]
    assert sum(r.n_requests for r in per) == len(reqs)


# ---------------------------------------------------------------------------
# placement invariants
# ---------------------------------------------------------------------------
def test_params_and_caches_on_disjoint_device_sets(reduced_models):
    """After a served wave, each container's params AND (donation-replaced)
    caches still live exactly on its slice; slices are pairwise disjoint
    and cover the pod."""
    model, params = reduced_models["qwen3-0.6b"]
    meshes = make_container_meshes(POD, 4)
    pool = ContainerServingPool(model, params, 4,
                                n_slots_per_container=2, max_len=64,
                                meshes=meshes)
    pool.serve(_requests(model.cfg, [(6, 3)] * 8, seed=3))

    sets = []
    for eng, mesh in zip(pool.engines, meshes):
        slice_ = frozenset(mesh.devices.flat)
        assert eng.device_set == slice_
        assert tree_device_set(eng.params) == slice_
        assert tree_device_set(eng.cache) == slice_
        sets.append(slice_)
    for i, a in enumerate(sets):
        for b in sets[i + 1:]:
            assert not (a & b), "containers share devices"
    assert frozenset().union(*sets) == _pod_devices()


def test_cache_donation_holds_under_submesh_jit(reduced_models):
    """The chunk executable still donates the cache when the engine is
    committed to a multi-chip sub-mesh: the aliasing/donation annotation
    survives lowering (multi-device lowerings mark donors as
    ``jax.buffer_donor`` instead of ``tf.aliasing_output``) and the input
    buffers are actually freed after a call."""
    import jax.numpy as jnp

    model, params = reduced_models["qwen3-0.6b"]
    mesh = make_container_meshes(POD, 4)[1]        # a 2-chip slice
    eng = ServingEngine(model, params, n_slots=2, max_len=64, mesh=mesh)
    fn = eng._chunk_fn(2)
    state = {"tokens": jnp.zeros((2,), jnp.int32),
             "pos": jnp.zeros((2,), jnp.int32),
             "remaining": jnp.zeros((2,), jnp.int32),
             "active": jnp.zeros((2,), bool),
             "key": jax.random.PRNGKey(0)}
    txt = fn.lower(eng.params, eng.cache, state).as_text()
    assert "tf.aliasing_output" in txt or "jax.buffer_donor" in txt
    old = eng.cache
    _, _, _, eng.cache = fn(eng.params, old, state)
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(old))
    assert tree_device_set(eng.cache) == frozenset(mesh.devices.flat)


def test_admission_scatter_donates_on_submesh(reduced_models):
    """The prefill row-scatter donates too, and the replacement cache stays
    on the slice — admission never migrates state off the sub-mesh."""
    model, params = reduced_models["qwen3-0.6b"]
    mesh = make_container_meshes(POD, 2)[1]
    eng = ServingEngine(model, params, n_slots=2, max_len=64, mesh=mesh)
    old = eng.cache
    eng.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                       max_new_tokens=2))
    eng.step()
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(old))
    assert tree_device_set(eng.cache) == frozenset(mesh.devices.flat)


def test_placement_reused_across_waves(reduced_models):
    """The device_put replication happens once per container, at engine
    construction — serving more waves must reuse the placed params, not
    re-place them."""
    model, params = reduced_models["qwen3-0.6b"]
    pool = ContainerServingPool(model, params, 2,
                                n_slots_per_container=2, max_len=64,
                                meshes=make_container_meshes(POD, 2))
    before = [jax.tree.leaves(e.params)[0] for e in pool.engines]
    pool.serve(_requests(model.cfg, [(6, 2)] * 4, seed=4))
    pool.serve(_requests(model.cfg, [(5, 3)] * 4, seed=5))
    after = [jax.tree.leaves(e.params)[0] for e in pool.engines]
    assert all(a is b for a, b in zip(before, after))


# ---------------------------------------------------------------------------
# adaptive re-placement
# ---------------------------------------------------------------------------
def test_adaptive_replaces_engines_across_counts(reduced_models):
    """The scheduler changes n across waves; the adaptive pool re-places
    engines onto each count's sub-meshes, caches the placement per count,
    and every wave's completions still match the single-device baseline."""
    model, params = reduced_models["qwen3-0.6b"]
    reqs = _requests(model.cfg, [(6, 3), (9, 2), (7, 4), (6, 3)], seed=6)

    base = ServingEngine(model, params, n_slots=2, max_len=64)
    base.submit_many(_clone(reqs))
    want = {c.rid: c.tokens for c in base.run()}

    apool = AdaptiveServingPool(model, params, [1, 2, 4],
                                objective="time", epsilon=0.0,
                                n_slots_per_container=2, max_len=64,
                                submesh_devices=POD)
    for _ in range(4):                      # bootstrap probes n=2, 1, 4
        out = apool.serve_wave(_clone(reqs))
        assert {c.rid: c.tokens for c in out} == want
    assert len(apool._pools) >= 3           # one placed pool per probed n
    for n, pool in apool._pools.items():
        sets = [e.device_set for e in pool.engines]
        assert all(len(s) == POD // n for s in sets)
        for i, a in enumerate(sets):
            for b in sets[i + 1:]:
                assert not (a & b)
    # placements are cached: serving again at a seen count re-uses the
    # pool object (and therefore its placed engines)
    seen = dict(apool._pools)
    apool.serve_wave(_clone(reqs))
    assert all(apool._pools[n] is p for n, p in seen.items())
