"""Paged KV cache behind the CacheBackend protocol: greedy bit-parity
with the dense baseline across every model family, block-budget
admission, the EngineConfig surface, and the curated public API.

Parity methodology: BOTH engines receive the SAME precomputed Request
lists (a shared rng between the two serves would silently hand them
different prompts and fail for the wrong reason). The paged engine is
deliberately run with its full block budget — it admits MORE requests
concurrently than ``n_slots`` (``peak_active`` asserts it) and must
still emit identical greedy streams per rid.
"""
from __future__ import annotations

import warnings

import numpy as np
import pytest

from repro.serving import EngineConfig
from repro.serving.cache import BlockAllocator, CacheBackend, PagedCache
from repro.serving.engine import (PROMPT_BUCKETS, Request, ServingEngine,
                                  _bucket)

# one representative per model family (see models/model.py's family table)
FAMILY_ARCHS = [
    "qwen3-0.6b",        # dense
    "gemma3-27b",        # gemma (local/global sliding-window pattern)
    "mixtral-8x22b",     # moe (GQA)
    "mamba2-2.7b",       # ssm
    "zamba2-7b",         # zamba (ssm + shared attention)
    "whisper-large-v3",  # whisper (encoder-decoder, cross-attention)
]

# ragged prompts around the block boundary (block_size=16: 15/16/17),
# ragged budgets so slots finish mid-chunk, a 2-token prompt, and
# enough requests that the paged engine's admission exceeds n_slots=2
SPEC = [(5, 4), (15, 3), (16, 5), (17, 2), (9, 6), (2, 1), (12, 8), (7, 5)]

DENSE = EngineConfig(n_slots=2, max_len=64)
PAGED = EngineConfig(n_slots=2, max_len=64, cache="paged", block_size=16)


def _requests(cfg, plens_max_new, seed=0):
    """Deterministic ragged requests; whisper/vlm extras attached. A
    fresh seeded rng per call: two calls build identical prompt lists."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i, (plen, max_new) in enumerate(plens_max_new):
        extras = {}
        if cfg.n_encoder_layers:
            extras["audio_frames"] = 0.1 * rng.standard_normal(
                (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        if cfg.n_vision_tokens:
            extras["vision_embeds"] = 0.1 * rng.standard_normal(
                (cfg.n_vision_tokens, cfg.vision_embed_dim)).astype(
                    np.float32)
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, (plen,),
                                       dtype=np.int32),
            max_new_tokens=max_new, extras=extras))
    return reqs


def _serve(model, params, reqs, config):
    eng = ServingEngine(model, params, config)
    eng.submit_many([Request(r.rid, r.prompt, r.max_new_tokens, r.extras)
                     for r in reqs])
    return {c.rid: c.tokens for c in eng.run()}, eng


# ---------------------------------------------------------------------------
# bit-parity across every family, in-flight beyond n_slots
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_paged_matches_dense_greedy(arch, reduced_models):
    """Identical greedy token streams per rid, with the paged engine
    admitting MORE concurrent requests than the dense engine has slots —
    the cache layout (and the admission width it allows) must be
    semantically invisible."""
    model, params = reduced_models[arch]
    reqs = _requests(model.cfg, SPEC)
    want, _ = _serve(model, params, reqs, DENSE)
    got, eng = _serve(model, params, reqs, PAGED)
    assert got == want
    assert eng.peak_active > DENSE.n_slots, (
        "paged engine never exceeded the dense slot count — the "
        "block-budget admission isn't doing its job")


def test_paged_block_exhaustion_completes(reduced_models):
    """A block pool smaller than the workload: admission stalls on the
    queue head when the allocator runs dry (strict FIFO, no scan-past),
    frees blocks as requests finish, and still completes everything with
    dense-identical streams."""
    model, params = reduced_models["qwen3-0.6b"]
    tight = EngineConfig(n_slots=2, max_len=64, cache="paged",
                         block_size=16, max_blocks=3)
    reqs = _requests(model.cfg, [(16, 4), (16, 4), (16, 4), (5, 2)])
    want, _ = _serve(model, params, reqs, DENSE)
    got, eng = _serve(model, params, reqs, tight)
    assert got == want
    # ≤3 blocks: never more than one 2-block request resident at a time
    assert eng.peak_active <= 2
    # block conservation: free + held (incl. pending-release rows) = pool
    cb = eng.cache_backend
    assert cb.allocator.n_free + sum(len(b) for b in cb._blocks) == 3


def test_paged_respects_max_len_truncation(reduced_models):
    """Budgets past the horizon: both layouts clamp at max_len - 1 and
    stay bit-identical (the paged reservation is clamped too)."""
    model, params = reduced_models["qwen3-0.6b"]
    dense = EngineConfig(n_slots=2, max_len=32)
    paged = EngineConfig(n_slots=2, max_len=32, cache="paged",
                         block_size=16)
    reqs = _requests(model.cfg, [(8, 100), (30, 100), (17, 10)])
    want, _ = _serve(model, params, reqs, dense)
    got, _ = _serve(model, params, reqs, paged)
    assert got == want


# ---------------------------------------------------------------------------
# EngineConfig surface
# ---------------------------------------------------------------------------
def test_engine_legacy_kwargs_warn_and_forward(reduced_models):
    model, params = reduced_models["qwen3-0.6b"]
    with pytest.warns(DeprecationWarning, match="EngineConfig"):
        eng = ServingEngine(model, params, n_slots=3, max_len=32)
    assert eng.config == EngineConfig(n_slots=3, max_len=32)
    assert eng.n_slots == 3 and eng.max_len == 32


def test_engine_rejects_config_plus_legacy_kwargs(reduced_models):
    model, params = reduced_models["qwen3-0.6b"]
    with pytest.raises(TypeError, match="not both"):
        ServingEngine(model, params, EngineConfig(), n_slots=2)


def test_engine_config_validation():
    with pytest.raises(ValueError, match="dense.*paged|paged.*dense"):
        EngineConfig(cache="bogus")
    with pytest.raises(ValueError, match="multiple"):
        EngineConfig(cache="paged", max_len=60, block_size=16)
    with pytest.raises(ValueError, match="prefix_cache"):
        EngineConfig(prefix_cache=True)              # needs cache="paged"
    cfg = EngineConfig(n_slots=2, max_len=64, cache="paged", block_size=16)
    assert cfg.resolved_max_blocks == 8          # dense footprint default
    assert cfg.resolved_max_seqs == 8
    assert cfg.n_rows == 8
    assert EngineConfig(n_slots=2, max_len=64).n_rows == 2


def test_engine_cache_property_proxies_backend(reduced_models):
    """Both layouts satisfy the runtime-checkable CacheBackend protocol
    and the engine's ``cache`` attribute proxies the backend's tree."""
    model, params = reduced_models["qwen3-0.6b"]
    for cfg in (DENSE, PAGED):
        eng = ServingEngine(model, params, cfg)
        assert isinstance(eng.cache_backend, CacheBackend)
        assert eng.cache is eng.cache_backend.tree
    assert isinstance(eng.cache_backend, PagedCache)


# ---------------------------------------------------------------------------
# the hoisted bucket table (bugfix regression)
# ---------------------------------------------------------------------------
def test_bucket_table_single_definition():
    """Engine and router must share ONE bucket table — the historic bug
    was a second hardcoded tuple drifting out of sync."""
    import repro.serving.router as router_mod
    assert router_mod._bucket is _bucket
    assert PROMPT_BUCKETS[0] == 16 and PROMPT_BUCKETS == tuple(
        sorted(PROMPT_BUCKETS))
    for n, want in [(1, 16), (16, 16), (17, 32), (512, 512), (513, 1024),
                    (2048, 2048), (2049, 4096), (5000, 8192)]:
        assert _bucket(n) == want, (n, want)


# ---------------------------------------------------------------------------
# curated public surface + deprecation shims
# ---------------------------------------------------------------------------
def test_public_surface_is_curated():
    import repro.serving as s
    assert s.__all__ == ["Router", "Request", "Completion", "ChunkEvent",
                         "DoneEvent", "RetryEvent", "FailedEvent",
                         "RejectedEvent", "ContainerFailure",
                         "RequestFailed", "RequestRejected", "Fault",
                         "FaultPlan", "ContainerBackend", "EngineConfig",
                         "CacheBackend"]
    for name in s.__all__:
        assert getattr(s, name) is not None


def test_legacy_serving_import_warns():
    import repro.serving as s
    with pytest.warns(DeprecationWarning, match="repro.serving.pool"):
        assert s.ContainerServingPool is not None
    with pytest.raises(AttributeError):
        s.NoSuchName


def test_wave_shim_warns_once(reduced_models):
    import repro.serving.pool as pool_mod
    from repro.serving.backend import ThreadBackend
    from repro.serving.pool import ContainerServingPool
    model, params = reduced_models["qwen3-0.6b"]
    backend = ThreadBackend(model, params, 1, config=DENSE)
    pool = ContainerServingPool(model, params, 1, backend=backend)
    reqs = _requests(model.cfg, [(4, 1)])
    old = pool_mod._WAVE_SHIM_WARNED
    try:
        pool_mod._WAVE_SHIM_WARNED = False
        with pytest.warns(DeprecationWarning, match="Router.submit"):
            pool.serve_timed(reqs)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            pool.serve_timed(reqs)       # second wave: silent
    finally:
        pool_mod._WAVE_SHIM_WARNED = old


# ---------------------------------------------------------------------------
# allocator unit behaviour (the non-hypothesis half; properties live in
# test_block_allocator_props.py)
# ---------------------------------------------------------------------------
def test_block_allocator_all_or_nothing():
    a = BlockAllocator(4)
    got = a.alloc(3)
    assert got is not None and len(got) == 3 and a.n_free == 1
    assert a.alloc(2) is None and a.n_free == 1      # refused, untouched
    a.free(got)
    assert a.n_free == 4
    with pytest.raises(ValueError):
        a.free(got)                                  # double free
    with pytest.raises(ValueError):
        a.free([99])                                 # foreign block


# ---------------------------------------------------------------------------
# deferred-free reclamation at admission (bugfix regression)
# ---------------------------------------------------------------------------
def test_can_admit_counts_deferred_frees():
    """``can_admit`` must see blocks parked behind a deferred free: the
    pre-fix version counted only ``allocator.n_free``, so a freshly
    freed (but unflushed) row made a reclaimable pool look exhausted."""
    from repro.models.cache import PagedLayout
    layout = PagedLayout(block_size=4, max_blocks=4)
    cache = PagedCache(tree={}, n_rows=2, layout=layout, max_len=16,
                       batch_axes=None, jits={})
    assert cache.alloc(0, 16)                  # whole pool to row 0
    assert not cache.can_admit(4)              # live row: truly full
    cache.free(0)                              # deferred (awaiting flush)
    assert cache.allocator.n_free == 0         # nothing freed yet...
    assert cache.can_admit(16)                 # ...but all reclaimable
    cache.flush()
    assert cache.alloc(1, 16)


def test_admission_reclaims_deferred_frees_same_step(reduced_models):
    """Admit/finish churn on a pool exactly one request wide: each
    max_new_tokens=1 request instant-finishes inside the admission batch,
    parking its blocks behind a deferred free. The engine must flush and
    keep admitting within the SAME macro-step — pre-fix, the blocked
    round ended and each request cost a full step."""
    model, params = reduced_models["qwen3-0.6b"]
    tight = EngineConfig(n_slots=4, max_len=64, cache="paged",
                         block_size=16, max_blocks=4)
    reqs = _requests(model.cfg, [(48, 1), (48, 1), (48, 1)])
    eng = ServingEngine(model, params, tight)
    eng.submit_many(reqs)
    eng.step()
    assert len(eng.done) == 3, "churn did not drain in one macro-step"
    assert eng.steps == 1
    cb = eng.cache_backend
    cb.flush()
    assert cb.allocator.n_free == 4 and cb.n_live_blocks == 0


# ---------------------------------------------------------------------------
# copy-on-write prefix sharing: bit parity + hit accounting
# ---------------------------------------------------------------------------
SHARE_PREFIX_LEN = 64                 # four full 16-token blocks
SHARE_PHASE1 = [(80, 4)]              # seeds the prefix index alone
SHARE_PHASE2 = [(72, 3), (70, 4), (75, 2)]   # mixed tails, same prefix
# ssm archs are never bucket-padded and their chunked prefill scan needs
# seq % 32 == 0 — same shared prefix, chunk-aligned prompt lengths
SHARE_PHASE1_SSM = [(96, 4)]
SHARE_PHASE2_SSM = [(96, 3), (96, 4), (96, 2)]


def _shared_prefix_requests(cfg, specs, rid0=0, seed=0):
    """Requests sharing one SHARE_PREFIX_LEN-token prompt prefix (and,
    for encoder/vlm archs, identical extras — the hash seed covers
    extras, so differing frontends must not alias)."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (SHARE_PREFIX_LEN,),
                          dtype=np.int32)
    extras = {}
    if cfg.n_encoder_layers:
        extras["audio_frames"] = 0.1 * rng.standard_normal(
            (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    if cfg.n_vision_tokens:
        extras["vision_embeds"] = 0.1 * rng.standard_normal(
            (cfg.n_vision_tokens, cfg.vision_embed_dim)).astype(np.float32)
    reqs = []
    for i, (plen, max_new) in enumerate(specs):
        tail = rng.integers(0, cfg.vocab_size, (plen - SHARE_PREFIX_LEN,),
                            dtype=np.int32)
        reqs.append(Request(rid=rid0 + i,
                            prompt=np.concatenate([prefix, tail]),
                            max_new_tokens=max_new, extras=extras))
    return reqs


def _serve_phases(model, params, phases, config):
    """Drive the phases through ONE engine, draining between them — the
    second phase's admission then sees the first phase's prefix index,
    and both sharing modes admit the same prefill batch sizes (logits
    are batch-size-sensitive at the last ulp, so parity needs equal n)."""
    eng = ServingEngine(model, params, config)
    got = {}
    for reqs in phases:
        eng.submit_many([Request(r.rid, r.prompt.copy(), r.max_new_tokens,
                                 extras=r.extras) for r in reqs])
        for c in eng.run():
            got[c.rid] = (c.tokens, c.prefix_hit_tokens)
    return got, eng


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_prefix_sharing_bit_parity(arch, reduced_models):
    """Greedy streams with prefix sharing ON are bit-identical to the
    non-sharing paged path at the same block budget, across every model
    family. Eligible archs (the engine's sharing gate) must show hits on
    the second phase; gated archs must run with zero hits — sharing off
    in all but name."""
    model, params = reduced_models[arch]
    p1, p2 = ((SHARE_PHASE1_SSM, SHARE_PHASE2_SSM) if model.cfg.is_ssm
              else (SHARE_PHASE1, SHARE_PHASE2))
    phases = [_shared_prefix_requests(model.cfg, p1, rid0=0),
              _shared_prefix_requests(model.cfg, p2, rid0=10)]
    base = dict(n_slots=4, max_len=128, cache="paged", block_size=16)
    on, eng_on = _serve_phases(model, params, phases,
                               EngineConfig(prefix_cache=True, **base))
    off, eng_off = _serve_phases(model, params, phases,
                                 EngineConfig(prefix_cache=False, **base))
    assert {r: t for r, (t, _) in on.items()} \
        == {r: t for r, (t, _) in off.items()}
    assert all(h == 0 for _, h in off.values())
    assert eng_off.prefix_hit_tokens_total == 0
    if eng_on._share:
        # every phase-2 request hit the full shared prefix
        assert [h for r, (_, h) in sorted(on.items()) if r >= 10] \
            == [SHARE_PREFIX_LEN] * len(SHARE_PHASE2)
        assert eng_on.prefix_hit_tokens_total \
            == SHARE_PREFIX_LEN * len(SHARE_PHASE2)
        assert eng_on.prefill_tokens_executed \
            < eng_off.prefill_tokens_executed
    else:
        assert eng_on.prefix_hit_tokens_total == 0
    # conservation with the prefix index holding its own references
    cb = eng_on.cache_backend
    cb.flush()
    assert (cb.allocator.n_free + cb.n_live_blocks
            == cb.layout.max_blocks)


def test_prefix_sharing_covers_moe():
    """The six-family sweep only exercises the dense gate (mixtral ships
    a sliding window); a window-free mixtral variant pins the moe suffix
    path — dense-layer prologue included — to the same bit parity."""
    import dataclasses as dc

    import jax

    from repro.configs.registry import get_config
    from repro.models.model import Model
    cfg = dc.replace(get_config("mixtral-8x22b-reduced"), sliding_window=0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    phases = [_shared_prefix_requests(cfg, SHARE_PHASE1, rid0=0),
              _shared_prefix_requests(cfg, SHARE_PHASE2, rid0=10)]
    base = dict(n_slots=4, max_len=128, cache="paged", block_size=16)
    on, eng_on = _serve_phases(model, params, phases,
                               EngineConfig(prefix_cache=True, **base))
    off, _ = _serve_phases(model, params, phases,
                           EngineConfig(prefix_cache=False, **base))
    assert eng_on._share, "window-free moe should pass the sharing gate"
    assert {r: t for r, (t, _) in on.items()} \
        == {r: t for r, (t, _) in off.items()}
    assert eng_on.prefix_hit_tokens_total \
        == SHARE_PREFIX_LEN * len(SHARE_PHASE2)


# ---------------------------------------------------------------------------
# page-axis indexing on 3-trailing-dim page groups (MLA latents, int8
# scales). Regression: _copy_fn/gather_prefix derived the layer-stack
# depth from the PAGE array's rank (ndim - 4) — right for attention
# pages, off by one for MLA/scale groups, which turned the CoW page copy
# into a silent no-op (OOB updates drop) and the gather into a read of
# the wrong axis. The depth now comes from the table (always 2 trailing
# dims), matching insert/_clear_fn.
# ---------------------------------------------------------------------------
def _mla_paged_backend():
    import jax

    from repro.configs.registry import get_config
    from repro.models.model import Model
    cfg = get_config("deepseek-v2-lite-16b-reduced")
    model = Model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, EngineConfig(
        n_slots=2, max_len=64, cache="paged", block_size=16))
    return eng.cache_backend


def _each_paged_group(tree):
    from repro.serving.cache import is_paged_group
    if isinstance(tree, dict) and is_paged_group(tree):
        yield tree
    elif isinstance(tree, dict):
        for v in tree.values():
            yield from _each_paged_group(v)


def test_paged_copy_reaches_mla_page_axis():
    import jax.numpy as jnp
    cb = _mla_paged_backend()
    src, dst = 3, 5
    tree = cb.tree
    # seed page `src` on every pageable leaf; MLA page arrays stack as
    # (L, P+1, bs, hd) so axis 1 is the physical page axis
    def seed(t):
        if isinstance(t, dict):
            out = {}
            for k, v in t.items():
                if k.endswith("_pages") and "table" in t:
                    out[k] = v.at[:, src].set(7.5)
                else:
                    out[k] = seed(v) if isinstance(v, dict) else v
            return out
        return t
    tree = seed(tree)
    copied = cb._copy_fn()(tree, jnp.int32(src), jnp.int32(dst))
    groups = list(_each_paged_group(copied))
    assert groups, "no paged groups found in the MLA tree"
    for g in groups:
        for k, v in g.items():
            if not k.endswith("_pages"):
                continue
            pages = np.asarray(v)
            assert (pages[:, dst] == 7.5).all(), \
                f"{k}: CoW page copy did not reach page {dst}"
            assert (pages[:, 0] == 0.0).all(), \
                f"{k}: copy touched an unrelated page"


def test_paged_gather_reads_mla_page_axis():
    import jax.numpy as jnp
    cb = _mla_paged_backend()
    bs = cb.layout.block_size
    page = 4
    tree = cb.tree
    def seed(t):
        if isinstance(t, dict):
            out = {}
            for k, v in t.items():
                if k.endswith("_pages") and "table" in t:
                    # position j within the page carries value j+1
                    ramp = jnp.arange(1, bs + 1, dtype=v.dtype)
                    shape = [1] * v.ndim
                    shape[2] = bs
                    out[k] = v.at[:, page].set(
                        ramp.reshape(shape)[:, 0])
                else:
                    out[k] = seed(v) if isinstance(v, dict) else v
            return out
        return t
    tree = seed(tree)
    table_rows = jnp.full((1, cb.tree_nblocks if hasattr(cb, "tree_nblocks")
                           else 4), page, jnp.int32)
    pos = jnp.arange(bs, dtype=jnp.int32)
    gathered = cb._gather_fn()(tree, table_rows, pos)
    leaves = [np.asarray(v) for g in _each_paged_group_out(gathered)
              for v in g.values()]
    assert leaves, "gather returned no page data"
    for arr in leaves:
        # every gathered position j must carry the seeded value j+1,
        # regardless of trailing rank
        flat = arr.reshape(arr.shape[:-1] + (-1,)) if arr.ndim else arr
        expect = np.arange(1, bs + 1)
        got = np.moveaxis(arr, 2, 0).reshape(bs, -1)
        assert (got == expect[:, None]).all(), \
            "gather read the wrong axis for a 3-trailing-dim page group"


def _each_paged_group_out(tree):
    """Gather output groups: dicts of arrays (no table)."""
    if isinstance(tree, dict) and tree and all(
            not isinstance(v, dict) for v in tree.values()):
        yield tree
    elif isinstance(tree, dict):
        for v in tree.values():
            yield from _each_paged_group_out(v)
