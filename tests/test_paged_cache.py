"""Paged KV cache behind the CacheBackend protocol: greedy bit-parity
with the dense baseline across every model family, block-budget
admission, the EngineConfig surface, and the curated public API.

Parity methodology: BOTH engines receive the SAME precomputed Request
lists (a shared rng between the two serves would silently hand them
different prompts and fail for the wrong reason). The paged engine is
deliberately run with its full block budget — it admits MORE requests
concurrently than ``n_slots`` (``peak_active`` asserts it) and must
still emit identical greedy streams per rid.
"""
from __future__ import annotations

import warnings

import numpy as np
import pytest

from repro.serving import EngineConfig
from repro.serving.cache import BlockAllocator, CacheBackend, PagedCache
from repro.serving.engine import (PROMPT_BUCKETS, Request, ServingEngine,
                                  _bucket)

# one representative per model family (see models/model.py's family table)
FAMILY_ARCHS = [
    "qwen3-0.6b",        # dense
    "gemma3-27b",        # gemma (local/global sliding-window pattern)
    "mixtral-8x22b",     # moe (GQA)
    "mamba2-2.7b",       # ssm
    "zamba2-7b",         # zamba (ssm + shared attention)
    "whisper-large-v3",  # whisper (encoder-decoder, cross-attention)
]

# ragged prompts around the block boundary (block_size=16: 15/16/17),
# ragged budgets so slots finish mid-chunk, a 2-token prompt, and
# enough requests that the paged engine's admission exceeds n_slots=2
SPEC = [(5, 4), (15, 3), (16, 5), (17, 2), (9, 6), (2, 1), (12, 8), (7, 5)]

DENSE = EngineConfig(n_slots=2, max_len=64)
PAGED = EngineConfig(n_slots=2, max_len=64, cache="paged", block_size=16)


def _requests(cfg, plens_max_new, seed=0):
    """Deterministic ragged requests; whisper/vlm extras attached. A
    fresh seeded rng per call: two calls build identical prompt lists."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i, (plen, max_new) in enumerate(plens_max_new):
        extras = {}
        if cfg.n_encoder_layers:
            extras["audio_frames"] = 0.1 * rng.standard_normal(
                (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        if cfg.n_vision_tokens:
            extras["vision_embeds"] = 0.1 * rng.standard_normal(
                (cfg.n_vision_tokens, cfg.vision_embed_dim)).astype(
                    np.float32)
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, (plen,),
                                       dtype=np.int32),
            max_new_tokens=max_new, extras=extras))
    return reqs


def _serve(model, params, reqs, config):
    eng = ServingEngine(model, params, config)
    eng.submit_many([Request(r.rid, r.prompt, r.max_new_tokens, r.extras)
                     for r in reqs])
    return {c.rid: c.tokens for c in eng.run()}, eng


# ---------------------------------------------------------------------------
# bit-parity across every family, in-flight beyond n_slots
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_paged_matches_dense_greedy(arch, reduced_models):
    """Identical greedy token streams per rid, with the paged engine
    admitting MORE concurrent requests than the dense engine has slots —
    the cache layout (and the admission width it allows) must be
    semantically invisible."""
    model, params = reduced_models[arch]
    reqs = _requests(model.cfg, SPEC)
    want, _ = _serve(model, params, reqs, DENSE)
    got, eng = _serve(model, params, reqs, PAGED)
    assert got == want
    assert eng.peak_active > DENSE.n_slots, (
        "paged engine never exceeded the dense slot count — the "
        "block-budget admission isn't doing its job")


def test_paged_block_exhaustion_completes(reduced_models):
    """A block pool smaller than the workload: admission stalls on the
    queue head when the allocator runs dry (strict FIFO, no scan-past),
    frees blocks as requests finish, and still completes everything with
    dense-identical streams."""
    model, params = reduced_models["qwen3-0.6b"]
    tight = EngineConfig(n_slots=2, max_len=64, cache="paged",
                         block_size=16, max_blocks=3)
    reqs = _requests(model.cfg, [(16, 4), (16, 4), (16, 4), (5, 2)])
    want, _ = _serve(model, params, reqs, DENSE)
    got, eng = _serve(model, params, reqs, tight)
    assert got == want
    # ≤3 blocks: never more than one 2-block request resident at a time
    assert eng.peak_active <= 2
    # block conservation: free + held (incl. pending-release rows) = pool
    cb = eng.cache_backend
    assert cb.allocator.n_free + sum(len(b) for b in cb._blocks) == 3


def test_paged_respects_max_len_truncation(reduced_models):
    """Budgets past the horizon: both layouts clamp at max_len - 1 and
    stay bit-identical (the paged reservation is clamped too)."""
    model, params = reduced_models["qwen3-0.6b"]
    dense = EngineConfig(n_slots=2, max_len=32)
    paged = EngineConfig(n_slots=2, max_len=32, cache="paged",
                         block_size=16)
    reqs = _requests(model.cfg, [(8, 100), (30, 100), (17, 10)])
    want, _ = _serve(model, params, reqs, dense)
    got, _ = _serve(model, params, reqs, paged)
    assert got == want


# ---------------------------------------------------------------------------
# EngineConfig surface
# ---------------------------------------------------------------------------
def test_engine_legacy_kwargs_warn_and_forward(reduced_models):
    model, params = reduced_models["qwen3-0.6b"]
    with pytest.warns(DeprecationWarning, match="EngineConfig"):
        eng = ServingEngine(model, params, n_slots=3, max_len=32)
    assert eng.config == EngineConfig(n_slots=3, max_len=32)
    assert eng.n_slots == 3 and eng.max_len == 32


def test_engine_rejects_config_plus_legacy_kwargs(reduced_models):
    model, params = reduced_models["qwen3-0.6b"]
    with pytest.raises(TypeError, match="not both"):
        ServingEngine(model, params, EngineConfig(), n_slots=2)


def test_engine_config_validation():
    with pytest.raises(ValueError, match="dense.*paged|paged.*dense"):
        EngineConfig(cache="bogus")
    with pytest.raises(ValueError, match="multiple"):
        EngineConfig(cache="paged", max_len=60, block_size=16)
    cfg = EngineConfig(n_slots=2, max_len=64, cache="paged", block_size=16)
    assert cfg.resolved_max_blocks == 8          # dense footprint default
    assert cfg.resolved_max_seqs == 8
    assert cfg.n_rows == 8
    assert EngineConfig(n_slots=2, max_len=64).n_rows == 2


def test_engine_cache_property_proxies_backend(reduced_models):
    """Both layouts satisfy the runtime-checkable CacheBackend protocol
    and the engine's ``cache`` attribute proxies the backend's tree."""
    model, params = reduced_models["qwen3-0.6b"]
    for cfg in (DENSE, PAGED):
        eng = ServingEngine(model, params, cfg)
        assert isinstance(eng.cache_backend, CacheBackend)
        assert eng.cache is eng.cache_backend.tree
    assert isinstance(eng.cache_backend, PagedCache)


# ---------------------------------------------------------------------------
# the hoisted bucket table (bugfix regression)
# ---------------------------------------------------------------------------
def test_bucket_table_single_definition():
    """Engine and router must share ONE bucket table — the historic bug
    was a second hardcoded tuple drifting out of sync."""
    import repro.serving.router as router_mod
    assert router_mod._bucket is _bucket
    assert PROMPT_BUCKETS[0] == 16 and PROMPT_BUCKETS == tuple(
        sorted(PROMPT_BUCKETS))
    for n, want in [(1, 16), (16, 16), (17, 32), (512, 512), (513, 1024),
                    (2048, 2048), (2049, 4096), (5000, 8192)]:
        assert _bucket(n) == want, (n, want)


# ---------------------------------------------------------------------------
# curated public surface + deprecation shims
# ---------------------------------------------------------------------------
def test_public_surface_is_curated():
    import repro.serving as s
    assert s.__all__ == ["Router", "Request", "Completion", "ChunkEvent",
                         "DoneEvent", "RetryEvent", "FailedEvent",
                         "RejectedEvent", "ContainerFailure",
                         "RequestFailed", "RequestRejected", "Fault",
                         "FaultPlan", "ContainerBackend", "EngineConfig",
                         "CacheBackend"]
    for name in s.__all__:
        assert getattr(s, name) is not None


def test_legacy_serving_import_warns():
    import repro.serving as s
    with pytest.warns(DeprecationWarning, match="repro.serving.pool"):
        assert s.ContainerServingPool is not None
    with pytest.raises(AttributeError):
        s.NoSuchName


def test_wave_shim_warns_once(reduced_models):
    import repro.serving.pool as pool_mod
    from repro.serving.backend import ThreadBackend
    from repro.serving.pool import ContainerServingPool
    model, params = reduced_models["qwen3-0.6b"]
    backend = ThreadBackend(model, params, 1, config=DENSE)
    pool = ContainerServingPool(model, params, 1, backend=backend)
    reqs = _requests(model.cfg, [(4, 1)])
    old = pool_mod._WAVE_SHIM_WARNED
    try:
        pool_mod._WAVE_SHIM_WARNED = False
        with pytest.warns(DeprecationWarning, match="Router.submit"):
            pool.serve_timed(reqs)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            pool.serve_timed(reqs)       # second wave: silent
    finally:
        pool_mod._WAVE_SHIM_WARNED = old


# ---------------------------------------------------------------------------
# allocator unit behaviour (the non-hypothesis half; properties live in
# test_block_allocator_props.py)
# ---------------------------------------------------------------------------
def test_block_allocator_all_or_nothing():
    a = BlockAllocator(4)
    got = a.alloc(3)
    assert got is not None and len(got) == 3 and a.n_free == 1
    assert a.alloc(2) is None and a.n_free == 1      # refused, untouched
    a.free(got)
    assert a.n_free == 4
    with pytest.raises(ValueError):
        a.free(got)                                  # double free
    with pytest.raises(ValueError):
        a.free([99])                                 # foreign block
