"""Sharding-rule unit tests on an AbstractMesh (no devices needed)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.launch.sharding import ShardingRules
from repro.models.model import Model

# jax >= 0.4.36 takes ((name, size), ...); older versions took (shape, names)
try:
    MESH = AbstractMesh((("data", 16), ("model", 16)))
    POD_MESH = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
except TypeError:
    MESH = AbstractMesh((16, 16), ("data", "model"))
    POD_MESH = AbstractMesh((2, 16, 16), ("pod", "data", "model"))


def _specs(tree):
    return jax.tree.map(lambda ns: ns.spec, tree,
                        is_leaf=lambda x: hasattr(x, "spec"))


@pytest.fixture(scope="module")
def qwen_params_struct():
    model = Model(get_config("qwen3-8b"))
    return jax.eval_shape(lambda k: model.init(k, dtype=jnp.bfloat16),
                          jax.random.PRNGKey(0))


def test_attention_param_specs(qwen_params_struct):
    rules = ShardingRules(MESH, train=True)
    specs = _specs(rules.params(qwen_params_struct))
    stack = specs["stack"]
    # wq (L, d, H, hd): heads on model, d on data (FSDP); hd NEVER sharded
    assert stack["attn"]["wq"] == P(None, "data", "model")
    # kv heads = 8 < model=16: replicated on model
    assert stack["attn"]["wk"] == P(None, "data")
    assert stack["attn"]["wo"] == P(None, "model", None, "data")
    assert stack["mlp"]["w_up"] == P(None, "data", "model")
    assert stack["mlp"]["w_down"] == P(None, "model", "data")
    # embed (V, d): vocab on model
    assert specs["embed"]["table"] == P("model", "data")
    assert specs["lm_head"]["w"] == P("data", "model")


def test_inference_replicates_over_data(qwen_params_struct):
    rules = ShardingRules(MESH, train=False, fsdp=False)
    specs = _specs(rules.params(qwen_params_struct))
    stack = specs["stack"]
    assert stack["attn"]["wq"] == P(None, None, "model")
    assert stack["mlp"]["w_down"] == P(None, "model")
    flat = jax.tree.leaves(
        jax.tree.map(lambda s: "data" in jax.tree.leaves(tuple(s)) if s else False,
                     stack, is_leaf=lambda x: isinstance(x, P)))
    assert not any(flat), "inference (no fsdp) must not shard over data"


def test_expert_parallel_when_divisible():
    model = Model(get_config("deepseek-v2-lite-16b"))
    struct = jax.eval_shape(lambda k: model.init(k, dtype=jnp.bfloat16),
                            jax.random.PRNGKey(0))
    rules = ShardingRules(MESH, train=True)
    specs = _specs(rules.params(struct))
    up = specs["stack"]["moe"]["experts"]["w_up"]
    # (L, E=64, d, ff): E divides 16 → expert-parallel
    assert up == P(None, "model", "data")


def test_tensor_parallel_experts_when_not_divisible():
    model = Model(get_config("mixtral-8x22b"))
    struct = jax.eval_shape(lambda k: model.init(k, dtype=jnp.bfloat16),
                            jax.random.PRNGKey(0))
    rules = ShardingRules(MESH, train=True)
    specs = _specs(rules.params(struct))
    up = specs["stack"]["moe"]["experts"]["w_up"]
    # (L, E=8, d, ff): E doesn't divide 16 → shard ff
    assert up == P(None, None, "data", "model")


def test_cache_specs_gqa_decode():
    model = Model(get_config("qwen3-8b"))
    cache = jax.eval_shape(lambda: model.init_cache(128, 32768,
                                                    dtype=jnp.bfloat16))
    rules = ShardingRules(MESH, train=False)
    specs = _specs(rules.cache(cache, batch=128))
    kspec = specs["stack"]["k"]
    # (L, B, W, kv=8, hd): kv doesn't divide → sequence-parallel decode
    assert kspec == P(None, "data", "model")


def test_cache_specs_long_context_idle_batch():
    model = Model(get_config("gemma3-27b"))
    cache = jax.eval_shape(lambda: model.init_cache(1, 524_288,
                                                    dtype=jnp.bfloat16))
    rules = ShardingRules(MESH, train=False)
    specs = _specs(rules.cache(cache, batch=1))
    gspec = specs["super"]["global"]["k"]
    # (n_super, B=1, W, kv=16, hd): batch idle → seq over data, kv over model
    assert gspec == P(None, None, "data", "model")


def test_cache_specs_mla_latent():
    model = Model(get_config("deepseek-v2-lite-16b"))
    cache = jax.eval_shape(lambda: model.init_cache(128, 32768,
                                                    dtype=jnp.bfloat16))
    rules = ShardingRules(MESH, train=False)
    specs = _specs(rules.cache(cache, batch=128))
    ckv = specs["stack"]["ckv"]        # (L, B, S, r=512)
    # seq over model (distributed softmax) — NOT r (r-sharding makes GSPMD
    # all-gather the whole latent cache per layer)
    assert ckv == P(None, "data", "model")


def test_ssm_cache_heads_on_model():
    model = Model(get_config("mamba2-2.7b"))
    cache = jax.eval_shape(lambda: model.init_cache(128, 32768,
                                                    dtype=jnp.bfloat16))
    rules = ShardingRules(MESH, train=False)
    specs = _specs(rules.cache(cache, batch=128))
    state = specs["stack"]["state"]    # (L, B, nh=80, hd, ds)
    assert state == P(None, "data", "model")


def test_batch_spec_multipod():
    rules = ShardingRules(POD_MESH, train=True)
    specs = _specs(rules.batch({"tokens": jax.ShapeDtypeStruct((256, 4096),
                                                               jnp.int32)}))
    assert specs["tokens"] == P(("pod", "data"))


def test_batch_too_small_replicates():
    rules = ShardingRules(MESH, train=False)
    specs = _specs(rules.batch({"tokens": jax.ShapeDtypeStruct((1, 128),
                                                               jnp.int32)}))
    assert specs["tokens"] == P()


def test_opt_state_mirrors_params(qwen_params_struct):
    from repro.train.optimizer import init_opt_state
    opt = jax.eval_shape(init_opt_state, qwen_params_struct)
    rules = ShardingRules(MESH, train=True)
    specs = _specs(rules.opt_state(opt))
    assert specs["m"]["stack"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["step"] == P()


def test_head_dim_never_sharded(qwen_params_struct):
    """head_dim is always a contraction dim of the attention scores — a
    sharded head_dim forces an all-reduce per flash tile (the exact bug the
    role-based rules exist to prevent)."""
    for cfgname in ("qwen3-8b", "gemma3-27b", "whisper-large-v3"):
        model = Model(get_config(cfgname))
        struct = jax.eval_shape(lambda k: model.init(k, dtype=jnp.bfloat16),
                                jax.random.PRNGKey(0))
        rules = ShardingRules(MESH, train=True)
        specs = _specs(rules.params(struct))

        def check(path, spec, leaf):
            names = [str(getattr(p, "key", p)) for p in path]
            if names[-1] in ("wq", "wk", "wv"):
                rank = len(leaf.shape)
                full = tuple(spec) + (None,) * (rank - len(spec))
                assert full[-1] is None, (names, spec)   # hd dim unsharded

        jax.tree_util.tree_map_with_path(
            check, specs, struct, is_leaf=lambda x: isinstance(x, P))
