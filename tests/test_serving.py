"""Serving engine + container pool integration tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.pipeline import VideoRequestStream
from repro.models.model import Model
from repro.serving.engine import Completion, Request, ServingEngine
from repro.serving.pool import ContainerServingPool, latency_percentiles


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("qwen3-0.6b-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _requests(cfg, n, plen=8, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, (plen,),
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def test_engine_completes_all_requests(small_lm):
    model, params = small_lm
    eng = ServingEngine(model, params, n_slots=2, max_len=64)
    reqs = _requests(model.cfg, 5)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert sorted(c.rid for c in done) == list(range(5))
    for c in done:
        assert len(c.tokens) == 4


def test_engine_greedy_matches_manual_decode(small_lm):
    """Continuous batching with ragged slots must equal a manual per-request
    prefill+decode loop."""
    model, params = small_lm
    cfg = model.cfg
    reqs = _requests(cfg, 3, plen=6, max_new=3, seed=1)

    eng = ServingEngine(model, params, n_slots=2, max_len=64)
    for r in reqs:
        eng.submit(r)
    done = {c.rid: c.tokens for c in eng.run()}

    for r in reqs:
        cache = model.init_cache(1, 64)
        batch = {"tokens": jnp.asarray(r.prompt)[None]}
        logits, cache = model.prefill(params, batch, cache,
                                      logits_at=len(r.prompt) - 1)
        toks = [int(jnp.argmax(logits, -1)[0])]
        pos = len(r.prompt)
        while len(toks) < r.max_new_tokens:
            lg, cache = model.decode_step(
                params, jnp.asarray([[toks[-1]]], jnp.int32), cache,
                jnp.asarray([pos], jnp.int32))
            toks.append(int(jnp.argmax(lg, -1)[0]))
            pos += 1
        assert done[r.rid] == toks, r.rid


def test_engine_continuous_batching_refills(small_lm):
    model, params = small_lm
    eng = ServingEngine(model, params, n_slots=1, max_len=64)
    reqs = _requests(model.cfg, 4, max_new=2)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 4          # 1 slot served 4 requests sequentially


def test_pool_splits_and_preserves_order(small_lm):
    model, params = small_lm
    pool = ContainerServingPool(model, params, n_containers=3,
                                n_slots_per_container=2, max_len=64)
    reqs = _requests(model.cfg, 7, max_new=2)
    ordered, per_container = pool.serve(reqs)
    assert [c.rid for c in ordered] == [r.rid for r in reqs]
    # paper's equal split: 7 → 3/2/2
    assert [r.n_requests for r in per_container] == [3, 2, 2]


def test_pool_outputs_independent_of_container_count(small_lm):
    """Splitting is semantically invisible: same completions for n=1, 2, 4
    (the paper's accuracy-neutrality claim)."""
    model, params = small_lm
    reqs = _requests(model.cfg, 4, max_new=3, seed=3)
    outs = []
    for n in (1, 2, 4):
        pool = ContainerServingPool(model, params, n_containers=n,
                                    n_slots_per_container=2, max_len=64)
        ordered, _ = pool.serve(list(reqs))
        outs.append([tuple(c.tokens) for c in ordered])
    assert outs[0] == outs[1] == outs[2]


def test_ssm_engine_no_padding(small_lm):
    """SSM caches absorb right-padding, so the engine must prefill SSM
    prompts unpadded — and completions must still be correct."""
    cfg = get_config("mamba2-2.7b-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, n_slots=2, max_len=64)
    assert not eng._pad_ok
    reqs = _requests(cfg, 3, plen=5, max_new=3)
    for r in reqs:
        eng.submit(r)
    done = {c.rid: c.tokens for c in eng.run()}

    r = reqs[0]
    cache = model.init_cache(1, 64)
    lg, cache = model.prefill(params, {"tokens": jnp.asarray(r.prompt)[None]},
                              cache, logits_at=len(r.prompt) - 1)
    toks = [int(jnp.argmax(lg, -1)[0])]
    pos = len(r.prompt)
    while len(toks) < 3:
        lg, cache = model.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), cache,
            jnp.asarray([pos], jnp.int32))
        toks.append(int(jnp.argmax(lg, -1)[0]))
        pos += 1
    assert done[r.rid] == toks


def test_engine_max_len_truncates_generation(small_lm):
    """A request whose generation would overrun the cache is finished at
    the max_len boundary rather than corrupting the ring."""
    model, params = small_lm
    eng = ServingEngine(model, params, n_slots=1, max_len=16)
    eng.submit(Request(rid=0,
                       prompt=np.arange(8, dtype=np.int32),
                       max_new_tokens=100))
    done = eng.run()
    assert len(done) == 1
    assert 0 < len(done[0].tokens) <= 16 - 8


def test_engine_interleaved_submission(small_lm):
    """Requests submitted while others are mid-decode (true continuous
    batching) still complete with the same outputs as batch submission."""
    model, params = small_lm
    reqs = _requests(model.cfg, 4, plen=6, max_new=4, seed=5)

    eng1 = ServingEngine(model, params, n_slots=2, max_len=64)
    for r in reqs:
        eng1.submit(r)
    want = {c.rid: c.tokens for c in eng1.run()}

    eng2 = ServingEngine(model, params, n_slots=2, max_len=64)
    eng2.submit(reqs[0])
    eng2.step()
    eng2.submit(reqs[1])
    eng2.step()
    eng2.step()
    eng2.submit(reqs[2])
    eng2.submit(reqs[3])
    got = {c.rid: c.tokens for c in eng2.run()}
    assert got == want


def test_step_reports_work_remaining(small_lm):
    """Non-blocking contract: step() is a no-op returning False when idle,
    True while work remains — what lets a pool drive engines round-robin.
    chunk_tokens=1 pins one decode iteration per macro-step so the
    step-by-step protocol stays observable."""
    model, params = small_lm
    eng = ServingEngine(model, params, n_slots=2, max_len=64,
                        chunk_tokens=1)
    assert not eng.has_work
    assert eng.step() is False
    eng.submit_many(_requests(model.cfg, 2, max_new=3))
    assert eng.has_work
    assert eng.step() is True          # prefill + first decode, more left
    while eng.step():
        pass
    assert not eng.has_work
    assert len(eng.done) == 2
    assert eng.busy_s > 0.0
    assert eng.tokens_generated == 6   # per-chunk token accounting


def test_run_budget_counts_admit_only_steps(small_lm):
    """Regression: ``run(max_steps)`` must budget every ``step()`` call.
    With max_new_tokens=1 every iteration is admit-only (the request
    finishes at prefill) — the old decode-only counter never advanced and
    the loop could spin past its budget."""
    model, params = small_lm
    eng = ServingEngine(model, params, n_slots=1, max_len=64)
    eng.submit_many(_requests(model.cfg, 5, max_new=1))
    with pytest.warns(RuntimeWarning, match="exhausted max_steps"):
        done = eng.run(max_steps=3)
    assert len(done) == 3              # one admit-only step per request
    assert eng.has_work                # budget stopped the loop, not idle
    assert len(eng.run()) == 2         # fresh budget drains the rest


def test_run_budget_exhaustion_warns_and_flags(small_lm):
    """Regression: ``run(max_steps)`` used to return a partial result
    silently when the step budget ran out with work still queued. It must
    warn and set ``budget_exhausted`` — and clear the flag again on a run
    that drains cleanly."""
    model, params = small_lm
    eng = ServingEngine(model, params, n_slots=1, max_len=64,
                        chunk_tokens=1)
    assert eng.budget_exhausted is False
    eng.submit_many(_requests(model.cfg, 3, max_new=4))
    with pytest.warns(RuntimeWarning, match="partial completions"):
        partial = eng.run(max_steps=2)
    assert eng.budget_exhausted
    assert len(partial) < 3
    import warnings as _warnings
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")    # a clean drain must NOT warn
        rest = eng.run()
    assert not eng.budget_exhausted
    assert len(partial) + len(rest) == 3


def test_completion_latency_uses_monotonic_clock(small_lm, monkeypatch):
    """latency_s must come from the monotonic clock (perf_counter), never
    time.time() — a wall-clock step mid-request would corrupt it."""
    import time as real_time
    import types

    import repro.serving.engine as engine_mod
    model, params = small_lm
    shim = types.SimpleNamespace(
        perf_counter=real_time.perf_counter,
        time=lambda: pytest.fail("engine read time.time()"))
    monkeypatch.setattr(engine_mod, "time", shim)
    eng = ServingEngine(model, params, n_slots=2, max_len=64)
    eng.submit_many(_requests(model.cfg, 2, max_new=3))
    done = eng.run()
    assert len(done) == 2
    for c in done:
        assert 0.0 < c.latency_s < 600.0


def test_batched_admission_matches_one_at_a_time(small_lm):
    """Same-bucket ragged prompts admitted as one prefill batch must
    produce exactly the tokens of per-request admission (per-row logits_at
    makes the padded bucket exact)."""
    model, params = small_lm
    rng = np.random.default_rng(11)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, model.cfg.vocab_size,
                                        (plen,), dtype=np.int32),
                    max_new_tokens=4)
            for i, plen in enumerate((4, 7, 11, 16, 5))]  # all bucket 16

    batched = ServingEngine(model, params, n_slots=4, max_len=64,
                            batch_admit=True)
    batched.submit_many(reqs)
    got = {c.rid: c.tokens for c in batched.run()}

    single = ServingEngine(model, params, n_slots=4, max_len=64,
                           batch_admit=False)
    single.submit_many(reqs)
    want = {c.rid: c.tokens for c in single.run()}
    assert got == want


def test_concurrent_pool_order_and_disjoint_rids(small_lm):
    """The concurrent pool preserves request order in the combined output
    and assigns each rid to exactly one container."""
    model, params = small_lm
    reqs = _requests(model.cfg, 9, max_new=3, seed=7)
    pool = ContainerServingPool(model, params, n_containers=3,
                                n_slots_per_container=2, max_len=64)
    ordered, per, wall, energy = pool.serve_timed(list(reqs),
                                                  concurrent=True)
    assert [c.rid for c in ordered] == [r.rid for r in reqs]
    rid_sets = [set(c.rid for c in r.completions) for r in per]
    for i, a in enumerate(rid_sets):
        for b in rid_sets[i + 1:]:
            assert not (a & b), "containers served overlapping rids"
    assert set().union(*rid_sets) == {r.rid for r in reqs}
    assert wall > 0 and energy > 0
    for r in per:
        assert 0 < r.busy_s and r.energy_j > 0


def test_concurrent_matches_sequential_outputs(small_lm):
    """Threaded execution is semantically invisible: identical completions
    to the sequential baseline (greedy decode, independent engines)."""
    model, params = small_lm
    reqs = _requests(model.cfg, 8, max_new=3, seed=9)
    pool = ContainerServingPool(model, params, n_containers=4,
                                n_slots_per_container=2, max_len=64)
    seq, _ = pool.serve(list(reqs), concurrent=False)
    conc, _ = pool.serve(list(reqs), concurrent=True)
    assert [(c.rid, tuple(c.tokens)) for c in conc] == \
           [(c.rid, tuple(c.tokens)) for c in seq]


def test_engine_run_drains_completions(small_lm):
    """Engines are reused across serves: run() must return only this
    call's completions and reset its step budget per call."""
    model, params = small_lm
    eng = ServingEngine(model, params, n_slots=2, max_len=64)
    eng.submit_many(_requests(model.cfg, 3, max_new=2))
    first = eng.run()
    assert sorted(c.rid for c in first) == [0, 1, 2]
    assert eng.run() == []                 # drained, idle
    eng.submit_many(_requests(model.cfg, 2, max_new=2, seed=2))
    second = eng.run()
    assert len(second) == 2                # no stale completions


def test_pool_reuse_returns_only_current_wave(small_lm):
    """A cached pool serving repeated waves (the adaptive loop) must not
    leak completions from earlier waves into later results."""
    model, params = small_lm
    pool = ContainerServingPool(model, params, n_containers=2,
                                n_slots_per_container=2, max_len=64)
    reqs = _requests(model.cfg, 4, max_new=2)
    pool.serve(list(reqs))
    ordered, per = pool.serve(list(reqs))  # same rids, reused engines
    assert [c.rid for c in ordered] == [r.rid for r in reqs]
    assert sum(len(r.completions) for r in per) == len(reqs)


def test_concurrent_worker_error_propagates(small_lm):
    """An engine failure inside a worker thread must surface as the
    original exception, not a later unpack error."""
    model, params = small_lm

    class Boom(ServingEngine):
        def run(self, max_steps=10_000):
            raise RuntimeError("boom")

    pool = ContainerServingPool(model, params, n_containers=2,
                                n_slots_per_container=2, max_len=64,
                                engine_factory=Boom)
    with pytest.raises(RuntimeError, match="boom"):
        pool.serve(_requests(model.cfg, 2, max_new=2))


def test_latency_percentiles_pure():
    comps = [Completion(i, [], 0, latency_s=float(i + 1)) for i in range(20)]
    lats = np.arange(1.0, 21.0)
    p50, p95 = latency_percentiles(comps)
    assert p50 == pytest.approx(float(np.percentile(lats, 50)))
    assert p95 == pytest.approx(float(np.percentile(lats, 95)))
    assert p50 <= p95
    assert latency_percentiles([]) == (0.0, 0.0)


def test_assemble_wave_empty_completions_yield_zeros():
    """Regression guard: an idle container (empty segment, zero wall — as
    happens in a streamed window) must produce a well-defined all-zeros
    ContainerResult, never a crash in the percentile/throughput math."""
    from repro.serving.pool import EnergyProxy, assemble_wave

    reqs = _requests(get_config("qwen3-0.6b-reduced"), 2)
    out = [([Completion(r.rid, [1, 2], len(r.prompt), 0.01)
             for r in reqs], 0.5, 0.4, 4),
           ([], 0.0, 0.0, 0)]                    # idle container
    ordered, results, energy = assemble_wave(
        out, [reqs, []], 0.5, EnergyProxy())
    assert [c.rid for c in ordered] == [0, 1]
    idle = results[1]
    assert idle.n_requests == 0 and idle.completions == []
    assert idle.tokens_per_s == 0.0
    assert idle.latency_p50_s == idle.latency_p95_s == 0.0
    assert energy > 0                            # busy container's share


def test_pool_with_more_containers_than_requests(small_lm):
    """n_containers > len(requests): the surplus containers idle through
    the wave with zeroed accounting and the served requests still come
    back in order."""
    model, params = small_lm
    pool = ContainerServingPool(model, params, n_containers=4,
                                n_slots_per_container=2, max_len=64)
    reqs = _requests(model.cfg, 2, max_new=2)
    ordered, per = pool.serve(reqs)
    assert [c.rid for c in ordered] == [0, 1]
    assert [r.n_requests for r in per] == [1, 1, 0, 0]
    for r in per[2:]:
        assert r.completions == [] and r.n_tokens == 0
        assert r.latency_p50_s == r.latency_p95_s == 0.0


def test_pool_reports_latency_percentiles(small_lm):
    """Each ContainerResult carries p50/p95 completion latency (ROADMAP's
    scheduler-facing percentiles): positive, ordered, bounded by the
    container's wall time (latency clocks start at admission)."""
    model, params = small_lm
    pool = ContainerServingPool(model, params, n_containers=2,
                                n_slots_per_container=2, max_len=64)
    _, per = pool.serve(_requests(model.cfg, 6, max_new=3))
    for r in per:
        assert 0.0 < r.latency_p50_s <= r.latency_p95_s <= r.wall_s


@pytest.mark.parametrize("chunked", [True, False])
def test_completion_prompt_len_is_admission_prompt_length(small_lm, chunked):
    """Regression: _finish used to report slot.pos as prompt_len, which at
    finish time is prompt length PLUS generated tokens. The true prompt
    length must be recorded at admission — on both the fused-chunk and
    per-token decode paths."""
    model, params = small_lm
    eng = ServingEngine(model, params, n_slots=2, max_len=64,
                        chunked=chunked)
    rng = np.random.default_rng(13)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, model.cfg.vocab_size, (plen,),
                                        dtype=np.int32),
                    max_new_tokens=3)
            for i, plen in enumerate((4, 9, 13))]
    eng.submit_many(reqs)
    done = {c.rid: c for c in eng.run()}
    for r in reqs:
        c = done[r.rid]
        assert len(c.tokens) == 3
        assert c.prompt_len == len(r.prompt), \
            "prompt_len must not include generated tokens"


def test_zero_budget_request_completes_empty(small_lm):
    """Regression: a request with max_new_tokens <= 0 used to emit the
    prefill sample — one token it never asked for. It must now complete
    empty without touching the device, while neighbours are unaffected."""
    model, params = small_lm
    rng = np.random.default_rng(17)

    def prompt(plen):
        return rng.integers(0, model.cfg.vocab_size, (plen,),
                            dtype=np.int32)

    reqs = [Request(rid=0, prompt=prompt(5), max_new_tokens=0),
            Request(rid=1, prompt=prompt(7), max_new_tokens=-2),
            Request(rid=2, prompt=prompt(6), max_new_tokens=2)]
    eng = ServingEngine(model, params, n_slots=2, max_len=64)
    eng.submit_many(reqs)
    done = {c.rid: c for c in eng.run()}
    assert sorted(done) == [0, 1, 2]
    assert done[0].tokens == [] and done[1].tokens == []
    assert done[0].prompt_len == 5 and done[1].prompt_len == 7
    assert len(done[2].tokens) == 2
    # token accounting saw only the real request's tokens
    assert eng.tokens_generated == 2

    # an all-zero-budget queue drains without any device work
    eng2 = ServingEngine(model, params, n_slots=2, max_len=64)
    eng2.submit(Request(rid=9, prompt=prompt(4), max_new_tokens=0))
    out = eng2.run()
    assert [c.rid for c in out] == [9] and out[0].tokens == []
    assert eng2.tokens_generated == 0 and eng2.chunks == 0


def test_long_prompt_bucket_rounds_to_power_of_two(small_lm):
    """Regression: _bucket returned the raw length past 2048, so every
    distinct long prompt compiled its own prefill executable. Lengths past
    the table must round up to the next power of two so ragged long
    prompts share one jitted prefill."""
    from repro.serving.engine import _bucket

    for b in (16, 32, 64, 128, 256, 512, 1024, 2048):
        assert _bucket(b) == b and _bucket(b - 1) == b
    assert _bucket(2049) == 4096
    assert _bucket(3000) == 4096
    assert _bucket(4096) == 4096
    assert _bucket(4097) == 8192

    model, params = small_lm
    eng = ServingEngine(model, params, n_slots=2, max_len=64)
    rng = np.random.default_rng(19)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, model.cfg.vocab_size, (plen,),
                                        dtype=np.int32),
                    max_new_tokens=2)
            for i, plen in enumerate((2049, 2500, 3000, 4096))]
    # one admission bucket — hence ONE prefill executable in the shared
    # jit cache, instead of one compile per distinct long length
    assert len({eng._admit_key(r) for r in reqs}) == 1
    assert eng._prefill_fn(4, _bucket(2049)) is eng._prefill_fn(
        4, _bucket(4096))


def test_video_stream_requests_deterministic():
    s1 = VideoRequestStream(n_frames=10, seed=42)
    s2 = VideoRequestStream(n_frames=10, seed=42)
    np.testing.assert_array_equal(s1.frames(), s2.frames())
    r1 = s1.prompt_requests(100, 8)
    r2 = s2.prompt_requests(100, 8)
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a, b)
