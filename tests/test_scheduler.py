"""DivideAndSave scheduler: converges to the device's optimal container
count from online observations (the paper's concluding proposal)."""
from __future__ import annotations

import pytest

from repro.core.energy_model import orin_model, tx2_model
from repro.core.scheduler import DivideAndSaveScheduler


def _drive(sched, device, counts):
    for n in counts:
        sched.observe(n, device.time(n), device.energy(n))


def test_scheduler_converges_tx2_energy():
    dev = tx2_model()
    sched = DivideAndSaveScheduler(list(range(1, 7)), objective="energy",
                                   epsilon=0.0)
    _drive(sched, dev, [1, 2, 3, 4, 5, 6])
    best = min(range(1, 7), key=dev.energy)
    assert sched.pick() == best


def test_scheduler_converges_orin_time():
    dev = orin_model()
    sched = DivideAndSaveScheduler(list(range(1, 13)), objective="time",
                                   epsilon=0.0)
    _drive(sched, dev, [1, 4, 8, 12])
    pick = sched.pick()
    # saturating-exp curve: anything ≥8 is within a few % of optimum
    assert pick >= 8


def test_scheduler_bootstrap_explores():
    sched = DivideAndSaveScheduler([1, 2, 4, 8], epsilon=0.0)
    first = sched.pick()
    assert first in (1, 2, 4, 8)
    assert sched.n_observations == 0


def test_deadline_constrains_choice():
    dev = tx2_model()
    # TX2 time minimises at 4; force a deadline only n=4 can meet, but make
    # energy minimal at a different count by using the energy objective
    sched = DivideAndSaveScheduler(
        list(range(1, 7)), objective="energy_under_deadline",
        deadline_s=dev.time(4) * 1.02, epsilon=0.0)
    _drive(sched, dev, [1, 2, 3, 4, 5, 6])
    pick = sched.pick()
    assert dev.time(pick) <= dev.time(4) * 1.02


def test_deadline_infeasible_falls_back_to_fastest():
    dev = tx2_model()
    sched = DivideAndSaveScheduler(
        list(range(1, 7)), objective="energy_under_deadline",
        deadline_s=1.0, epsilon=0.0)   # nothing meets 1 s
    _drive(sched, dev, [1, 2, 3, 4, 5, 6])
    pick = sched.pick()
    assert pick == min(range(1, 7), key=dev.time)


def test_summary_contains_fitted_models():
    dev = orin_model()
    sched = DivideAndSaveScheduler(list(range(1, 13)), epsilon=0.0)
    _drive(sched, dev, [1, 6, 12])
    s = sched.summary()
    assert s["observations"] == 3
    assert s["time_model"] is not None
    assert s["choice"] in range(1, 13)


def test_best_is_exploitation_only():
    """best() matches the fitted argmin once models exist, and falls back
    to the best observed mean (never exploration) before that."""
    dev = tx2_model()
    sched = DivideAndSaveScheduler(list(range(1, 7)), objective="energy",
                                   epsilon=0.5, seed=1)
    _drive(sched, dev, [2, 5])             # too few counts to fit
    assert sched.best() == min((2, 5), key=dev.energy)
    _drive(sched, dev, [1, 3, 4, 6])
    assert sched.best() == sched._argmin()


def test_rejects_empty_feasible_set():
    with pytest.raises(ValueError):
        DivideAndSaveScheduler([])


def test_untrusted_fit_deadline_fallback_uses_observed_means():
    """Regression: when every count misses the deadline AND the fit failed
    the RMSE_TRUST check, the fallback used to rank counts by the rejected
    fitted model anyway. It must rank by observed time means — the same
    source the main loop just fell back to."""
    from repro.core.energy_model import FittedModel

    sched = DivideAndSaveScheduler([1, 2, 4],
                                   objective="energy_under_deadline",
                                   deadline_s=0.5, epsilon=0.0)
    for n, t in ((1, 5.0), (2, 1.0), (4, 9.0)):   # observed fastest: n=2
        sched.observe(n, t, t * 40.0)
    # deliberately misfit models: enormous rmse (fails the trust check),
    # with a fitted argmin at n=4 — the opposite of the measurements
    misfit = FittedModel("quad", (0.0, -1.0, 10.0), rmse=100.0)
    sched.time_model = sched.energy_model = misfit
    assert sched._argmin() == 2      # old fallback returned misfit's n=4
    assert sched.pick() == 2
    assert sched.best() == 2


def test_poor_fit_falls_back_to_observed_minimum():
    """A V-shaped curve over a wide n range (the pod factorisation sweep)
    fits neither convex form; the scheduler must then trust the measured
    means instead of a misleading fitted argmin."""
    ns = [1, 2, 4, 8, 16, 32, 64, 128]
    times = [1.0, 0.82, 0.83, 0.68, 0.71, 1.68, 2.07, 2.60]
    sched = DivideAndSaveScheduler(ns, objective="energy", epsilon=0.0)
    for n, t in zip(ns, times):
        sched.observe(n, t, t * 0.8)
    assert sched.pick() == 8


# ---------------------------------------------------------------------------
# the quantile (ttfc p95) model behind energy_under_slo
# ---------------------------------------------------------------------------
def _drive_slo(sched, windows_per_count=10):
    """Observations where energy FALLS with n (argmin at the top) but
    the tail at small counts is blown: the mean objective and the SLO
    constraint disagree by construction."""
    tails = {1: 2.0, 2: 0.9, 3: 0.25, 4: 0.2}
    energy = {1: 10.0, 2: 8.0, 3: 9.0, 4: 11.0}
    for n, q in tails.items():
        for _ in range(windows_per_count):
            sched.observe(n, 1.0, energy[n], ttfc_p95_s=q)


def test_energy_under_slo_skips_infeasible_counts():
    sched = DivideAndSaveScheduler([1, 2, 3, 4],
                                   objective="energy_under_slo",
                                   slo_ttfc_p95_s=0.5, epsilon=0.0)
    _drive_slo(sched)
    # energy argmin is n=2, but its predicted tail (0.9) breaks the
    # 0.5s constraint: the cheapest FEASIBLE count is n=3
    assert sched.pick() == 3
    assert sched.predict_ttfc_p95(1) > 0.5
    assert sched.predict_ttfc_p95(3) <= 0.5


def test_energy_under_slo_infeasible_everywhere_minimises_tail():
    sched = DivideAndSaveScheduler([1, 2, 3, 4],
                                   objective="energy_under_slo",
                                   slo_ttfc_p95_s=0.05, epsilon=0.0)
    _drive_slo(sched)
    assert sched.pick() == 4        # least-bad violation


def test_energy_under_slo_requires_target():
    with pytest.raises(ValueError, match="slo_ttfc_p95_s"):
        DivideAndSaveScheduler([1, 2], objective="energy_under_slo")


def test_quantile_aggregation_is_tail_not_mean():
    """Bursty traffic violates in a MINORITY of windows; averaging them
    with the calm majority would declare the count feasible. The
    per-count aggregate must be a tail over windows."""
    sched = DivideAndSaveScheduler([1, 2, 3],
                                   objective="energy_under_slo",
                                   slo_ttfc_p95_s=0.5, epsilon=0.0)
    # n=1: 7 calm windows + 3 burst windows far over target -> mean
    # would be ~0.66 but > 20% of windows violate: must read as blown
    for q in [0.1] * 7 + [2.0] * 3:
        sched.observe(1, 1.0, 5.0, ttfc_p95_s=q)
    for _ in range(10):
        sched.observe(2, 1.0, 6.0, ttfc_p95_s=0.2)
    for _ in range(10):
        sched.observe(3, 1.0, 7.0, ttfc_p95_s=0.2)
    assert sched.predict_ttfc_p95(1) > 0.5
    assert sched.pick() == 2


def test_quantile_tail_tolerates_rare_bad_window():
    """...but ONE loss-censored burst window in ten must not brand an
    otherwise-attaining count infeasible forever (TAIL_FRAC, not max)."""
    sched = DivideAndSaveScheduler([1, 2], objective="energy_under_slo",
                                   slo_ttfc_p95_s=0.5, epsilon=0.0)
    vals = [0.2] * 9 + [2.0]
    assert sched._tail_of(vals) <= 0.5


def test_quantile_prediction_none_before_samples():
    sched = DivideAndSaveScheduler([1, 2], objective="energy_under_slo",
                                   slo_ttfc_p95_s=0.5, epsilon=0.0)
    sched.observe(1, 1.0, 5.0)          # mean-only observation
    assert sched.predict_ttfc_p95(1) is None
    sched.observe(1, 1.0, 5.0, ttfc_p95_s=0.3)
    assert sched.predict_ttfc_p95(1) == pytest.approx(0.3)


def test_persistent_exploration_revisits_known_counts():
    """With epsilon > 0 the scheduler keeps re-sampling VISITED counts:
    per-window cost depends on the traffic phase a count happened to
    serve, and means de-bias only through revisits."""
    import collections
    sched = DivideAndSaveScheduler([1, 2, 3], objective="energy",
                                   epsilon=0.5, seed=0)
    for n in (1, 2, 3):
        for _ in range(3):
            sched.observe(n, 1.0 + n * 0.1, 5.0 + n)
    picks = collections.Counter(sched.pick() for _ in range(200))
    assert len(picks) == 3          # every count still gets explored
    assert picks[1] > 100           # ...while the argmin dominates
