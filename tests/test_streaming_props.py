"""Property test: streaming is semantically invisible.

For any request mix (ragged prompt lengths, ragged budgets, any
submit-order interleaving with pump points) the concatenation of a
handle's streamed ``ChunkEvent`` tokens bit-matches the blocking
``run()`` output for greedy decode, across ≥3 model families. Hypothesis
drives the request shapes; the engines share one jit cache per family
(session fixture), so examples reuse compiled executables.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.serving import Request, Router, ServingEngine, ThreadBackend

FAMILIES = ["qwen3-0.6b", "gemma3-27b", "mamba2-2.7b"]

# prompt lengths stay inside the first admission bucket and budgets small
# so every drawn example reuses the same compiled prefill/chunk shapes
request_shape = st.tuples(st.integers(3, 14),      # prompt_len
                          st.integers(0, 5))       # max_new_tokens
request_sets = st.lists(request_shape, min_size=1, max_size=6)


@pytest.fixture(scope="module")
def family_models(reduced_models):
    return {arch: reduced_models[arch] for arch in FAMILIES}


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(shapes=request_sets, data=st.data())
@pytest.mark.parametrize("arch", FAMILIES)
def test_stream_concat_equals_blocking_run(arch, family_models, shapes,
                                           data):
    model, params = family_models[arch]
    rng = np.random.default_rng(hash(tuple(shapes)) % (2**32))

    def make():
        return [Request(rid=i,
                        prompt=rng_states[i].copy(),
                        max_new_tokens=mn)
                for i, (_, mn) in enumerate(shapes)]

    rng_states = [rng.integers(0, model.cfg.vocab_size, (plen,),
                               dtype=np.int32)
                  for plen, _ in shapes]

    eng = ServingEngine(model, params, n_slots=2, max_len=64)
    eng.submit_many(make())
    want = {c.rid: list(c.tokens) for c in eng.run()}

    with Router(ThreadBackend(model, params, 2, n_slots_per_container=2,
                              max_len=64)) as router:
        handles = []
        for req in make():
            handles.append(router.submit(req))
            # random interleaving: sometimes let decoding progress
            # between admissions (continuous batching mid-stream)
            if data.draw(st.booleans()):
                router.poll()
        got = {}
        for h in handles:
            evs = list(h.stream())
            got[h.rid] = [t for ev in evs[:-1] for t in ev.tokens]
            assert got[h.rid] == list(evs[-1].completion.tokens)
    assert got == want
