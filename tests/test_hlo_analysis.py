"""While-aware HLO cost parser unit tests on a handcrafted post-SPMD-style
module (no jax devices needed)."""
from __future__ import annotations

import pytest

from repro.core.hlo_analysis import Collective, analyze_hlo

HLO = """\
HloModule test_module

%loop_cond (p.0: (s32[], f32[128,256])) -> pred[] {
  %p.0 = (s32[], f32[128,256]{1,0}) parameter(0)
  %gte = s32[] get-tuple-element(%p.0), index=0
  %trip = s32[] constant(12)
  ROOT %lt = pred[] compare(%gte, %trip), direction=LT
}

%loop_body (p.1: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p.1 = (s32[], f32[128,256]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p.1), index=0
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  %x = f32[128,256]{1,0} get-tuple-element(%p.1), index=1
  %w = f32[256,256]{1,0} constant({...})
  %mm = f32[128,256]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,256]{1,0} all-reduce(%mm), replica_groups=[16,16]<=[256], to_apply=%add_comp
  ROOT %t = (s32[], f32[128,256]) tuple(%ip, %ar)
}

%add_comp (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[128,256]) -> f32[128,256] {
  %arg = f32[128,256]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128,256]) tuple(%zero, %arg)
  %loop = (s32[], f32[128,256]) while(%init), condition=%loop_cond, body=%loop_body
  %res = f32[128,256]{1,0} get-tuple-element(%loop), index=1
  %ag = f32[128,4096]{1,0} all-gather(%res), replica_groups=[16,16]<=[256], dimensions={1}
  %red = f32[128]{0} reduce(%ag, %zero), dimensions={1}, to_apply=%add_comp
  ROOT %out = f32[128,256]{1,0} dynamic-slice(%ag, %zero, %zero), dynamic_slice_sizes={128,256}
}
"""


def test_trip_count_and_dot_flops():
    cost = analyze_hlo(HLO)
    assert cost.trip_counts["loop_body"] == 12.0
    # dot: 2 * 128*256 (result) * 256 (contraction) per iteration
    dot_flops = 2 * 128 * 256 * 256
    # reduce in entry: 2 * input elements (128*4096)
    red_flops = 2 * 128 * 4096
    assert cost.flops_per_chip == pytest.approx(12 * dot_flops + red_flops)


def test_collective_wire_bytes_scaled_by_trips():
    cost = analyze_hlo(HLO)
    ar_res = 128 * 256 * 4
    ar_wire = 2.0 * ar_res * 15 / 16 * 12        # in-loop, 12 trips
    ag_res = 128 * 4096 * 4
    ag_wire = ag_res * 15 / 16                   # entry, once
    assert cost.collectives["all-reduce"] == pytest.approx(ar_wire)
    assert cost.collectives["all-gather"] == pytest.approx(ag_wire)
    assert cost.coll_wire_bytes_per_chip == pytest.approx(ar_wire + ag_wire)


def test_dynamic_slice_counts_slice_only():
    cost = analyze_hlo(HLO)
    # entry bytes: all-gather result + reduce result + 2×slice (+dot ops are
    # in the loop). The 128×4096 gathered buffer must NOT be charged to the
    # dynamic-slice op.
    assert cost.bytes_per_chip < 12 * (3 * 128 * 256 * 4) + 4 * 128 * 4096 * 4


def test_participants_iota_format():
    c = Collective("all-gather", result_bytes=1000, participants=16)
    assert c.wire_bytes_per_chip == pytest.approx(1000 * 15 / 16)
    c = Collective("all-reduce", result_bytes=1000, participants=16)
    assert c.wire_bytes_per_chip == pytest.approx(2 * 1000 * 15 / 16)
    c = Collective("reduce-scatter", result_bytes=64, participants=16)
    assert c.wire_bytes_per_chip == pytest.approx(64 * 15)
    c = Collective("collective-permute", result_bytes=77, participants=2)
    assert c.wire_bytes_per_chip == 77.0


def test_no_entry_raises():
    with pytest.raises(ValueError):
        analyze_hlo("HloModule empty\n")


def test_nested_scan_multiplicities_multiply():
    """Nested lax.scan: the inner body's trip count must be outer × inner
    (the flash-attention q-block × kv-block pattern the roofline depends
    on)."""
    import jax
    import jax.numpy as jnp

    L_OUT, L_IN, N = 5, 3, 32

    def f(ws, x):
        def outer(c, w):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=L_IN)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    ws = jnp.zeros((L_OUT, N, N))
    x = jnp.zeros((4, N))
    txt = jax.jit(f).lower(ws, x).compile().as_text()
    cost = analyze_hlo(txt)
    want = L_OUT * L_IN * 2 * 4 * N * N
    assert cost.flops_per_chip == pytest.approx(want, rel=0.35), \
        (cost.flops_per_chip, want)
    assert max(cost.trip_counts.values()) == L_OUT * L_IN


def test_parser_on_real_lowered_module():
    """End-to-end: jit a scanned matmul on the single CPU device and check
    the parser finds the trip count and scales the in-loop dot."""
    import jax
    import jax.numpy as jnp

    L, N = 7, 64

    def f(ws, x):
        def step(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(step, x, ws)
        return y

    ws = jnp.zeros((L, N, N))
    x = jnp.zeros((8, N))
    txt = jax.jit(f).lower(ws, x).compile().as_text()
    cost = analyze_hlo(txt)
    want = L * 2 * 8 * N * N
    assert cost.flops_per_chip == pytest.approx(want, rel=0.35), \
        (cost.flops_per_chip, want)


# ---------------------------------------------------------------------------
# donation parsing (repro.analysis's donation auditor builds on this)
# ---------------------------------------------------------------------------
def test_parse_donation_inline_typed_operands():
    from repro.core.hlo_analysis import parse_donation
    text = (
        "module @jit_f {\n"
        "  func.func public @main("
        "%arg0: tensor<4x8xf32> {tf.aliasing_output = 0 : i32}, "
        "%arg1: tensor<4x8xf32> {tf.aliasing_output = 1 : i32, "
        "mhlo.layout_mode = \"default\"}, "
        "%arg2: tensor<4xi32>) -> (tensor<4x8xf32>, tensor<4x8xf32>) {\n"
        "    return %arg0, %arg1 : tensor<4x8xf32>, tensor<4x8xf32>\n"
        "  }\n"
        "}\n")
    info = parse_donation(text)
    assert info.aliased_outputs == (0, 1)
    assert info.buffer_donors == 0
    assert info.n_aliased == 2


def test_parse_donation_tuple_results_no_markers():
    from repro.core.hlo_analysis import parse_donation
    text = (
        "func.func public @main(%arg0: tensor<2xf32>) "
        "-> (tensor<2xf32>, tensor<2xf32>) {\n"
        "  return %arg0, %arg0 : tensor<2xf32>, tensor<2xf32>\n"
        "}\n")
    info = parse_donation(text)
    assert info.aliased_outputs == ()
    assert info.n_aliased == 0


def test_parse_donation_multi_device_buffer_donor():
    """Multi-device lowerings defer alias pairing to compile time and
    mark donated args ``jax.buffer_donor = true`` instead of
    ``tf.aliasing_output`` — both count as donated."""
    from repro.core.hlo_analysis import parse_donation
    text = (
        "func.func public @main("
        "%arg0: tensor<8x128xf32> {jax.buffer_donor = true, "
        "mhlo.sharding = \"{devices=[2,1]<=[2]}\"}, "
        "%arg1: tensor<8x128xf32> {tf.aliasing_output = 0 : i32}) "
        "-> (tensor<8x128xf32>, tensor<8x128xf32>) {\n"
        "  return %arg0, %arg1 : tensor<8x128xf32>, tensor<8x128xf32>\n"
        "}\n")
    info = parse_donation(text)
    assert info.aliased_outputs == (0,)
    assert info.buffer_donors == 1
    assert info.n_aliased == 2


def test_parse_donation_on_real_lowering():
    import jax
    import jax.numpy as jnp

    from repro.core.hlo_analysis import parse_donation
    buf = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    low = jax.jit(lambda b: b * 2.0, donate_argnums=0).lower(buf)
    info = parse_donation(low.as_text())
    assert info.n_aliased == 1

    low = jax.jit(lambda b: b * 2.0).lower(buf)   # undonated
    assert parse_donation(low.as_text()).n_aliased == 0
