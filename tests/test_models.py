"""Per-architecture smoke + consistency tests on the reduced configs.

Every assigned architecture: one forward (shape + finiteness), one train
step (params actually move, loss finite), prefill==forward at the last
position, and one decode step == full forward on S+1 tokens.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_NAMES, get_config
from repro.models.model import Model
from repro.train.loop import TrainConfig, make_train_step
from repro.train.optimizer import init_opt_state

from conftest import make_batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch, reduced_models):
    model, params = reduced_models[arch]
    cfg = model.cfg
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    logits, aux = model.forward(params, batch)
    S_out = S + (cfg.n_vision_tokens or 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_one_train_step(arch, reduced_models):
    model, params = reduced_models[arch]
    batch = make_batch(model.cfg, 2, 16)
    step = jax.jit(make_train_step(model, TrainConfig()))
    opt = init_opt_state(params)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # params moved (skip zero-size leaves — empty remainder stacks)
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32))))
        if a.size else 0.0,
        params, new_params)
    assert max(jax.tree.leaves(moved)) > 0
    assert int(new_opt["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_matches_forward(arch, reduced_models):
    model, params = reduced_models[arch]
    B, S, ML = 2, 16, 64
    batch = make_batch(model.cfg, B, S)
    full, _ = model.forward(params, batch)
    cache = model.init_cache(B, ML)
    lg, _ = model.prefill(params, batch, cache, logits_at=-1)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_forward(arch, reduced_models):
    model, params = reduced_models[arch]
    cfg = model.cfg
    B, S, ML = 2, 16, 64
    key = jax.random.PRNGKey(7)
    toks = jax.random.randint(key, (B, S + 2), 0, cfg.vocab_size)
    batch = make_batch(cfg, B, S)
    batch["tokens"] = toks[:, :S]
    cache = model.init_cache(B, ML)
    _, cache = model.prefill(params, batch, cache, logits_at=-1)
    nv = cfg.n_vision_tokens or 0
    # two decode steps, compare the second against the full forward
    lg = None
    for t in range(2):
        pos = jnp.full((B,), nv + S + t, jnp.int32)
        lg, cache = model.decode_step(params, toks[:, S + t:S + t + 1],
                                      cache, pos)
    batch_full = dict(batch, tokens=toks)
    full, _ = model.forward(params, batch_full)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]),
                               atol=2e-4, rtol=2e-4)


def test_sliding_window_cache_matches_full_history():
    """Ring-buffer decode with window W must equal full attention masked to
    the window (gemma3-style local layer)."""
    cfg = get_config("gemma3-27b-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 80
    W = cfg.sliding_window
    assert W < S, "test requires history longer than the window"
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 4), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    # max_len covers the full history (the GLOBAL layer's cache needs it);
    # the local layers' ring stays at the 64-token window
    cache = model.init_cache(B, 128)
    _, cache = model.prefill(params, batch, cache, logits_at=-1)
    lg = None
    for t in range(4):
        pos = jnp.full((B,), S + t, jnp.int32)
        lg, cache = model.decode_step(params, toks[:, S + t:S + t + 1],
                                      cache, pos)
    full, _ = model.forward(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]),
                               atol=2e-4, rtol=2e-4)


def test_vlm_prefix_changes_logits():
    cfg = get_config("internvl2-26b-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 1, 8)
    l1, _ = model.forward(params, batch)
    batch2 = dict(batch,
                  vision_embeds=batch["vision_embeds"] * 0.0)
    l2, _ = model.forward(params, batch2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-6


def test_whisper_encoder_memory_changes_logits():
    cfg = get_config("whisper-large-v3-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 1, 8)
    l1, _ = model.forward(params, batch)
    batch2 = dict(batch, audio_frames=batch["audio_frames"] * -1.0)
    l2, _ = model.forward(params, batch2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-6


def test_remat_matches_no_remat():
    cfg = get_config("qwen3-0.6b-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 16)
    l1, _ = model.loss(params, batch, remat=False)
    l2, _ = model.loss(params, batch, remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_count_matches_init(arch):
    """The analytic param_count used by the roofline must match the real
    initialised tree within 2% (vocab rounding etc.)."""
    cfg = get_config(arch + "-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    n_analytic = cfg.param_count()
    assert abs(n_real - n_analytic) / n_real < 0.02, (n_real, n_analytic)
