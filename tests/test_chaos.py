"""Chaos suite: every injected fault ends in a correct completion or a
typed failure — never a hang, a stuck CompletionHandle, leaked paged
blocks, or an orphaned child process.

The faults come from the test-only ``FaultPlan`` harness
(serving/faults.py): containers are killed mid-stream, engines raise,
reply pipes drop messages, block allocation is refused. The assertions
are the fault-tolerance contract of ISSUE 7:

* requests lost with a container are retried (``RetryEvent``) and
  complete *bit-correct* on the survivor/respawn, or fail typed
  (``RequestFailed``) once retries/containers run out;
* deadlines cut through silent containers (router backstop) and free
  paged blocks with exact conservation;
* overload sheds (``RequestRejected``) instead of queueing unboundedly;
* process children always exit with a classified nonzero code and are
  reaped — ``close()`` leaves no live descendants.
"""
from __future__ import annotations

import multiprocessing as mp
import time

import numpy as np
import pytest

from repro.serving import (Completion, ContainerFailure, DoneEvent,
                           EngineConfig, FailedEvent, Fault, FaultPlan,
                           RejectedEvent, Request, RequestFailed,
                           RequestRejected, RetryEvent, Router)
from repro.serving.backend import ProcessBackend, ThreadBackend
from repro.serving.engine import ServingEngine
from repro.serving.faults import (EXIT_FAULT_KILL, EXIT_STEP_ERROR,
                                  FaultInjector, InjectedFault,
                                  describe_exitcode)

pytestmark = pytest.mark.filterwarnings(
    "ignore::DeprecationWarning")


def _requests(cfg, plens_max_new, seed=0, deadline_s=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, (plen,),
                                        dtype=np.int32),
                    max_new_tokens=mn, deadline_s=deadline_s)
            for i, (plen, mn) in enumerate(plens_max_new)]


def _clone(reqs):
    return [Request(r.rid, r.prompt.copy(), r.max_new_tokens,
                    deadline_s=r.deadline_s) for r in reqs]


def _blocking_tokens(model, params, reqs):
    eng = ServingEngine(model, params,
                        EngineConfig(n_slots=2, max_len=64))
    eng.submit_many(_clone(reqs))
    return {c.rid: list(c.tokens) for c in eng.run()}


def _paged_conserved(engine) -> bool:
    cb = engine.cache_backend
    return (cb.allocator.n_free + cb.n_live_blocks
            == cb.layout.max_blocks)


# ---------------------------------------------------------------------------
# harness unit tests (no engines)
# ---------------------------------------------------------------------------
def test_fault_plan_scopes_by_container_and_incarnation():
    plan = FaultPlan((Fault("kill", container_id=0, after_steps=2),
                      Fault("error", container_id=1, incarnation=None),
                      Fault("drop_replies", container_id=0,
                            incarnation=1, count=3)))
    assert len(plan.for_container(0, 0)) == 1          # kill only
    assert len(plan.for_container(0, 1)) == 1          # drop only
    assert len(plan.for_container(1, 0)) == 1          # error, any inc
    assert len(plan.for_container(1, 5)) == 1
    assert plan.for_container(2, 0) == ()


def test_fault_injector_kill_fires_after_threshold():
    plan = FaultPlan((Fault("kill", container_id=0, after_steps=2),))
    inj = FaultInjector(plan, 0, 0)
    assert inj.armed
    inj.on_step(1)
    inj.on_step(2)
    with pytest.raises(InjectedFault) as ei:
        inj.on_step(3)
    assert ei.value.fault.kind == "kill"
    # incarnation 1 is out of scope: unarmed, hooks are no-ops
    inj1 = FaultInjector(plan, 0, 1)
    assert not inj1.armed
    inj1.on_step(99)


def test_fault_injector_counted_hooks_drain():
    plan = FaultPlan((Fault("drop_replies", container_id=0, count=2),
                      Fault("delay_replies", container_id=0, count=1,
                            delay_s=0.25),
                      Fault("refuse_blocks", container_id=0, count=3)))
    inj = FaultInjector(plan, 0, 0)
    assert [inj.drop_reply() for _ in range(4)] == [True, True,
                                                   False, False]
    assert inj.reply_delay() == 0.25
    assert inj.reply_delay() == 0.0
    assert [inj.refuse_alloc() for _ in range(5)] == [True, True, True,
                                                      False, False]


def test_unknown_fault_kind_rejected():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("segfault", container_id=0)


def test_describe_exitcode():
    assert "injected fault kill" in describe_exitcode(EXIT_FAULT_KILL)
    assert "engine step error" in describe_exitcode(EXIT_STEP_ERROR)
    assert "signal 9" in describe_exitcode(-9)
    assert "unknown" in describe_exitcode(None)


# ---------------------------------------------------------------------------
# thread backend: kill / respawn / retry / circuit breaker
# ---------------------------------------------------------------------------
def test_thread_kill_midstream_retries_bitcorrect(reduced_models):
    """Kill container 0 (incarnation 0 only) mid-stream: its in-flight
    requests ride a RetryEvent to a healthy home and every request's
    completion still bit-matches the blocking reference."""
    model, params = reduced_models["qwen3-0.6b"]
    cfg = model.cfg
    reqs = _requests(cfg, [(6, 4), (9, 4), (5, 4), (7, 4)], seed=5)
    want = _blocking_tokens(model, params, reqs)
    plan = FaultPlan((Fault("kill", container_id=0, after_steps=2),))
    # chunk_tokens=1: one token per macro-step, so the step-count fault
    # is guaranteed to fire while requests are still in flight (roofline
    # chunking could finish a 4-token request inside one step)
    config = EngineConfig(n_slots=2, max_len=64, chunk_tokens=1)
    backend = ThreadBackend(model, params, 2, config=config,
                            fault_plan=plan, max_respawns=2)
    with Router(backend, max_retries=2) as router:
        handles = [router.submit(r) for r in _clone(reqs)]
        events = {}
        for h in handles:
            events[h.rid] = list(h.stream())     # raises on any failure
        got = {h.rid: list(h.completion.tokens) for h in handles}
    assert got == want
    # the kill surfaced as exactly one typed container failure, its lost
    # requests were re-dispatched, and their post-retry chunk concat is
    # the completion (pre-retry chunks belong to the aborted attempt)
    assert len(router.container_failures) == 1
    fail = router.container_failures[0]
    assert fail.kind == "error" and fail.container_id == 0
    assert "injected fault: kill" in fail.message
    retried = set()
    for rid, evs in events.items():
        assert isinstance(evs[-1], DoneEvent)
        retries = [i for i, e in enumerate(evs)
                   if isinstance(e, RetryEvent)]
        if retries:
            retried.add(rid)
            tail = [t for e in evs[retries[-1] + 1:-1] for t in e.tokens]
            assert tail == got[rid]
    assert retried == set(fail.lost_rids)
    assert router.retry_total == len(fail.lost_rids) > 0
    assert backend.alive(0)                      # respawned, serving


def test_thread_circuit_breaker_trips_to_typed_failure(reduced_models):
    """A container that dies every incarnation exhausts its respawn
    budget; the request exhausts retries and fails typed — no hang."""
    model, params = reduced_models["qwen3-0.6b"]
    plan = FaultPlan((Fault("kill", container_id=0, incarnation=None),))
    backend = ThreadBackend(model, params, 1, n_slots_per_container=2,
                            max_len=64, fault_plan=plan, max_respawns=1)
    with Router(backend, max_retries=5) as router:
        h = router.submit(_requests(model.cfg, [(6, 4)], seed=7)[0])
        with pytest.raises(RequestFailed) as ei:
            h.result()
        assert ei.value.event.kind == "container"
        assert h.failure is not None and h.completion is None
        assert not backend.alive(0)
        # original + 1 respawn, both killed
        assert len(router.container_failures) == 2
        with pytest.raises(RuntimeError, match="circuit-broken"):
            backend.submit(0, _requests(model.cfg, [(5, 2)], seed=8)[0])
        with pytest.raises(RuntimeError, match="circuit-broken"):
            backend.drain()
        # a NEW submission sees no healthy container: fails typed at
        # admission instead of dispatching into the dead backend
        h2 = router.submit(_requests(model.cfg, [(5, 2)], seed=9)[0])
        with pytest.raises(RequestFailed, match="no healthy container"):
            h2.result()


def test_thread_refuse_blocks_stalls_then_serves(reduced_models):
    """Injected paged-pool exhaustion: admission stalls while the fault
    has budget, then the same requests admit and complete bit-correct;
    block conservation holds throughout."""
    model, params = reduced_models["qwen3-0.6b"]
    cfg = model.cfg
    reqs = _requests(cfg, [(6, 3), (9, 4), (5, 2)], seed=11)
    want = _blocking_tokens(model, params, reqs)
    plan = FaultPlan((Fault("refuse_blocks", container_id=0, count=4),))
    config = EngineConfig(n_slots=2, max_len=64, cache="paged",
                          block_size=8)
    backend = ThreadBackend(model, params, 1, config=config,
                            fault_plan=plan)
    with Router(backend) as router:
        handles = [router.submit(r) for r in _clone(reqs)]
        got = {h.rid: h.tokens() for h in handles}
        assert got == want
        assert _paged_conserved(backend.engines[0])


# ---------------------------------------------------------------------------
# deadlines / cancellation / shedding
# ---------------------------------------------------------------------------
def test_deadline_expiry_fails_typed_and_conserves_blocks(reduced_models):
    model, params = reduced_models["qwen3-0.6b"]
    cfg = model.cfg
    config = EngineConfig(n_slots=2, max_len=64, cache="paged",
                          block_size=8)
    backend = ThreadBackend(model, params, 1, config=config)
    with Router(backend, request_deadline_s=1e-4) as router:
        h = router.submit(_requests(cfg, [(6, 30)], seed=13)[0])
        with pytest.raises(RequestFailed) as ei:
            h.result()
        assert ei.value.event.kind == "deadline"
        assert isinstance(h.failure, FailedEvent)
        # the stack still serves: an undeadlined request admits into the
        # freed blocks and completes
        ok = Request(rid=100, prompt=_requests(cfg, [(6, 3)],
                                               seed=13)[0].prompt,
                     max_new_tokens=3)
        assert len(router.submit(ok).tokens()) == 3
        eng = backend.engines[0]
        assert _paged_conserved(eng)
        assert not eng.has_work                 # nothing stuck in a slot


def test_mid_decode_deadline_frees_slot(reduced_models):
    """A deadline that lands mid-decode (not queued) frees the slot and
    emits the typed failure with progress in the reason."""
    model, params = reduced_models["qwen3-0.6b"]
    cfg = model.cfg
    backend = ThreadBackend(model, params, 1, n_slots_per_container=2,
                            max_len=64)
    # a huge grace keeps the router backstop out of the race: the first
    # step (admit + compile) can exceed deadline+grace on a cold process,
    # and the backstop would then cancel before the ENGINE's own expiry —
    # the path under test here — ever gets to emit its typed failure
    with Router(backend, deadline_grace_s=60.0) as router:
        h = router.submit(Request(rid=0,
                                  prompt=np.arange(6, dtype=np.int32),
                                  max_new_tokens=500, deadline_s=0.35))
        router.poll()                            # admit + first chunk
        with pytest.raises(RequestFailed) as ei:
            h.result()
        assert ei.value.event.kind == "deadline"
        assert "mid-decode" in ei.value.event.reason
        assert not backend.engines[0].has_work


def test_router_cancel_frees_resources(reduced_models):
    model, params = reduced_models["qwen3-0.6b"]
    cfg = model.cfg
    backend = ThreadBackend(model, params, 1, n_slots_per_container=2,
                            max_len=64)
    with Router(backend) as router:
        h = router.submit(Request(rid=0,
                                  prompt=np.arange(6, dtype=np.int32),
                                  max_new_tokens=500))
        router.poll()                            # mid-decode
        assert router.cancel(0, "user went away")
        assert not router.cancel(0)              # already gone
        with pytest.raises(RequestFailed) as ei:
            h.result()
        assert ei.value.event.kind == "cancelled"
        assert not backend.engines[0].has_work   # slot actually freed
        # the freed slot serves the next request normally
        h1 = router.submit(_requests(cfg, [(6, 3)], seed=17)[0])
        assert len(h1.tokens()) == 3
    assert router.failed_total == 1          # the cancel, counted once


def test_max_queue_sheds_with_retry_after(reduced_models):
    model, params = reduced_models["qwen3-0.6b"]
    cfg = model.cfg
    backend = ThreadBackend(model, params, 1, n_slots_per_container=2,
                            max_len=64)
    reqs = _requests(cfg, [(6, 6), (7, 6), (5, 3)], seed=19)
    with Router(backend, max_queue=2) as router:
        keep = [router.submit(r) for r in reqs[:2]]
        shed = router.submit(reqs[2])
        evs = []
        with pytest.raises(RequestRejected) as ei:
            for ev in shed.stream():
                evs.append(ev)
        assert len(evs) == 1 and isinstance(evs[0], RejectedEvent)
        assert ei.value.event.retry_after_s > 0
        assert "queue full" in ei.value.event.reason
        assert router.shed_total == 1
        # shed request never reached a container; the admitted ones
        # complete untouched
        for h in keep:
            assert len(h.tokens()) == 6
        # queue drained: the SAME request admits now
        retry = Request(rid=99, prompt=reqs[2].prompt.copy(),
                        max_new_tokens=3)
        assert len(router.submit(retry).tokens()) == 3


def test_shed_p95_threshold_sheds_under_slow_ttfc(reduced_models):
    """Synthetic ttfc history over the shed threshold makes admission
    reject with the typed event (windowed tail shedding)."""
    model, params = reduced_models["qwen3-0.6b"]
    backend = ThreadBackend(model, params, 1, n_slots_per_container=2,
                            max_len=64)
    with Router(backend, shed_p95_s=0.5) as router:
        for _ in range(16):                      # observed slow tail
            router.note_ttfc(2.0)
        h = router.submit(_requests(model.cfg, [(6, 2)], seed=23)[0])
        with pytest.raises(RequestRejected, match="shed threshold"):
            h.result()
        assert router.shed_total == 1


def test_shed_p95_recovers_once_spike_leaves_window(reduced_models):
    """Burst → drain → admitted again: the shed-threshold ttfc sample is
    bounded by time, so a past overload spike stops tripping
    ``shed_p95_s`` once it ages past ``shed_window_s``. Pre-fix the
    sample never aged out and one burst shed traffic forever."""
    model, params = reduced_models["qwen3-0.6b"]
    backend = ThreadBackend(model, params, 1, n_slots_per_container=2,
                            max_len=64)
    with Router(backend, shed_p95_s=0.5, shed_window_s=0.25) as router:
        for _ in range(16):                 # the burst's slow tail
            router.note_ttfc(2.0)
        shed = router.submit(_requests(model.cfg, [(6, 2)], seed=23)[0])
        with pytest.raises(RequestRejected, match="shed threshold"):
            shed.result()
        assert router.shed_total == 1
        time.sleep(0.3)                     # spike leaves the window
        ok = router.submit(Request(rid=50, prompt=np.arange(
            6, dtype=np.int32), max_new_tokens=2))
        assert len(ok.tokens()) == 2        # admitted and served
        assert router.shed_total == 1


# ---------------------------------------------------------------------------
# stale events from abandoned incarnations (scripted structural backend)
# ---------------------------------------------------------------------------
class _ScriptedBackend:
    """Structural backend replaying a poll() tape: stages the
    cross-incarnation races (a stale terminal arriving AFTER the request
    was re-homed by a retry) that real backends only produce under
    timing-dependent chaos. ``loads`` steer ``Router._dispatch``."""

    def __init__(self, capacity, tape):
        self.capacity = capacity
        self._tape = list(tape)
        self.submitted: list[tuple[int, int]] = []
        self._load = [0] * capacity

    def submit(self, cid, req):
        self.submitted.append((cid, req.rid))
        self._load[cid] += 1

    def poll(self):
        return self._tape.pop(0) if self._tape else []

    def load(self, cid):
        return self._load[cid]

    def stats(self, cid):
        return (0.0, 0)

    def cancel(self, cid, rid):
        pass

    def close(self):
        pass


def test_stale_terminal_after_retry_is_ignored_and_backstop_fires():
    """A request retried off a hung container must not be terminated by
    the old incarnation's late DoneEvent (wrong tokens, and it would pop
    the router backstop while the live incarnation still runs). With the
    new home silent, the re-armed backstop is what ends it — typed."""
    req = Request(rid=7, prompt=np.arange(6, dtype=np.int32),
                  max_new_tokens=4, deadline_s=0.2)
    stale = DoneEvent(7, 0, Completion(7, [1, 2, 3, 4], 6, 0.01), 0.0)
    tape = [
        [ContainerFailure(0, "hung", "heartbeat timeout", 0.0,
                          lost_rids=(7,))],
        [stale],                       # container 0 wakes up too late
    ]
    backend = _ScriptedBackend(2, tape)
    with Router(backend, deadline_grace_s=0.1, max_retries=2) as router:
        h = router.submit(req)
        assert backend.submitted == [(0, 7)]
        router.poll()                  # failure -> retry, re-homed to c1
        assert backend.submitted[-1] == (1, 7)
        router.poll()                  # stale DoneEvent from container 0
        assert h.completion is None, (
            "aborted incarnation's completion leaked into the retried "
            "stream")
        with pytest.raises(RequestFailed) as ei:
            h.result()                 # c1 stays silent: backstop fires
        assert ei.value.event.kind == "deadline"
        assert "backstop" in ei.value.event.reason
        assert h.attempts == 1


# ---------------------------------------------------------------------------
# process backend chaos (slow: real spawns)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_process_kill_child_respawns_and_recovers(reduced_models):
    """Kill 1 of n=2 pinned children mid-stream: all in-flight requests
    complete bit-correct (survivor or respawn), the failure is typed
    with the injected exitcode, and close() leaves no orphans."""
    model, params = reduced_models["qwen3-0.6b"]
    cfg = model.cfg
    reqs = _requests(cfg, [(6, 4), (9, 4), (5, 4), (7, 4)], seed=29)
    want = _blocking_tokens(model, params, reqs)
    plan = FaultPlan((Fault("kill", container_id=0, after_steps=1),))
    # chunk_tokens=1 (see the thread kill test): the step-count fault
    # must land while requests are in flight
    backend = ProcessBackend(cfg, 2, n_slots_per_container=2, max_len=64,
                             params_seed=0, allow_shared_cores=True,
                             chunk_tokens=1, fault_plan=plan,
                             max_respawns=2, respawn_backoff_s=0.05)
    t_fail = t_recover = None
    with Router(backend, max_retries=2) as router:
        handles = [router.submit(r) for r in _clone(reqs)]
        got, events = {}, {}
        for h in handles:
            events[h.rid] = list(h.stream())
            got[h.rid] = list(h.completion.tokens)
        assert got == want
        fails = [f for f in router.container_failures if f.kind == "dead"]
        assert len(fails) == 1
        assert fails[0].exitcode == EXIT_FAULT_KILL
        assert "injected fault kill" in fails[0].message
        assert set(fails[0].lost_rids) == {
            rid for rid, evs in events.items()
            if any(isinstance(e, RetryEvent) for e in evs)}
        t_fail = fails[0].time_s
        # the respawn must come back: pump until container 0 serves again
        deadline = time.perf_counter() + 120
        while not backend.alive(0):
            assert time.perf_counter() < deadline, "respawn never landed"
            router.poll()
            time.sleep(0.05)
        t_recover = time.perf_counter()
        # ... and serve bit-correct on incarnation 1 (fault was inc-0)
        again = Request(rid=50, prompt=reqs[0].prompt.copy(),
                        max_new_tokens=4)
        backend.submit(0, again)
        done = {}
        deadline = time.perf_counter() + 120
        while 50 not in done:
            assert time.perf_counter() < deadline, "respawn never served"
            for ev in backend.poll():
                if isinstance(ev, DoneEvent):
                    done[ev.rid] = list(ev.completion.tokens)
            time.sleep(0.01)
        assert done[50] == want[0]
    assert t_recover - t_fail < 120
    # no orphaned processes: every child (including the respawn) reaped
    for p in mp.active_children():
        p.join(timeout=10)
    assert mp.active_children() == []


@pytest.mark.slow
def test_process_drop_replies_caught_by_deadline_backstop(reduced_models):
    """A child that silently swallows every reply (message loss) cannot
    hang the stream: heartbeats keep it 'alive', but the router-side
    deadline backstop cancels and fails the request typed."""
    model, params = reduced_models["qwen3-0.6b"]
    cfg = model.cfg
    plan = FaultPlan((Fault("drop_replies", container_id=0, count=-1),))
    backend = ProcessBackend(cfg, 1, n_slots_per_container=2, max_len=64,
                             params_seed=0, allow_shared_cores=True,
                             fault_plan=plan, max_respawns=0)
    with Router(backend, request_deadline_s=2.0,
                deadline_grace_s=0.5, max_retries=0) as router:
        h = router.submit(_requests(cfg, [(6, 400)], seed=31)[0])
        t0 = time.perf_counter()
        with pytest.raises(RequestFailed) as ei:
            h.result()
        assert ei.value.event.kind == "deadline"
        assert "backstop" in ei.value.event.reason
        assert time.perf_counter() - t0 < 60
    for p in mp.active_children():
        p.join(timeout=10)
    assert mp.active_children() == []


@pytest.mark.slow
def test_process_retry_onto_drop_replies_hits_backstop(reduced_models):
    """Kill the first incarnation's container so the request is retried
    onto a container that silently drops every reply: the router-side
    backstop must stay armed across the re-dispatch and end the retried
    incarnation typed — never a hang."""
    model, params = reduced_models["qwen3-0.6b"]
    cfg = model.cfg
    plan = FaultPlan((Fault("kill", container_id=0, after_steps=1),
                      Fault("drop_replies", container_id=1, count=-1)))
    backend = ProcessBackend(cfg, 2, n_slots_per_container=2, max_len=64,
                             params_seed=0, allow_shared_cores=True,
                             chunk_tokens=1, fault_plan=plan,
                             max_respawns=0)
    # the deadline must outlive child spawn + prefill compile + the kill
    # -> retry hop, or the backstop fires on the FIRST incarnation and
    # the test stops exercising the re-dispatch path it is pinning
    with Router(backend, request_deadline_s=30.0, deadline_grace_s=1.0,
                max_retries=2) as router:
        h = router.submit(_requests(cfg, [(6, 400)], seed=41)[0])
        t0 = time.perf_counter()
        with pytest.raises(RequestFailed) as ei:
            h.result()
        assert h.attempts == 1                   # it WAS re-dispatched
        assert ei.value.event.kind == "deadline"
        assert "backstop" in ei.value.event.reason
        assert time.perf_counter() - t0 < 120
    for p in mp.active_children():
        p.join(timeout=10)
    assert mp.active_children() == []


@pytest.mark.slow
def test_process_step_error_reports_classified_exit(reduced_models):
    """An engine error in the child crosses the pipe as a typed 'error'
    failure (traceback included) and the child exits nonzero — no more
    silent exit-0 sharing with clean shutdown."""
    model, params = reduced_models["qwen3-0.6b"]
    cfg = model.cfg
    plan = FaultPlan((Fault("error", container_id=0),))
    backend = ProcessBackend(cfg, 1, n_slots_per_container=2, max_len=64,
                             params_seed=0, allow_shared_cores=True,
                             fault_plan=plan, max_respawns=0)
    with Router(backend, max_retries=0) as router:
        h = router.submit(_requests(cfg, [(6, 4)], seed=37)[0])
        with pytest.raises(RequestFailed, match="injected fault: error"):
            h.result()
        fails = router.container_failures
        assert fails and fails[0].kind == "error"
        assert not backend.alive(0)              # max_respawns=0: broken
        # the child's own exit is classified, observable once reaped
        deadline = time.perf_counter() + 30
        while mp.active_children() and time.perf_counter() < deadline:
            time.sleep(0.05)
    for p in mp.active_children():
        p.join(timeout=10)
    assert mp.active_children() == []
