"""Adaptive serving pool: the online scheduler loop closed over the
container pool (paper's concluding proposal, end-to-end)."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.containers import feasible_counts
from repro.models.model import Model
from repro.serving import (AdaptiveServingPool, Request,
                           SyntheticContainerPool, synthetic_pool_factory)


def _convex_time(n):
    return 1.0 / n + 0.02 * n * n          # argmin over {1,2,4,8} at n=4


def _energy(n):
    return _convex_time(n) * (40.0 + 7.0 * n)   # argmin at n=2


def test_adaptive_converges_to_time_argmin_within_8_waves():
    apool = AdaptiveServingPool(
        None, None, [1, 2, 4, 8], objective="time",
        pool_factory=synthetic_pool_factory(_convex_time, _energy))
    for _ in range(8):
        apool.serve_wave([])
    assert apool.choice == 4
    assert apool.scheduler.n_observations == 8
    assert all(w.n_containers in (1, 2, 4, 8) for w in apool.history)


def test_adaptive_converges_to_energy_argmin():
    apool = AdaptiveServingPool(
        None, None, [1, 2, 4, 8], objective="energy",
        pool_factory=synthetic_pool_factory(_convex_time, _energy))
    for _ in range(8):
        apool.serve_wave([])
    assert apool.choice == min((1, 2, 4, 8), key=_energy) == 2


def test_adaptive_reuses_pools_per_count():
    built = []

    def factory(n):
        built.append(n)
        return SyntheticContainerPool(n, _convex_time, _energy)

    apool = AdaptiveServingPool(None, None, [1, 2, 4],
                                objective="time", pool_factory=factory)
    for _ in range(6):
        apool.serve_wave([])
    # once converged, waves reuse the cached pool: one build per count seen
    assert len(built) == len(set(built))


def test_max_cached_pools_evicts_lru():
    """Each cached pool pins placed param replicas; ``max_cached_pools``
    LRU-bounds that. Eviction drops the stalest count, and re-probing it
    later rebuilds (one fresh placement) instead of growing without
    bound."""
    built = []

    def factory(n):
        built.append(n)
        return SyntheticContainerPool(n, _convex_time, _energy)

    sched_picks = [1, 2, 4, 2, 1]          # 4 evicts 1; reprobe of 1 rebuilds

    class FixedScheduler:
        n_observations = 0

        def pick(self):
            return sched_picks[FixedScheduler.n_observations]

        def observe(self, n, t, e):
            FixedScheduler.n_observations += 1

    apool = AdaptiveServingPool(None, None, [1, 2, 4],
                                scheduler=FixedScheduler(),
                                pool_factory=factory, max_cached_pools=2)
    for _ in sched_picks:
        apool.serve_wave([])
    assert built == [1, 2, 4, 1]           # 2 stayed cached (LRU refresh)
    assert set(apool._pools) == {2, 1}     # 4 was the LRU at the last miss


def test_evicted_pools_are_closed():
    """A pool dropped by the LRU bound (or by close()) must have its
    close() called — for process isolation that is what shuts the warm
    child processes down instead of leaking them."""
    closed = []

    class ClosingPool(SyntheticContainerPool):
        def close(self):
            closed.append(self.n_containers)

    sched_picks = [1, 2, 4]

    class FixedScheduler:
        n_observations = 0

        def pick(self):
            return sched_picks[FixedScheduler.n_observations]

        def observe(self, n, t, e):
            FixedScheduler.n_observations += 1

    apool = AdaptiveServingPool(
        None, None, [1, 2, 4], scheduler=FixedScheduler(),
        pool_factory=lambda n: ClosingPool(n, _convex_time, _energy),
        max_cached_pools=2)
    for _ in sched_picks:
        apool.serve_wave([])
    assert closed == [1]                   # LRU eviction closed count 1
    apool.close()
    assert sorted(closed) == [1, 2, 4]     # close() drains the rest
    assert apool._pools == {}


def test_adaptive_wave_history_and_completions():
    apool = AdaptiveServingPool(
        None, None, [1, 2], objective="time",
        pool_factory=synthetic_pool_factory(_convex_time))
    reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=2) for i in range(5)]
    out = apool.serve_wave(list(reqs))
    assert [c.rid for c in out] == [0, 1, 2, 3, 4]
    w = apool.history[0]
    assert w.wave == 0 and w.n_requests == 5
    assert w.wall_s > 0 and w.energy_j > 0
    # synthetic completions are zero-latency echoes: percentiles present
    # on the WaveResult but degenerate
    assert w.latency_p50_s == w.latency_p95_s == 0.0


def test_requires_model_or_factory():
    with pytest.raises(ValueError):
        AdaptiveServingPool(None, None, [1, 2])


def test_submesh_counts_must_divide_devices():
    """Fail fast at construction: a feasible count that does not divide
    the submesh device pool would otherwise crash mid-serving the first
    time the scheduler probes it."""
    with pytest.raises(ValueError, match="do not divide"):
        AdaptiveServingPool(None, None, [1, 2, 4],
                            pool_factory=synthetic_pool_factory(_convex_time),
                            submesh_devices=6)


def test_feasible_counts_memory_bounded():
    """Big model on a 256-chip pod: low counts (weights sharded over many
    chips per container) fit; high counts (1 chip per container holding
    the full replica) do not — the paper's TX2 memory cap, pod-sized."""
    cfg = get_config("qwen3-8b")
    counts = feasible_counts(cfg, 256, hbm_bytes=16e9)
    assert counts, "some factorisation must fit"
    assert counts == sorted(counts)
    assert 1 in counts
    assert 256 not in counts               # 16 GB of weights on one chip
    # reduced config fits everywhere
    small = get_config("qwen3-0.6b-reduced")
    assert feasible_counts(small, 8) == [1, 2, 4, 8]


@pytest.mark.slow
def test_adaptive_real_model_smoke():
    """Three real waves over the reduced model: every wave returns all its
    requests in order and feeds the scheduler."""
    cfg = get_config("qwen3-0.6b-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    apool = AdaptiveServingPool(model, params, [1, 2],
                                objective="energy",
                                n_slots_per_container=2, max_len=64)
    for wave in range(3):
        reqs = [Request(rid=wave * 4 + i,
                        prompt=rng.integers(0, cfg.vocab_size, (6,),
                                            dtype=np.int32),
                        max_new_tokens=3) for i in range(4)]
        out = apool.serve_wave(reqs)
        assert [c.rid for c in out] == [r.rid for r in reqs]
    assert apool.scheduler.n_observations == 3
    assert apool.choice in (1, 2)
    # real waves have real tail latencies on the WaveResult
    assert all(0.0 < w.latency_p50_s <= w.latency_p95_s <= w.wall_s
               for w in apool.history)
