"""Property tests (hypothesis) for the workload splitter — the paper's
"divide" step. Invariants: combine∘split == identity, segment sizes differ
by at most one, segment count is exact."""
from __future__ import annotations

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import splitter


@given(st.lists(st.integers(), max_size=200), st.integers(1, 32))
@settings(max_examples=200, deadline=None)
def test_split_combine_roundtrip(items, n):
    segs = splitter.split(items, n)
    assert splitter.combine(segs) == list(items)
    assert len(segs) == n


@given(st.integers(0, 10_000), st.integers(1, 64))
@settings(max_examples=200, deadline=None)
def test_segment_sizes_maximally_equal(n_items, n_segments):
    sizes = splitter.segment_sizes(n_items, n_segments)
    assert sum(sizes) == n_items
    assert len(sizes) == n_segments
    assert max(sizes) - min(sizes) <= 1
    # paper: equal split — larger segments come first (deterministic order)
    assert sizes == sorted(sizes, reverse=True)


@given(st.integers(1, 97), st.integers(1, 12), st.integers(0, 3))
@settings(max_examples=100, deadline=None)
def test_split_array_roundtrip(n_frames, n_segments, extra_dims)  :
    shape = (n_frames,) + (2,) * extra_dims
    x = np.arange(np.prod(shape), dtype=np.float32).reshape(shape)
    parts = splitter.split_array(x, n_segments)
    assert len(parts) == n_segments
    y = splitter.combine_arrays(parts)
    np.testing.assert_array_equal(x, y)


def test_zero_segments_rejected():
    import pytest
    with pytest.raises(ValueError):
        splitter.segment_sizes(10, 0)
