"""Paged decode attention: the Pallas block-table kernel (interpret mode)
and the pure-jnp paged reference, against the dense decode oracle.

The load-bearing property is *bit*-parity of the reference: gathering
K/V through a block table whose unreserved entries point at garbage
pages must produce the exact bits of dense decode attention — masked
lanes contribute an exact ``0.0`` to the flash accumulator (see
kernels/ref.paged_decode_attention), which is what lets the paged
serving engine bit-match the dense baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.paged_attention import (paged_decode_attention,
                                           paged_decode_attention_int8)

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16] if dtype == jnp.bfloat16 else TOL[jnp.float32]


def _paged_case(key, B, H, Hkv, K, bs, nblk, n_pages, dtype,
                unique_pages=False):
    """Random q + page pool + block table + ragged lengths. Unreserved /
    beyond-length page contents are random garbage by construction."""
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, K)).astype(dtype)
    k_pages = jax.random.normal(
        ks[1], (n_pages + 1, bs, Hkv, K)).astype(dtype)
    v_pages = jax.random.normal(
        ks[2], (n_pages + 1, bs, Hkv, K)).astype(dtype)
    if unique_pages:
        assert n_pages >= B * nblk
        perm = jax.random.permutation(ks[3], n_pages)[:B * nblk]
        table = perm.reshape(B, nblk).astype(jnp.int32)
    else:
        table = jax.random.randint(ks[3], (B, nblk), 0, n_pages, jnp.int32)
    lengths = jax.random.randint(ks[4], (B,), 1, bs * nblk + 1, jnp.int32)
    return q, k_pages, v_pages, table, lengths


@pytest.mark.parametrize("B,H,Hkv,K,bs,nblk", [
    (2, 4, 4, 64, 16, 4),     # MHA
    (3, 4, 2, 64, 16, 4),     # GQA
    (2, 8, 2, 32, 8, 6),      # small pages, more groups
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_kernel_matches_ref(B, H, Hkv, K, bs, nblk, dtype):
    q, kp, vp, table, lengths = _paged_case(
        jax.random.PRNGKey(0), B, H, Hkv, K, bs, nblk, 32, dtype)
    got = paged_decode_attention(q, kp, vp, table, lengths, interpret=True)
    want = ref.paged_decode_attention(q, kp, vp, table, lengths)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_paged_kernel_softcap():
    q, kp, vp, table, lengths = _paged_case(
        jax.random.PRNGKey(1), 2, 4, 2, 64, 16, 4, 32, jnp.float32)
    got = paged_decode_attention(q, kp, vp, table, lengths, softcap=30.0,
                                 interpret=True)
    want = ref.paged_decode_attention(q, kp, vp, table, lengths,
                                      softcap=30.0)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_paged_kernel_int8():
    """int8 pages with per-(token, kv head) absmax scales, dequant inside
    the kernel grid, vs the paged reference's gather-then-dequant."""
    key = jax.random.PRNGKey(2)
    B, H, Hkv, K, bs, nblk, P = 2, 4, 2, 64, 16, 4, 32
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, K), jnp.float32)
    kf = jax.random.normal(ks[1], (P + 1, bs, Hkv, K), jnp.float32)
    vf = jax.random.normal(ks[2], (P + 1, bs, Hkv, K), jnp.float32)

    def quant(x):
        scale = jnp.max(jnp.abs(x), axis=-1) / 127.0
        qx = jnp.round(x / scale[..., None]).astype(jnp.int8)
        return qx, scale
    kq, ksc = quant(kf)
    vq, vsc = quant(vf)
    table = jax.random.randint(ks[3], (B, nblk), 0, P, jnp.int32)
    lengths = jax.random.randint(ks[4], (B,), 1, bs * nblk + 1, jnp.int32)
    got = paged_decode_attention_int8(q, kq, vq, ksc, vsc, table, lengths,
                                      interpret=True)
    want = ref.paged_decode_attention(q, kq, vq, table, lengths,
                                      k_scale_pages=ksc, v_scale_pages=vsc)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_paged_ref_bitwise_matches_dense_gather():
    """BIT-parity of the reference: scatter a dense K/V into disjoint
    pages, leave every unreserved page as garbage — the paged path must
    produce the exact bits of dense decode attention over the gathered
    context, garbage and all."""
    key = jax.random.PRNGKey(3)
    B, H, Hkv, K, bs, nblk, P = 3, 4, 2, 32, 16, 4, 16
    W = bs * nblk
    q, kp, vp, table, lengths = _paged_case(
        key, B, H, Hkv, K, bs, nblk, P, jnp.float32, unique_pages=True)
    # dense view: the exact tokens the table points at
    k = kp[table].reshape(B, W, Hkv, K)
    v = vp[table].reshape(B, W, Hkv, K)
    valid = jnp.arange(W)[None, :] < lengths[:, None]
    got = ref.paged_decode_attention(q, kp, vp, table, lengths)
    want = ref.decode_attention_blocked(q, k, v, valid)
    assert jnp.array_equal(got, want), "paged reference is not bit-exact"


def test_paged_ref_ignores_garbage_pages():
    """Poisoning every page the tables don't reference (including the
    scratch page) must not change a single output bit."""
    key = jax.random.PRNGKey(4)
    B, H, Hkv, K, bs, nblk, P = 2, 4, 4, 32, 8, 4, 24
    q, kp, vp, table, lengths = _paged_case(
        key, B, H, Hkv, K, bs, nblk, P, jnp.float32, unique_pages=True)
    base = ref.paged_decode_attention(q, kp, vp, table, lengths)
    used = np.unique(np.asarray(table))
    poison = np.ones(P + 1, bool)
    poison[used] = False
    kp2 = jnp.where(jnp.asarray(poison)[:, None, None, None],
                    jnp.full_like(kp, 1e9), kp)
    vp2 = jnp.where(jnp.asarray(poison)[:, None, None, None],
                    jnp.full_like(vp, -1e9), vp)
    got = ref.paged_decode_attention(q, kp2, vp2, table, lengths)
    assert jnp.array_equal(got, base)
