"""MoE dispatch: dropless-capacity equivalence with the dense oracle,
capacity-drop semantics, aux-loss behaviour."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import moe as moe_lib


def _cfg(**over):
    base = get_config("mixtral-8x22b-reduced")
    return dataclasses.replace(base, **over) if over else base


def test_dropless_matches_dense_oracle():
    cfg = _cfg()          # reduced config sets eval_cf = E/K (dropless)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    got, _ = moe_lib.moe_fwd(p, cfg, x, train=False)
    want = moe_lib.moe_fwd_ref(p, cfg, x)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_shared_experts_added():
    cfg = _cfg(n_shared_experts=1)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    got, _ = moe_lib.moe_fwd(p, cfg, x)
    want = moe_lib.moe_fwd_ref(p, cfg, x)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_tiny_capacity_drops_tokens():
    """With capacity factor ~0 every token drops and the output is ~zero
    (plus shared experts if any — none here). One global dispatch group so
    the per-group capacity floor (4 rows) doesn't mask the drops."""
    cfg = _cfg(moe_eval_cf=1e-9, moe_dispatch_groups=1)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    got, _ = moe_lib.moe_fwd(p, cfg, x)
    # capacity floor is 4 rows/expert, so a few tokens survive; most drop
    frac_zero = float(jnp.mean(jnp.all(got == 0.0, axis=-1)))
    assert frac_zero > 0.5


def test_aux_loss_balanced_vs_collapsed():
    """A uniform router must score (near-)minimal aux loss; a collapsed
    router (all tokens to one expert) must score ~E times that."""
    cfg = _cfg()
    E = cfg.n_experts
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model))

    p_uniform = dict(p, router=jnp.zeros_like(p["router"]))
    _, aux_u = moe_lib.moe_fwd(p_uniform, cfg, x, train=True)

    # collapse: positive inputs + a one-column router → every token routes
    # its top-1 to expert 0 with probability ~1
    x_pos = jnp.abs(x) + 0.5
    collapsed = jnp.zeros_like(p["router"]).at[:, 0].set(100.0)
    p_col = dict(p, router=collapsed)
    _, aux_c = moe_lib.moe_fwd(p_col, cfg, x_pos, train=True)
    # Switch aux: uniform = K exactly; collapsed = E (me0=ce0=1) — the
    # E=4, K=2 reduced config gives a clean 2× separation
    assert float(aux_c) > 1.5 * float(aux_u)


def test_moe_grads_flow_to_experts():
    cfg = _cfg()
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))

    def loss(p):
        out, aux = moe_lib.moe_fwd(p, cfg, x, train=True)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    gnorm_experts = sum(float(jnp.sum(jnp.abs(l)))
                        for l in jax.tree.leaves(g["experts"]))
    gnorm_router = float(jnp.sum(jnp.abs(g["router"])))
    assert gnorm_experts > 0
    assert gnorm_router > 0
