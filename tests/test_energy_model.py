"""Energy/time/power model tests: fit recovery (property), paper-model
evaluation, and the calibrated edge-device simulators reproducing the
paper's headline savings (DESIGN.md table)."""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import energy_model as em


# ---------------------------------------------------------------------------
# fitting machinery
# ---------------------------------------------------------------------------
@given(st.tuples(st.floats(0.001, 0.1), st.floats(-0.5, -0.01),
                 st.floats(0.8, 1.5)))
@settings(max_examples=50, deadline=None)
def test_quadratic_fit_recovers_coefficients(coef):
    x = np.arange(1, 13, dtype=float)
    y = em.eval_model("quad", coef, x)
    fit = em.fit_quadratic(x, y)
    assert fit.rmse < 1e-8
    np.testing.assert_allclose(fit.coef, coef, rtol=1e-5, atol=1e-7)


def test_exponential_fit_recovers_curve():
    x = np.arange(1, 13, dtype=float)
    true = (0.33, 1.77, 0.98)
    y = em.eval_model("exp", true, x)
    fit = em.fit_exponential(x, y)
    pred = fit(x)
    np.testing.assert_allclose(pred, y, atol=5e-3)


def test_fit_best_picks_the_right_family():
    x = np.arange(1, 13, dtype=float)
    yq = em.eval_model("quad", (0.026, -0.21, 1.17), x)
    ye = em.eval_model("exp", (0.33, 1.77, 0.98), x)
    assert em.fit_best(x, yq).kind == "quad"
    assert em.fit_best(x, ye).kind == "exp"


def test_paper_models_normalised_near_one_at_benchmark():
    """Table II models are normalised to the 1-container benchmark: f(1)≈1
    (the paper's fits carry small residuals)."""
    for (dev, metric), (kind, coef) in em.PAPER_MODELS.items():
        v1 = float(em.eval_model(kind, coef, 1.0))
        assert 0.8 < v1 < 1.2, (dev, metric, v1)


def test_paper_model_argmin_matches_paper_conclusions():
    """TX2 time/energy minimise at ~4 containers; Orin keeps improving to
    12 (both per §VI)."""
    t_tx2 = em.FittedModel(*em.PAPER_MODELS[("tx2", "time")], rmse=0.0)
    e_tx2 = em.FittedModel(*em.PAPER_MODELS[("tx2", "energy")], rmse=0.0)
    assert t_tx2.argmin(6) == 4
    assert e_tx2.argmin(6) == 4
    t_orin = em.FittedModel(*em.PAPER_MODELS[("orin", "time")], rmse=0.0)
    assert t_orin.argmin(12) == 12


# ---------------------------------------------------------------------------
# calibrated edge-device simulators vs the paper's headline numbers
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("model_fn,ref_key", [(em.tx2_model, "tx2"),
                                              (em.orin_model, "orin")])
def test_device_model_reproduces_benchmark_refs(model_fn, ref_key):
    m = model_fn()
    ref = em.PAPER_REF[ref_key]
    assert abs(m.time(1) - ref["time_s"]) / ref["time_s"] < 0.10
    assert abs(m.energy(1) - ref["energy_j"]) / ref["energy_j"] < 0.10
    assert abs(m.power(1) - ref["power_w"]) / ref["power_w"] < 0.10


def test_tx2_model_savings_match_paper():
    """Paper §VI: TX2 2 containers → −19% time/−10% energy; 4 → −25%/−15%;
    beyond 4 degrades. Simulator must land within a few points."""
    m = em.tx2_model()
    t1, e1 = m.time(1), m.energy(1)
    dt2 = 1 - m.time(2) / t1
    de2 = 1 - m.energy(2) / e1
    dt4 = 1 - m.time(4) / t1
    de4 = 1 - m.energy(4) / e1
    assert abs(dt2 - 0.19) < 0.06, dt2
    assert abs(de2 - 0.10) < 0.06, de2
    assert abs(dt4 - 0.25) < 0.06, dt4
    assert abs(de4 - 0.15) < 0.06, de4
    assert m.time(6) > m.time(4)       # degradation past the core count
    assert m.energy(6) > m.energy(4)


def test_orin_model_savings_match_paper():
    """Orin: 2 → −43%/−25%; 4 → −62%/−40%; 12 → −70%/−43%; power +84% at
    12 containers."""
    m = em.orin_model()
    t1, e1, p1 = m.time(1), m.energy(1), m.power(1)
    assert abs((1 - m.time(2) / t1) - 0.43) < 0.08
    assert abs((1 - m.energy(2) / e1) - 0.25) < 0.08
    assert abs((1 - m.time(4) / t1) - 0.62) < 0.08
    assert abs((1 - m.energy(4) / e1) - 0.40) < 0.08
    assert abs((1 - m.time(12) / t1) - 0.70) < 0.08
    assert abs((1 - m.energy(12) / e1) - 0.43) < 0.08
    assert abs((m.power(12) / p1 - 1) - 0.84) < 0.25


def test_power_rises_while_energy_falls():
    """The paper's core trade-off: splitting raises average power (better
    utilisation) yet lowers energy (shorter runtime wins)."""
    for m in (em.tx2_model(), em.orin_model()):
        best = 4 if m.cores == 4 else 12
        assert m.power(best) > m.power(1)
        assert m.energy(best) < m.energy(1)
        assert m.time(best) < m.time(1)


def test_single_container_cores_sweep_flattens():
    """Fig. 1: adding cores to ONE container has diminishing returns."""
    m = em.tx2_model()
    t = [m.single_container_time(c) for c in (1, 2, 3, 4)]
    assert t[0] > t[1] > t[2] > t[3]
    gain_12 = t[0] - t[1]
    gain_34 = t[2] - t[3]
    assert gain_34 < 0.4 * gain_12


def test_fitted_forms_match_device_model_curves():
    """Fitting the simulator's samples recovers a convex model whose argmin
    agrees — the full scheduler pipeline in one assertion."""
    m = em.orin_model()
    xs = np.arange(1, 13, dtype=float)
    times = np.array([m.time(int(n)) for n in xs]) / m.time(1)
    fit = em.fit_best(xs, times)
    assert fit.rmse < 0.05
    assert fit.argmin(12) >= 8
