"""Core carve-up + pinned-container testbed.

``assign_core_sets`` is pure logic (no process spawn), so most of this is
fast; the end-to-end pinned-process run is marked ``slow``.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.core import testbed


def test_assign_core_sets_disjoint_equal_cover():
    sets = testbed.assign_core_sets(3, avail=range(8))
    assert len(sets) == 3
    assert all(len(s) == 2 for s in sets)          # 8 // 3 cores each
    seen = set()
    for s in sets:
        assert not (seen & s), "core sets overlap"
        seen |= s
    assert seen <= set(range(8))


def test_assign_core_sets_respects_total_cores():
    assert testbed.assign_core_sets(2, total_cores=2, avail=range(8)) == \
        [frozenset({0}), frozenset({1})]


def test_assign_core_sets_rejects_overflow():
    """Regression: the modulo wrap used to hand 'disjoint' containers
    shared cores silently — corrupting both the isolation claim and
    busy_core_seconds. Overflow must now be an explicit error."""
    with pytest.raises(ValueError, match="disjoint"):
        testbed.assign_core_sets(5, avail=range(4))
    with pytest.raises(ValueError):
        testbed.assign_core_sets(0, avail=range(4))


def test_assign_core_sets_shared_is_explicit_round_robin():
    sets = testbed.assign_core_sets(5, avail=range(2), allow_shared=True)
    assert len(sets) == 5 and all(len(s) == 1 for s in sets)
    assert set().union(*sets) == {0, 1}            # every core still used


def test_run_split_rejects_more_containers_than_cores():
    """The n > cores case, end-to-end: refused before any process spawns
    (allow_shared=True is the explicit fractional-share escape hatch)."""
    frames = testbed.make_video(4)
    cores = len(os.sched_getaffinity(0))
    with pytest.raises(ValueError, match="disjoint"):
        testbed.run_split(frames, cores + 1)


@pytest.mark.slow
def test_run_split_pinned_processes_match_single_container():
    """The refactored pinned-worker harness end-to-end: split outputs are
    combined in frame order and match the 1-container run; core sets were
    disjoint and busy accounting is sane."""
    cores = len(os.sched_getaffinity(0))
    if cores < 2:
        pytest.skip("needs 2 cores")
    frames = testbed.make_video(8)
    base = testbed.run_split(frames, 1, batch=4)
    split = testbed.run_split(frames, 2, batch=4)
    assert split.disjoint
    assert split.outputs.shape == base.outputs.shape
    np.testing.assert_allclose(split.outputs, base.outputs, atol=1e-5)
    assert split.busy_core_seconds > 0
    # busy core-seconds can never exceed what the assigned cores could
    # physically run (the allow_shared overcount regression)
    assert split.busy_core_seconds <= 2 * split.wall_s + 1e-6
    assert len(split.per_container_s) == 2
