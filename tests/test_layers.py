"""Layer-primitive properties: rotary embeddings, quantisation, norms,
sharding-constraint no-op behaviour."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.attention import _quant_kv
from repro.models.layers import (apply_rope, constrain, constrain_batch,
                                 layernorm_fwd, rmsnorm_fwd)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 32))
    pos = jnp.arange(8)[None, :]
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1), rtol=1e-5)


def test_rope_relative_position_invariance():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))

    def dot_at(i, j):
        qi = apply_rope(q, jnp.asarray([[i]]), 10_000.0)
        kj = apply_rope(k, jnp.asarray([[j]]), 10_000.0)
        return float(jnp.sum(qi * kj))

    assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-4)
    assert dot_at(0, 0) == pytest.approx(dot_at(7, 7), rel=1e-4)


def test_partial_rope_leaves_tail_untouched():
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 2, 64))
    pos = jnp.arange(4)[None, :]
    y = apply_rope(x, pos, 10_000.0, partial=0.25)
    rot = int(64 * 0.25)
    np.testing.assert_array_equal(np.asarray(x[..., rot:]),
                                  np.asarray(y[..., rot:]))
    assert float(jnp.max(jnp.abs(x[..., :rot] - y[..., :rot]))) > 0


# ---------------------------------------------------------------------------
# int8 KV quantisation
# ---------------------------------------------------------------------------
@given(st.integers(1, 4), st.integers(1, 8), st.floats(0.01, 100.0))
@settings(max_examples=30, deadline=None)
def test_quant_roundtrip_bounded_error(b, h, scale):
    x = scale * jax.random.normal(jax.random.PRNGKey(b * 13 + h), (b, h, 32))
    q, s = _quant_kv(x)
    deq = q.astype(jnp.float32) * s[..., None]
    # absmax int8: error per element ≤ scale = rowmax/127
    err = jnp.max(jnp.abs(deq - x), axis=-1)
    bound = jnp.max(jnp.abs(x), axis=-1) / 127.0 * 0.51
    assert bool(jnp.all(err <= bound + 1e-7))


def test_quant_zero_row_is_safe():
    q, s = _quant_kv(jnp.zeros((2, 3, 16)))
    assert bool(jnp.all(q == 0))
    assert bool(jnp.all(jnp.isfinite(s)))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def test_rmsnorm_scale_invariance_property():
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 17))
    p = {"scale": jnp.ones((17,))}
    y1 = rmsnorm_fwd(p, x)
    y2 = rmsnorm_fwd(p, 7.3 * x)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)


def test_layernorm_shift_invariance():
    x = jax.random.normal(jax.random.PRNGKey(5), (3, 17))
    p = {"scale": jnp.ones((17,)), "bias": jnp.zeros((17,))}
    y1 = layernorm_fwd(p, x)
    y2 = layernorm_fwd(p, x + 42.0)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# sharding constraints degrade to identity off-mesh
# ---------------------------------------------------------------------------
def test_constrain_is_identity_without_mesh():
    x = jax.random.normal(jax.random.PRNGKey(6), (8, 16))
    np.testing.assert_array_equal(np.asarray(constrain(x, "data", "model")),
                                  np.asarray(x))
    np.testing.assert_array_equal(np.asarray(constrain_batch(x)),
                                  np.asarray(x))
    # and under jit
    y = jax.jit(lambda a: constrain(a * 2, ("pod", "data")))(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) * 2)
