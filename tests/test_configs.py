"""Assigned-architecture configs: exact hyper-parameters, param-count sanity
against the public model sizes, and the long-context applicability matrix."""
from __future__ import annotations

import pytest

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCH_NAMES, assigned_pairs, get_config

ASSIGNED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
    "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
    "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
    "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
    "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
    "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
    "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
    "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
    "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
}

# public parameter counts (±30%: our decoder-backbone scope excludes the
# stubbed frontends and some model-card details like per-layer biases)
PUBLIC_PARAMS = {
    "mamba2-2.7b": 2.7e9,
    "qwen3-8b": 8.2e9,
    "qwen3-0.6b": 0.6e9,
    "stablelm-1.6b": 1.6e9,
    "mixtral-8x22b": 141e9,
    "deepseek-v2-lite-16b": 15.7e9,
    "gemma3-27b": 27e9,
    "zamba2-7b": 7.4e9,
}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_assigned_hyperparameters(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = ASSIGNED[arch]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert cfg.vocab_size == v
    if arch == "deepseek-v2-lite-16b":
        assert cfg.moe_d_ff == ff          # bracket lists the expert width
    elif ff:
        assert cfg.d_ff == ff


def test_arch_specifics():
    z = get_config("zamba2-7b")
    assert z.ssm_state == 64 and z.shared_attn_every == 6
    m = get_config("mamba2-2.7b")
    assert m.ssm_state == 128 and m.d_inner == 5120
    mx = get_config("mixtral-8x22b")
    assert mx.n_experts == 8 and mx.n_experts_per_tok == 2
    ds = get_config("deepseek-v2-lite-16b")
    assert ds.mla and ds.kv_lora_rank == 512
    assert ds.n_experts == 64 and ds.n_experts_per_tok == 6
    assert ds.n_shared_experts == 2
    g = get_config("gemma3-27b")
    assert g.local_global_pattern == 5 and g.sliding_window == 1024
    q = get_config("qwen3-8b")
    assert q.qk_norm
    w = get_config("whisper-large-v3")
    assert w.n_encoder_layers == 32 and w.cross_attention
    s = get_config("stablelm-1.6b")
    assert s.partial_rotary_factor == 0.25


@pytest.mark.parametrize("arch,target", sorted(PUBLIC_PARAMS.items()))
def test_param_counts_near_public_sizes(arch, target):
    n = get_config(arch).param_count()
    assert 0.7 * target < n < 1.3 * target, (arch, n, target)


def test_moe_active_params():
    mx = get_config("mixtral-8x22b")
    total, active = mx.param_count(), mx.active_param_count()
    # Mixtral: ~39B active of ~141B
    assert 0.2 < active / total < 0.35
    ds = get_config("deepseek-v2-lite-16b")
    assert ds.active_param_count() < 0.35 * ds.param_count()


def test_long_context_applicability():
    """DESIGN.md §Arch-applicability: exactly these five run long_500k."""
    runs_long = {a for a in ARCH_NAMES if get_config(a).supports_long_decode}
    assert runs_long == {"mamba2-2.7b", "zamba2-7b", "gemma3-27b",
                         "mixtral-8x22b", "deepseek-v2-lite-16b"}


def test_assigned_pairs_count():
    pairs = assigned_pairs()
    assert len(pairs) == 10 * 4 - 5        # 5 documented long_500k skips
    assert len(INPUT_SHAPES) == 4
    assert INPUT_SHAPES["long_500k"].seq_len == 524_288
    assert INPUT_SHAPES["train_4k"].global_batch == 256


def test_extra_arch_one_file_addition():
    """llama3.1-8b: an architecture beyond the assigned pool is one config
    file — it must instantiate, forward and stay out of assigned_pairs."""
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import EXTRA_ARCH_NAMES
    from repro.models.model import Model

    assert "llama3.1-8b" in EXTRA_ARCH_NAMES
    assert all(a != "llama3.1-8b" for a, _ in assigned_pairs())
    cfg = get_config("llama3.1-8b-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    logits, _ = model.forward(
        params, {"tokens": jnp.zeros((1, 8), jnp.int32)})
    assert logits.shape == (1, 8, cfg.vocab_size)
    full = get_config("llama3.1-8b")
    assert 0.7 * 8e9 < full.param_count() < 1.3 * 8e9


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_configs_are_small(arch):
    cfg = get_config(arch + "-reduced")
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    assert cfg.vocab_size <= 512
