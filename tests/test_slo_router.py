"""Router SLO mode: priority-ordered dispatch, per-class admission,
tenant quotas, time-based window close.

These are the request-level guarantees the workload subsystem's claims
stand on: rank order survives overload (a batch flood cannot starve
interactive), every shed is a *typed* rejection naming its mechanism
(``kind`` ∈ queue/slo/tenant), and sparse traffic still produces
scheduler observations because windows close on ``window_s`` as well as
on completion count. All on a scripted in-memory backend — no model,
no jax, deterministic.
"""
from __future__ import annotations

import time

import numpy as np
import pytest

from repro.serving import ChunkEvent, DoneEvent, Request, Router
from repro.serving.engine import Completion
from repro.serving.events import RejectedEvent
from repro.workload.slo import SLOSpec

SLO = SLOSpec.parse("interactive:0.5,batch:4.0")


def _req(rid, priority="default", tenant="", max_new=2):
    return Request(rid=rid, prompt=np.arange(4, dtype=np.int32),
                   max_new_tokens=max_new, priority=priority,
                   tenant=tenant)


class StallBackend:
    """In-memory ContainerBackend whose requests complete only when the
    test calls ``release()`` — lets a test hold a backlog open and watch
    the dispatch order."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._inflight: list[list] = [[] for _ in range(capacity)]
        self._stats = [(0.0, 0)] * capacity
        self.dispatch_order: list[int] = []   # rids, in submit order
        self._released = False
        self.closed = False

    def submit(self, cid, req):
        self.dispatch_order.append(req.rid)
        self._inflight[cid].append(req)

    def submit_many(self, cid, reqs):
        for r in reqs:
            self.submit(cid, r)

    def release(self):
        self._released = True

    def poll(self):
        if not self._released:
            return []
        out = []
        now = time.perf_counter()
        for cid, flight in enumerate(self._inflight):
            for req in flight:
                toks = tuple(range(req.max_new_tokens))
                busy, ntok = self._stats[cid]
                self._stats[cid] = (busy + 1e-4, ntok + len(toks))
                out.append(ChunkEvent(req.rid, cid, toks, now))
                out.append(DoneEvent(req.rid, cid,
                                     Completion(req.rid, list(toks),
                                                len(req.prompt), 1e-4),
                                     now))
            self._inflight[cid] = []
        return out

    def load(self, cid):
        return len(self._inflight[cid])

    def stats(self, cid):
        return self._stats[cid]

    def drain(self, concurrent=True):
        return []

    def close(self):
        self.closed = True


# ---------------------------------------------------------------------------
# priority-ordered dispatch
# ---------------------------------------------------------------------------
def test_backlog_dispatches_interactive_before_batch():
    """With one container at dispatch_depth=1, everything past the
    first request queues ROUTER-side — and leaves in rank order, not
    arrival order."""
    backend = StallBackend(1)
    with Router(backend, slo=SLO, dispatch_depth=1) as router:
        router.submit(_req(0, "batch"))          # occupies the container
        router.submit(_req(1, "batch"))          # backlog, rank 1
        router.submit(_req(2, "interactive"))    # backlog, rank 0
        router.submit(_req(3, "interactive"))    # backlog, rank 0
        assert backend.dispatch_order == [0]     # depth bound held
        backend.release()
        router.drain()
    # interactive overtook the earlier-arrived batch request
    assert backend.dispatch_order == [0, 2, 3, 1]


def test_fifo_within_a_class():
    backend = StallBackend(1)
    with Router(backend, slo=SLO, dispatch_depth=1) as router:
        for rid in range(4):
            router.submit(_req(rid, "interactive"))
        backend.release()
        router.drain()
    assert backend.dispatch_order == [0, 1, 2, 3]


def test_unknown_priority_maps_to_worst_class():
    backend = StallBackend(1)
    with Router(backend, slo=SLO, dispatch_depth=1) as router:
        router.submit(_req(0, "batch"))
        router.submit(_req(1, "mystery"))        # -> batch rank
        h = router.submit(_req(2, "interactive"))
        assert h.priority == "interactive"
        backend.release()
        router.drain()
    assert backend.dispatch_order == [0, 2, 1]


# ---------------------------------------------------------------------------
# typed sheds: queue share, slo threshold, tenant quota
# ---------------------------------------------------------------------------
def test_class_queue_share_sheds_lower_class_first():
    """max_queue=4 with batch at queue_frac 0.5: two in flight shut the
    door on batch while interactive still gets the full queue."""
    backend = StallBackend(1)
    with Router(backend, slo=SLO, dispatch_depth=1,
                max_queue=4) as router:
        router.submit(_req(0, "interactive"))
        router.submit(_req(1, "interactive"))
        shed = router.submit(_req(2, "batch"))
        kept = router.submit(_req(3, "interactive"))
        assert isinstance(shed.failure, RejectedEvent)
        assert shed.failure.kind == "queue"
        assert shed.failure.priority == "batch"
        assert kept.failure is None
        backend.release()
        router.drain()


def test_slo_shed_uses_per_class_tail():
    """A blown interactive tail sheds interactive (kind='slo') without
    touching batch admission — the threshold and the samples are the
    class's own."""
    backend = StallBackend(2)
    with Router(backend, slo=SLO, dispatch_depth=4) as router:
        now = time.perf_counter()
        for _ in range(10):   # >= 8 samples, over 2.0*0.5s threshold
            router.note_ttfc(1.7, at=now, priority="interactive")
        shed = router.submit(_req(0, "interactive"))
        kept = router.submit(_req(1, "batch"))
        assert isinstance(shed.failure, RejectedEvent)
        assert shed.failure.kind == "slo"
        assert shed.failure.priority == "interactive"
        assert kept.failure is None
        backend.release()
        router.drain()


def test_tenant_quota_rejects_hog_frees_on_completion():
    backend = StallBackend(2)
    with Router(backend, slo=SLO, dispatch_depth=4,
                tenant_quota=2) as router:
        router.submit(_req(0, "interactive", tenant="hog"))
        router.submit(_req(1, "interactive", tenant="hog"))
        third = router.submit(_req(2, "interactive", tenant="hog"))
        other = router.submit(_req(3, "interactive", tenant="meek"))
        assert isinstance(third.failure, RejectedEvent)
        assert third.failure.kind == "tenant"
        assert other.failure is None
        backend.release()
        router.drain()
        # quota freed by completion: the tenant may submit again
        retry = router.submit(_req(4, "interactive", tenant="hog"))
        assert retry.failure is None
        backend.release()
        router.drain()


def test_non_slo_rejections_unchanged():
    """Byte-compat: without an SLOSpec the old admission surface is
    untouched — plain max_queue sheds with kind='queue'."""
    backend = StallBackend(1)
    with Router(backend, max_queue=1) as router:
        router.submit(_req(0))
        shed = router.submit(_req(1))
        assert isinstance(shed.failure, RejectedEvent)
        assert shed.failure.kind == "queue"
        assert shed.failure.priority == "default"
        backend.release()
        router.drain()


# ---------------------------------------------------------------------------
# time-based window close (sparse traffic) + per-class window stats
# ---------------------------------------------------------------------------
def test_window_s_closes_sparse_window():
    """A trace sparser than ``window`` completions must still feed the
    scheduler: the window closes on wall time instead of starving
    adaptation forever."""
    built = []

    def factory(n):
        b = StallBackend(n)
        b.release()          # complete immediately in this test
        built.append(b)
        return b

    router = Router(backend_factory=factory, feasible_counts=[1],
                    window=1000, window_s=0.05, epsilon=0.0)
    for rid in range(3):
        h = router.submit(_req(rid))
        while not h.done:
            router.poll()
    deadline = time.perf_counter() + 2.0
    while not router.history and time.perf_counter() < deadline:
        time.sleep(0.01)
        router.poll()        # rotation happens inside the pump
    router.close()
    assert router.history, "window_s never closed a sparse window"
    w = router.history[0]
    assert 0 < w.n_requests <= 3
    assert router.scheduler.n_observations >= 1


def test_per_class_window_stats_and_attainment():
    def factory(n):
        b = StallBackend(n)
        b.release()
        return b

    router = Router(backend_factory=factory, feasible_counts=[1],
                    window=4, epsilon=0.0, slo=SLO)
    rids = iter(range(100))
    for _ in range(2):       # two full windows
        handles = [router.submit(_req(next(rids), pri))
                   for pri in ("interactive", "interactive",
                               "batch", "batch")]
        while not all(h.done for h in handles):
            router.poll()
    router.close()
    assert router.history
    w = router.history[0]
    assert set(w.per_class) == {"interactive", "batch"}
    cw = w.per_class["interactive"]
    assert cw.n_done == 2
    assert cw.target_ttfc_p95_s == pytest.approx(0.5)
    assert cw.attained is True   # scripted backend answers instantly
