"""Trace-driven workload subsystem: generators, SLO arithmetic, the
virtual-time simulator.

The contracts under test: (a) synthesis is a pure function of
(spec, seed) — same inputs, bit-identical trace; (b) a trace survives
the JSONL round-trip exactly; (c) arrival processes have the rates
their specs claim; (d) the loss-censored quantile and the shed/queue
threshold helpers are the single source of admission arithmetic; and
(e) ``simulate`` — the twin the committed BENCH_trace numbers come
from — is deterministic bit-for-bit and responds to capacity the way a
queue must (more containers, shorter tails, on an overloaded trace).
"""
from __future__ import annotations

import dataclasses
import pickle

import pytest

from repro.workload.replay import assemble_report, build_request
from repro.workload.sim import FleetModel, simulate
from repro.workload.slo import (SHED_HEADROOM, SLOClass, SLOSpec,
                                censored_ttfc_p95, queue_limit,
                                shed_ttfc_threshold)
from repro.workload.traces import (PRESETS, get_preset, load_jsonl,
                                   load_or_synthesize, save_jsonl,
                                   synthesize)


# ---------------------------------------------------------------------------
# trace synthesis
# ---------------------------------------------------------------------------
def test_same_seed_identical_trace():
    spec = get_preset("diurnal-bursty")
    a, b = synthesize(spec, seed=7), synthesize(spec, seed=7)
    assert a == b
    assert a.requests == b.requests


def test_different_seed_different_trace():
    spec = get_preset("diurnal-bursty")
    assert synthesize(spec, seed=1) != synthesize(spec, seed=2)


def test_arrivals_sorted_within_duration():
    for name, spec in PRESETS.items():
        tr = synthesize(spec, seed=3)
        times = [r.arrival_s for r in tr.requests]
        assert times == sorted(times), name
        assert all(0.0 <= t <= spec.duration_s for t in times), name
        assert all(r.prompt_len >= 1 and r.max_new_tokens >= 1
                   for r in tr.requests), name


def test_poisson_rate_matches_spec():
    spec = dataclasses.replace(
        get_preset("poisson-light"), duration_s=2000.0, max_requests=10_000)
    tr = synthesize(spec, seed=0)
    rate = len(tr.requests) / spec.duration_s
    assert rate == pytest.approx(spec.arrival.rate_rps, rel=0.1)


def test_priority_mix_matches_weights():
    spec = dataclasses.replace(get_preset("diurnal-bursty"),
                               duration_s=2000.0, max_requests=10_000)
    tr = synthesize(spec, seed=5)
    share = (sum(1 for r in tr.requests if r.priority == "interactive")
             / len(tr.requests))
    assert share == pytest.approx(0.7, abs=0.05)


def test_jsonl_roundtrip_exact(tmp_path):
    tr = synthesize(get_preset("bursty"), seed=11)
    path = tmp_path / "trace.jsonl"
    save_jsonl(tr, path)
    assert load_jsonl(path) == tr
    assert load_or_synthesize(str(path)) == tr


def test_load_or_synthesize_rejects_unknown():
    with pytest.raises(ValueError, match="neither a preset"):
        load_or_synthesize("no-such-preset-or-file")


def test_trace_picklable():
    tr = synthesize(get_preset("poisson-light"), seed=0)
    assert pickle.loads(pickle.dumps(tr)) == tr


def test_build_request_regenerates_prompt():
    tr = synthesize(get_preset("poisson-light"), seed=0)
    r1 = build_request(tr.requests[0], vocab_size=256)
    r2 = build_request(tr.requests[0], vocab_size=256)
    assert (r1.prompt == r2.prompt).all()
    assert len(r1.prompt) == tr.requests[0].prompt_len
    assert r1.priority == tr.requests[0].priority


# ---------------------------------------------------------------------------
# SLO vocabulary + admission arithmetic
# ---------------------------------------------------------------------------
def test_slospec_parse_ranks_and_fracs():
    spec = SLOSpec.parse("interactive:0.5,batch:4.0")
    assert spec.names() == ("interactive", "batch")
    inter, batch = spec.classes
    assert inter.rank == 0 and batch.rank == 1
    assert inter.queue_frac == 1.0 and batch.queue_frac == 0.5
    assert spec.constraint is inter
    # unknown priorities map to the WORST class, never the best
    assert spec.cls("mystery") is batch


def test_slospec_parse_rejects_garbage():
    with pytest.raises(ValueError):
        SLOSpec.parse("")
    with pytest.raises(ValueError):
        SLOSpec.parse("a:1:2:3")
    with pytest.raises(ValueError):
        SLOClass(name="x", ttfc_p95_s=-1.0)
    with pytest.raises(ValueError):
        SLOSpec(classes=(SLOClass(name="a"), SLOClass(name="a")))


def test_queue_limit_scales_and_floors():
    cls = SLOClass(name="batch", queue_frac=0.25)
    assert queue_limit(cls, 64) == 16
    assert queue_limit(cls, 2) == 1          # never statically locked out
    assert queue_limit(cls, None) is None


def test_shed_threshold_headroom_and_override():
    cls = SLOClass(name="i", ttfc_p95_s=0.5)
    assert shed_ttfc_threshold(cls, None) == SHED_HEADROOM * 0.5
    assert shed_ttfc_threshold(cls, 2.5) == 2.5


def test_censored_p95_counts_losses_as_violations():
    clean = [0.1] * 100
    assert censored_ttfc_p95(clean, 0, cap_s=1.0) == pytest.approx(0.1)
    # 10 lost out of 110: the 95th percentile falls in the censored mass
    assert censored_ttfc_p95(clean, 10, cap_s=1.0) == 1.0
    # 2 lost out of 102 (< 5%): still the observed value
    assert censored_ttfc_p95(clean, 2, cap_s=1.0) == pytest.approx(0.1)
    assert censored_ttfc_p95([], 0, cap_s=1.0) is None
    assert censored_ttfc_p95([], 5, cap_s=1.0) == 1.0


def test_assemble_report_goodput_counts_only_met_targets():
    tr = synthesize(get_preset("poisson-light"), seed=0)
    slo = SLOSpec.parse("interactive:0.5,batch:4.0")
    done = [("interactive", 0.2, 1.0),    # met
            ("interactive", 0.9, 1.5),    # blew its target — not goodput
            ("batch", 3.0, 5.0)]          # met
    rep = assemble_report(tr, slo=slo, done=done, shed=["batch"],
                          failed=["interactive"], duration_s=10.0,
                          energy_j=50.0)
    assert rep.goodput_rps == pytest.approx(2 / 10.0)
    assert rep.n_done == 3 and rep.n_shed == 1 and rep.n_failed == 1
    assert rep.energy_per_done_j == pytest.approx(50.0 / 3)
    assert rep.per_class["interactive"].attained is False
    assert rep.per_class["batch"].attained is True
    assert rep.slo_attained is False
    assert pickle.loads(pickle.dumps(rep)) == rep


# ---------------------------------------------------------------------------
# the virtual-time simulator
# ---------------------------------------------------------------------------
SIM_KW = dict(window=16, window_s=10.0, max_queue=64, epsilon=0.05)


def _short_trace(seed=1):
    return synthesize(dataclasses.replace(get_preset("diurnal-bursty"),
                                          duration_s=300.0), seed=seed)


def test_simulate_deterministic_bit_for_bit():
    tr = _short_trace()
    slo = SLOSpec.parse("interactive:0.5,batch:8.0")
    kw = dict(feasible_counts=[1, 2, 3], objective="energy_under_slo",
              slo=slo, seed=4, **SIM_KW)
    a, b = simulate(tr, **kw), simulate(tr, **kw)
    assert a == b


def test_simulate_completes_everything_unloaded():
    tr = synthesize(get_preset("poisson-light"), seed=0)
    rep = simulate(tr, feasible_counts=[2], **SIM_KW)
    assert rep.n_done == rep.n_requests
    assert rep.n_shed == 0 and rep.n_failed == 0
    assert rep.final_n == 2 and rep.counts_visited == (2,)


def test_more_containers_shorter_tail_under_overload():
    """The paper's capacity story through the queue: on a bursty trace a
    1-container fleet queues, a 4-container fleet doesn't."""
    tr = _short_trace()
    one = simulate(tr, feasible_counts=[1], **SIM_KW)
    four = simulate(tr, feasible_counts=[4], **SIM_KW)
    assert four.ttfc_p95_s < one.ttfc_p95_s
    assert four.n_done >= one.n_done


def test_simulate_deadline_failures_accounted():
    tr = _short_trace()
    strict = simulate(tr, feasible_counts=[1],
                      deadline_by_class={"interactive": 0.3,
                                         "batch": 0.3, "default": 0.3},
                      **SIM_KW)
    assert strict.n_failed > 0
    assert (strict.n_done + strict.n_shed + strict.n_failed
            == strict.n_requests)


def test_fleet_model_shapes():
    fleet = FleetModel()
    # splitting recovers parallelism: aggregate throughput rises with n
    agg = [n * fleet.rate(n) for n in (1, 2, 4)]
    assert agg == sorted(agg)
    # static power rises with provisioned count, busy adds dynamic power
    assert fleet.power_w(2, 0) > fleet.power_w(1, 0)
    assert fleet.power_w(2, 2) > fleet.power_w(2, 1) > fleet.power_w(2, 0)


def test_slo_run_prefers_attainment_over_mean_energy():
    """The headline mechanism, miniaturised: under the frozen fleet the
    mean-energy run and the SLO run may pick different counts, and the
    SLO run must attain its targets."""
    tr = synthesize(dataclasses.replace(get_preset("diurnal-bursty"),
                                        duration_s=900.0), seed=1)
    slo = SLOSpec.parse("interactive:0.5,batch:8.0")
    dl = {"interactive": 1.2, "batch": 30.0, "default": 30.0}
    kw = dict(feasible_counts=[1, 2, 3, 4], seed=0,
              deadline_by_class=dl, **SIM_KW)
    cons = simulate(tr, objective="energy_under_slo", slo=slo, **kw)
    assert cons.slo_attained
    assert cons.per_class["interactive"].ttfc_p95_s <= 0.5
