"""Property-based contracts for the workload generators and the SLO
arithmetic (hypothesis; the module is skipped on hosts without it —
see conftest.pytest_ignore_collect).

The generators promise: determinism in (spec, seed), sorted in-range
arrivals, positive lengths, an exact JSONL round-trip for ANY spec the
validators accept. The censored quantile promises: bounded by the cap,
monotone in the loss count, and exactly the order statistic when
nothing was lost.
"""
from __future__ import annotations

import dataclasses
import math

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.workload.slo import SLOClass, SLOSpec, censored_ttfc_p95
from repro.workload.traces import (ArrivalSpec, LengthSpec, TraceSpec,
                                   load_jsonl, save_jsonl, synthesize)

arrival_specs = st.builds(
    ArrivalSpec,
    kind=st.sampled_from(["poisson", "diurnal", "bursty",
                          "diurnal_bursty"]),
    rate_rps=st.floats(0.2, 20.0),
    period_s=st.floats(10.0, 300.0),
    depth=st.floats(0.0, 0.95),
    burst_rate_rps=st.floats(1.0, 40.0),
    calm_dwell_s=st.floats(1.0, 60.0),
    burst_dwell_s=st.floats(0.5, 30.0),
)

trace_specs = st.builds(
    TraceSpec,
    name=st.just("prop"),
    duration_s=st.floats(5.0, 60.0),
    arrival=arrival_specs,
    lengths=st.builds(
        LengthSpec,
        prompt_median=st.floats(4.0, 64.0),
        prompt_sigma=st.floats(0.1, 1.0),
    ),
)


@settings(max_examples=40, deadline=None)
@given(spec=trace_specs, seed=st.integers(0, 2**31 - 1))
def test_synthesis_deterministic_and_well_formed(spec, seed):
    a = synthesize(spec, seed=seed)
    b = synthesize(spec, seed=seed)
    assert a == b
    times = [r.arrival_s for r in a.requests]
    assert times == sorted(times)
    assert all(0.0 <= t <= spec.duration_s for t in times)
    assert all(r.prompt_len >= 1 and r.max_new_tokens >= 1
               for r in a.requests)
    assert [r.rid for r in a.requests] == list(range(len(a.requests)))


@settings(max_examples=25, deadline=None)
@given(spec=trace_specs, seed=st.integers(0, 2**31 - 1))
def test_jsonl_roundtrip_any_spec(spec, seed, tmp_path_factory):
    tr = synthesize(spec, seed=seed)
    path = tmp_path_factory.mktemp("traces") / "t.jsonl"
    save_jsonl(tr, path)
    assert load_jsonl(path) == tr


@settings(max_examples=100, deadline=None)
@given(ttfc=st.lists(st.floats(0.0, 10.0, allow_nan=False), max_size=200),
       n_lost=st.integers(0, 200),
       cap=st.floats(0.1, 100.0))
def test_censored_p95_bounded_and_monotone(ttfc, n_lost, cap):
    q = censored_ttfc_p95(ttfc, n_lost, cap_s=cap)
    if not ttfc and n_lost == 0:
        assert q is None
        return
    assert q is not None
    if ttfc:
        assert min(ttfc) <= q <= max(max(ttfc), cap)
    else:
        assert q == cap
    # censoring more arrivals can never LOWER the reported tail
    q_more = censored_ttfc_p95(ttfc, n_lost + 10, cap_s=cap)
    if q <= cap:
        assert q_more >= q or math.isclose(q_more, q)


@settings(max_examples=100, deadline=None)
@given(ttfc=st.lists(st.floats(0.0, 10.0, allow_nan=False),
                     min_size=1, max_size=200))
def test_censored_p95_is_order_statistic_without_losses(ttfc):
    q = censored_ttfc_p95(ttfc, 0, cap_s=1e9)
    s = sorted(ttfc)
    k = max(0, math.ceil(0.95 * len(s)) - 1)
    assert q == s[k]


@settings(max_examples=50, deadline=None)
@given(targets=st.lists(st.floats(0.01, 50.0), min_size=1, max_size=5,
                        unique=True))
def test_slospec_constraint_is_tightest(targets):
    spec = SLOSpec(tuple(
        SLOClass(name=f"c{i}", ttfc_p95_s=t, rank=i,
                 queue_frac=1.0 / (2 ** i))
        for i, t in enumerate(targets)))
    assert spec.constraint.ttfc_p95_s == min(targets)
    # any unknown name lands on the highest rank
    worst = spec.cls("not-a-class")
    assert worst.rank == len(targets) - 1
