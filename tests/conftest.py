"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device (the 512-placeholder
override belongs to the dry-run only)."""
from __future__ import annotations

import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_NAMES, get_config
from repro.models.model import Model

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


def _uses_hypothesis(path: pathlib.Path) -> bool:
    try:
        src = path.read_text(encoding="utf-8")
    except OSError:
        return False
    return "import hypothesis" in src or "from hypothesis" in src


def pytest_ignore_collect(collection_path, config):
    """Offline degradation: property-based modules are skipped (not
    collection errors) when ``hypothesis`` isn't installed — tier-1 must
    run from a clean checkout with only runtime deps."""
    p = pathlib.Path(str(collection_path))
    if (not HAVE_HYPOTHESIS and p.suffix == ".py"
            and p.name.startswith("test_") and _uses_hypothesis(p)):
        return True
    return None


def pytest_report_header(config):
    if not HAVE_HYPOTHESIS:
        return ("hypothesis not installed — property-based test modules "
                "are skipped (pip install -e '.[dev]' to enable them)")
    return None


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def make_batch(cfg, batch: int = 2, seq: int = 16, seed: int = 1):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    out = {"tokens": toks}
    k1, k2 = jax.random.split(key)
    # random (not constant) frontend stubs: layernorm cancels constant
    # shifts, which would make "frontend changes logits" tests degenerate
    if cfg.n_vision_tokens:
        out["vision_embeds"] = 0.1 * jax.random.normal(
            k1, (batch, cfg.n_vision_tokens, cfg.vision_embed_dim))
    if cfg.n_encoder_layers:
        out["audio_frames"] = 0.1 * jax.random.normal(
            k2, (batch, cfg.encoder_seq, cfg.d_model))
    return out


@pytest.fixture(scope="session")
def reduced_models():
    """Initialised reduced models, shared across the whole session (init is
    the slow part)."""
    out = {}
    key = jax.random.PRNGKey(0)
    for name in ARCH_NAMES:
        cfg = get_config(name + "-reduced")
        model = Model(cfg)
        out[name] = (model, model.init(key))
    return out
