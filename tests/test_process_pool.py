"""Process-per-container pool: the paper's real isolation mechanism.

Parity harness for serving/process_pool.py — greedy completions from
pinned child processes must bit-match the in-process single-engine
baseline (params rebuilt from seed in one lane, handed off via .npz in
the other), per-container core sets must be pairwise disjoint, and warm
children must survive across waves. Spawn+compile makes these seconds-
scale, so the expensive ones are marked ``slow`` (the CI fast lane skips
them; the dedicated process-pool CI job runs this module in full).
"""
from __future__ import annotations

import os

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.model import Model
from repro.serving import (AdaptiveServingPool, ProcessContainerPool,
                           Request, ServingEngine, share_params)
from repro.serving.process_pool import save_params

HOST_CORES = len(os.sched_getaffinity(0))


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("qwen3-0.6b-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _requests(cfg, n, plen=6, max_new=3, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, (plen,),
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def test_too_many_containers_fails_fast_without_spawn(small_lm):
    """More containers than cores cannot be pairwise disjoint: the pool
    must refuse at construction, before paying any spawn cost."""
    model, _ = small_lm
    with pytest.raises(ValueError, match="disjoint"):
        ProcessContainerPool(model.cfg, HOST_CORES + 1)


def test_shared_cores_need_explicit_opt_in(small_lm):
    model, _ = small_lm
    pool = ProcessContainerPool(model.cfg, HOST_CORES + 1,
                                allow_shared_cores=True)
    assert len(pool.core_sets) == HOST_CORES + 1
    # round-robin singletons: every assigned core is a real host core
    assert set().union(*pool.core_sets) <= set(os.sched_getaffinity(0))


@pytest.mark.slow
def test_process_pool_parity_disjoint_cores_and_warm_reuse(small_lm,
                                                           tmp_path):
    """The acceptance harness: for n ∈ {1, 2}, greedy completions from
    pinned child processes bit-match the single-engine baseline (n=1
    rebuilds params from the seed, n=2 loads the parent's params from the
    .npz handoff), children report pairwise-disjoint core affinities, and
    a second wave reuses the warm children (same results, no respawn)."""
    model, params = small_lm
    cfg = model.cfg
    reqs = _requests(cfg, 5)

    eng = ServingEngine(model, params, n_slots=2, max_len=64)
    eng.submit_many(list(reqs))
    want = {c.rid: (tuple(c.tokens), c.prompt_len) for c in eng.run()}

    handoff = save_params(params, str(tmp_path / "params.npz"))
    for n, params_path in ((1, None), (2, handoff)):
        if n > HOST_CORES:
            pytest.skip(f"needs {n} cores, host exposes {HOST_CORES}")
        with ProcessContainerPool(cfg, n, n_slots_per_container=2,
                                  max_len=64, params_seed=0,
                                  params_path=params_path) as pool:
            ordered, per, wall, energy = pool.serve_timed(list(reqs))
            got = {c.rid: (tuple(c.tokens), c.prompt_len) for c in ordered}
            assert got == want, f"n={n} diverged from the baseline"
            assert [c.rid for c in ordered] == [r.rid for r in reqs]
            assert wall > 0 and energy > 0
            assert len(per) == n
            assert sum(r.n_requests for r in per) == len(reqs)
            for r in per:
                assert r.busy_s > 0 and r.energy_j > 0

            sets = pool.reported_core_sets
            assert sets is not None and len(sets) == n
            # children measured their OWN affinity after jax init: it must
            # be exactly the parent's assignment, pairwise disjoint
            assert sets == list(pool.core_sets)
            for i, a in enumerate(sets):
                for b in sets[i + 1:]:
                    assert not (a & b), "containers share cores"

            workers = pool._workers
            again, _, _, _ = pool.serve_timed(list(reqs))
            assert {c.rid: (tuple(c.tokens), c.prompt_len)
                    for c in again} == want
            assert pool._workers is workers    # warm: no respawn


@pytest.mark.slow
def test_adaptive_pool_process_isolation_converges_warm(small_lm):
    """AdaptiveServingPool(isolation='process'): waves are served by warm
    per-count process pools (spawn paid once per count), results stay
    order-correct, and close() shuts every child down."""
    model, params = small_lm
    counts = [1, 2] if HOST_CORES >= 2 else [1]
    apool = AdaptiveServingPool(model, params, counts, objective="energy",
                                n_slots_per_container=2, max_len=64,
                                isolation="process", params_seed=0)
    try:
        for wave in range(3):
            reqs = _requests(model.cfg, 4, seed=wave)
            out = apool.serve_wave(reqs)
            assert [c.rid for c in out] == [r.rid for r in reqs]
        assert apool.scheduler.n_observations == 3
        # converged serving reuses cached pools: at most one per count
        assert set(apool._pools) <= set(counts)
        procs = [proc for pool in apool._pools.values()
                 for (proc, _) in (pool._workers or [])]
        assert procs
    finally:
        apool.close()
    assert all(not p.is_alive() for p in procs)
    assert apool._pools == {}


def test_shared_memory_params_roundtrip_in_process(small_lm):
    """``share_params`` lays the leaves out in one shared-memory segment
    and the child-side loader rebuilds a byte-identical tree — verified
    in-process (no spawn cost), including the dangling-alias hazard: the
    rebuilt leaves must survive the segment being closed and unlinked."""
    from repro.serving.backend import _load_params_shm

    model, params = small_lm
    with share_params(params) as share:
        rebuilt = _load_params_shm(model, share.handle)
    # the share is now closed AND unlinked; the copies must be intact
    want = jax.tree_util.tree_leaves(params)
    got = jax.tree_util.tree_leaves(rebuilt)
    assert len(want) == len(got)
    for a, b in zip(want, got):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shared_params_handle_is_picklable(small_lm):
    import pickle
    _, params = small_lm
    with share_params(params) as share:
        handle = pickle.loads(pickle.dumps(share.handle))
        assert handle == share.handle


def test_params_path_and_shm_are_mutually_exclusive(small_lm):
    model, _ = small_lm
    with pytest.raises(ValueError, match="not both"):
        ProcessContainerPool(model.cfg, 1, params_path="x.npz",
                             params_shm=object())


@pytest.mark.slow
def test_shared_memory_handoff_parity_with_npz(small_lm, tmp_path):
    """The shared-memory params handoff must serve bit-identical
    completions to the ``.npz`` handoff (both carry the parent's exact
    float bytes) — the ROADMAP's cross-process shared-memory leftover."""
    model, params = small_lm
    cfg = model.cfg
    reqs = _requests(cfg, 4)

    handoff = save_params(params, str(tmp_path / "params.npz"))
    with ProcessContainerPool(cfg, 1, n_slots_per_container=2,
                              max_len=64, params_path=handoff) as pool:
        via_npz, _, _, _ = pool.serve_timed(list(reqs))

    with share_params(params) as share:
        with ProcessContainerPool(cfg, 1, n_slots_per_container=2,
                                  max_len=64,
                                  params_shm=share.handle) as pool:
            via_shm, _, _, _ = pool.serve_timed(list(reqs))
            # warm second wave over the mapped params
            again, _, _, _ = pool.serve_timed(list(reqs))
    key = lambda comps: {c.rid: (tuple(c.tokens), c.prompt_len)  # noqa: E731
                         for c in comps}
    assert key(via_shm) == key(via_npz)
    assert key(again) == key(via_npz)


def test_process_isolation_rejects_counts_past_core_budget():
    """Fail fast at construction (mirrors the submesh divisor check): a
    feasible count beyond the core budget would otherwise crash the first
    time the scheduler probes it."""
    from repro.serving import synthetic_pool_factory
    with pytest.raises(ValueError, match="core budget"):
        AdaptiveServingPool(None, None, [1, HOST_CORES + 1],
                            pool_factory=synthetic_pool_factory(
                                lambda n: 1.0 / n),
                            isolation="process")


def test_process_isolation_incompatible_with_submesh():
    from repro.serving import synthetic_pool_factory
    with pytest.raises(ValueError, match="submesh placement"):
        AdaptiveServingPool(None, None, [1, 2],
                            pool_factory=synthetic_pool_factory(
                                lambda n: 1.0 / n),
                            isolation="process", submesh_devices=8)
