"""Per-kernel validation: Pallas (interpret=True) and the flash-structured
jnp paths, swept over shapes/dtypes against the pure-jnp oracles in ref.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_ref, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16] if dtype == jnp.bfloat16 else TOL[jnp.float32]


def _qkv(key, B, Sq, Skv, H, Hkv, K, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, K)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, K)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, K)).astype(dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# flash attention (Pallas, interpret mode)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,Sq,H,Hkv,K", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 128, 4, 2, 64),      # GQA
    (1, 256, 8, 2, 32),      # more heads, small head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_pallas(B, Sq, H, Hkv, K, dtype, window):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, Sq, Sq, H, Hkv, K, dtype)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_attention_softcap():
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 128, 128, 4, 4, 64, jnp.float32)
    got = flash_attention(q, k, v, causal=True, softcap=30.0,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True, softcap=30.0)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal():
    q, k, v = _qkv(jax.random.PRNGKey(2), 2, 128, 128, 4, 2, 64, jnp.float32)
    got = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash_ref (the jnp flash path used on CPU): values AND grads
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,Sq,Skv,H,Hkv,K,causal,window,cap", [
    (2, 64, 64, 4, 2, 16, True, 0, 0.0),
    (2, 64, 64, 4, 2, 16, True, 24, 0.0),
    (1, 48, 48, 2, 2, 8, True, 0, 5.0),
    (2, 1, 64, 4, 4, 16, True, 0, 0.0),     # decode-style single query
    (2, 40, 40, 4, 2, 16, False, 0, 0.0),   # non-divisible (padding path)
])
def test_flash_ref_matches_oracle(B, Sq, Skv, H, Hkv, K, causal, window, cap):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, Sq, Skv, H, Hkv, K, jnp.float32)

    def f1(q, k, v):
        return flash_ref.flash_attention(q, k, v, causal=causal,
                                         window=window, softcap=cap,
                                         block_q=16, block_k=16)

    def f2(q, k, v):
        return ref.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=cap)

    np.testing.assert_allclose(f1(q, k, v), f2(q, k, v), atol=2e-5, rtol=2e-5)
    g1 = jax.grad(lambda *a: jnp.sum(jnp.sin(f1(*a))), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: jnp.sum(jnp.sin(f2(*a))), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-5)


# ---------------------------------------------------------------------------
# decode attention (Pallas, interpret mode)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,Hkv,K,W", [
    (2, 4, 2, 64, 256),
    (1, 8, 8, 32, 512),
    (3, 4, 1, 64, 128),      # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_pallas(B, H, Hkv, K, W, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, H, K)).astype(dtype)
    k = jax.random.normal(ks[1], (B, W, Hkv, K)).astype(dtype)
    v = jax.random.normal(ks[2], (B, W, Hkv, K)).astype(dtype)
    valid = jax.random.bernoulli(ks[3], 0.7, (B, W)).at[:, 0].set(True)
    got = decode_attention(q, k, v, valid, block_k=128, interpret=True)
    want = ref.decode_attention(q, k, v, valid)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("B,H,Hkv,K,W", [(2, 4, 2, 64, 256),
                                         (1, 8, 8, 32, 512)])
def test_decode_attention_int8_pallas(B, H, Hkv, K, W):
    """int8 Pallas decode (dequant in VMEM) vs the blocked jnp reference
    with the same scales."""
    from repro.kernels.decode_attention import decode_attention_int8
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (B, H, K))
    kf = jax.random.normal(ks[1], (B, W, Hkv, K))
    vf = jax.random.normal(ks[2], (B, W, Hkv, K))
    valid = jax.random.bernoulli(ks[3], 0.7, (B, W)).at[:, 0].set(True)

    def quant(x):
        scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1) / 127.0, 1e-8)
        return (jnp.clip(jnp.round(x / scale[..., None]), -127, 127)
                .astype(jnp.int8), scale)

    kq, ksc = quant(kf)
    vq, vsc = quant(vf)
    got = decode_attention_int8(q, kq, vq, valid, ksc, vsc, block_k=128,
                                interpret=True)
    want = ref.decode_attention_blocked(q, kq, vq, valid, k_scale=ksc,
                                        v_scale=vsc, block=128)
    np.testing.assert_allclose(got, want, atol=5e-5, rtol=5e-5)
    # and both must be close to the full-precision oracle
    full = ref.decode_attention(q, kf, vf, valid)
    np.testing.assert_allclose(got, full, atol=0.08, rtol=0.08)


def test_decode_attention_blocked_matches_oracle():
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    B, H, Hkv, K, W = 2, 4, 2, 32, 300   # non-divisible W (padding path)
    q = jax.random.normal(ks[0], (B, H, K))
    kf = jax.random.normal(ks[1], (B, W, Hkv, K))
    vf = jax.random.normal(ks[2], (B, W, Hkv, K))
    valid = jax.random.bernoulli(ks[3], 0.6, (B, W)).at[:, 0].set(True)
    got = ref.decode_attention_blocked(q, kf, vf, valid, block=64)
    want = ref.decode_attention(q, kf, vf, valid)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_decode_attention_partial_merge_equals_full():
    """Splitting the cache into S slices, computing partials and merging
    with the flash-decoding formula must equal the monolithic softmax —
    the invariant behind the shard_map sequence-parallel decode."""
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    B, H, Hkv, K, W, S = 2, 4, 2, 32, 256, 4
    q = jax.random.normal(ks[0], (B, H, K))
    kf = jax.random.normal(ks[1], (B, W, Hkv, K))
    vf = jax.random.normal(ks[2], (B, W, Hkv, K))
    valid = jax.random.bernoulli(ks[3], 0.7, (B, W)).at[:, 0].set(True)
    accs, ms, ls = [], [], []
    for i in range(S):
        sl = slice(i * W // S, (i + 1) * W // S)
        a, m, l = ref.decode_attention_partial(q, kf[:, sl], vf[:, sl],
                                               valid[:, sl])
        accs.append(a)
        ms.append(m)
        ls.append(l)
    m_tot = jnp.max(jnp.stack(ms), axis=0)
    w = [jnp.exp(m - m_tot) for m in ms]
    num = sum(wi[..., None] * a for wi, a in zip(w, accs))
    den = jnp.maximum(sum(wi * l for wi, l in zip(w, ls)), 1e-30)
    merged = num / den[..., None]
    want = ref.decode_attention(q, kf, vf, valid)
    np.testing.assert_allclose(merged, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan: Pallas kernel, sequential jnp path, decode recurrence
# ---------------------------------------------------------------------------
def _ssd_inputs(key, B, S, nh, hd, ng, ds, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, nh, hd)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    B_ = jax.random.normal(ks[3], (B, S, ng, ds)).astype(dtype)
    C_ = jax.random.normal(ks[4], (B, S, ng, ds)).astype(dtype)
    D = jnp.ones((nh,))
    return x, dt, A, B_, C_, D


@pytest.mark.parametrize("B,S,nh,hd,ng,ds,chunk", [
    (2, 128, 4, 16, 2, 16, 32),
    (1, 64, 8, 8, 1, 32, 16),
    (2, 256, 2, 32, 1, 8, 64),
])
def test_ssd_scan_pallas(B, S, nh, hd, ng, ds, chunk):
    x, dt, A, B_, C_, D = _ssd_inputs(jax.random.PRNGKey(0), B, S, nh, hd,
                                      ng, ds)
    y1, s1 = ssd_scan(x, dt, A, B_, C_, D, chunk=chunk, interpret=True)
    y2, s2 = ref.ssd_scan(x, dt, A, B_, C_, D, chunk=chunk)
    np.testing.assert_allclose(y1, y2, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(s1, s2, atol=5e-5, rtol=5e-5)


def test_ssd_scan_seq_matches_oracle():
    x, dt, A, B_, C_, D = _ssd_inputs(jax.random.PRNGKey(1), 2, 128, 4, 16,
                                      2, 16)
    y1, s1 = ref.ssd_scan_seq(x, dt, A, B_, C_, D, chunk=32)
    y2, s2 = ref.ssd_scan(x, dt, A, B_, C_, D, chunk=32)
    np.testing.assert_allclose(y1, y2, atol=1e-6)
    np.testing.assert_allclose(s1, s2, atol=1e-6)


def test_ssd_chunk_invariance():
    """The scan result must not depend on the chunk size."""
    x, dt, A, B_, C_, D = _ssd_inputs(jax.random.PRNGKey(2), 1, 128, 2, 8,
                                      1, 8)
    y16, s16 = ref.ssd_scan(x, dt, A, B_, C_, D, chunk=16)
    y64, s64 = ref.ssd_scan(x, dt, A, B_, C_, D, chunk=64)
    np.testing.assert_allclose(y16, y64, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(s16, s64, atol=2e-5, rtol=2e-5)


def test_ssd_decode_matches_scan():
    """Sequential single-token recurrence == chunked scan, step by step."""
    B, S, nh, hd, ng, ds = 1, 32, 2, 8, 1, 8
    x, dt, A, B_, C_, D = _ssd_inputs(jax.random.PRNGKey(3), B, S, nh, hd,
                                      ng, ds)
    y_all, s_all = ref.ssd_scan(x, dt, A, B_, C_, D, chunk=8)
    state = jnp.zeros((B, nh, hd, ds))
    ys = []
    for t in range(S):
        y_t, state = ref.ssd_decode_step(state, x[:, t], dt[:, t], A,
                                         B_[:, t], C_[:, t], D)
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_seq, y_all, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(state, s_all, atol=5e-5, rtol=5e-5)


# ---------------------------------------------------------------------------
# absorbed-MLA decode kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,r,dr,S,bs", [
    (2, 4, 64, 16, 256, 64),
    (1, 8, 128, 32, 512, 128),
    (3, 2, 32, 8, 128, 128),      # single block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mla_decode_pallas(B, H, r, dr, S, bs, dtype):
    from repro.kernels.mla_decode import mla_decode_ctx
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    q_lat = jax.random.normal(ks[0], (B, H, r)).astype(dtype)
    q_rope = jax.random.normal(ks[1], (B, H, dr)).astype(dtype)
    ckv = jax.random.normal(ks[2], (B, S, r)).astype(dtype)
    k_rope = jax.random.normal(ks[3], (B, S, dr)).astype(dtype)
    valid = jax.random.bernoulli(ks[4], 0.7, (B, S)).at[:, 0].set(True)
    scale = (r + dr) ** -0.5
    got = mla_decode_ctx(q_lat, q_rope, ckv, k_rope, valid, scale=scale,
                         block_s=bs, interpret=True)
    want = ref.mla_decode_ctx(q_lat, q_rope, ckv, k_rope, valid, scale=scale)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_mla_model_decode_through_pallas_interpret(monkeypatch):
    """deepseek (MLA) decode through the Pallas kernel in interpret mode
    matches the jnp path end-to-end."""
    from repro.configs.registry import get_config
    from repro.models.model import Model

    cfg = get_config("deepseek-v2-lite-16b-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 9), 0,
                              cfg.vocab_size)

    def decode_once():
        cache = model.init_cache(1, 64)
        _, cache = model.prefill(params, {"tokens": toks[:, :8]}, cache,
                                 logits_at=-1)
        lg, _ = model.decode_step(params, toks[:, 8:9], cache,
                                  jnp.asarray([8], jnp.int32))
        return lg

    ref_lg = decode_once()
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "interpret")
    pallas_lg = decode_once()
    np.testing.assert_allclose(np.asarray(pallas_lg), np.asarray(ref_lg),
                               atol=2e-4, rtol=2e-4)


def test_model_decode_through_pallas_interpret(monkeypatch):
    """End-to-end: a reduced int8-cache model decodes through the Pallas
    kernels in interpret mode (REPRO_FORCE_PALLAS) and matches the jnp
    path."""
    import dataclasses

    from repro.configs.registry import get_config
    from repro.models.model import Model

    cfg = dataclasses.replace(get_config("qwen3-0.6b-reduced"),
                              kv_cache_dtype="int8")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 9), 0,
                              cfg.vocab_size)
    def decode_once():
        cache = model.init_cache(1, 64)
        _, cache = model.prefill(params, {"tokens": toks[:, :8]}, cache,
                                 logits_at=-1)
        lg, _ = model.decode_step(params, toks[:, 8:9], cache,
                                  jnp.asarray([8], jnp.int32))
        return lg

    ref_lg = decode_once()
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "interpret")
    pallas_lg = decode_once()
    np.testing.assert_allclose(np.asarray(pallas_lg), np.asarray(ref_lg),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(4, 96), (2, 37, 64), (1, 5, 3, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_pallas(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    scale = 1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(1), shape[-1:])
    got = rmsnorm(x, scale.astype(dtype), block_rows=8, interpret=True)
    want = ref.rmsnorm(x, scale.astype(dtype))
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))
