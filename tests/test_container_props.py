"""Property tests (hypothesis) for the container factorisation and the
split→serve→combine loop.

Invariants: factorizations enumerate exactly the power-of-two divisions of
the pod; partition_indices is a disjoint ordered cover (the device-set
invariant behind ``container_meshes``, pinned without needing devices);
feasible_counts is memory-bound *monotone* (more containers → more bytes
per chip, so feasibility is a prefix of the powers of two); and the pool's
reorder-then-splice combination restores request order no matter what
order each container finishes its segment in.

Skipped (by conftest) when hypothesis isn't installed — it lives in the
``dev`` extra, so the CI no-hypothesis job stays green by skip.
"""
from __future__ import annotations

import pytest

# conftest's source-grep skip covers discovery runs; this covers the file
# being named explicitly on the pytest command line (e.g. the CI lane)
pytest.importorskip("hypothesis")

from hypothesis import given, settings      # noqa: E402
from hypothesis import strategies as st     # noqa: E402

from repro.configs.registry import get_config                   # noqa: E402
from repro.core import splitter                                 # noqa: E402
from repro.core.containers import (factorizations,              # noqa: E402
                                   feasible_counts,
                                   partition_indices)

CFG = get_config("qwen3-0.6b-reduced")


@given(st.integers(0, 10), st.one_of(st.none(), st.integers(1, 2048)))
@settings(max_examples=200, deadline=None)
def test_factorizations_enumerate_powers_of_two(k, max_containers):
    total = 2 ** k
    specs = factorizations(total, max_containers)
    want = [n for n in (2 ** i for i in range(k + 1))
            if max_containers is None or n <= max_containers]
    assert [s.n_containers for s in specs] == want
    for s in specs:
        assert s.total_chips == total
        assert s.n_containers * s.chips_per_container == total
        assert s.mesh_shape == (s.n_containers, s.chips_per_container)


@given(st.integers(0, 10), st.integers(0, 10))
@settings(max_examples=200, deadline=None)
def test_partition_indices_disjoint_ordered_cover(k, j):
    total, n = 2 ** k, 2 ** min(j, k)
    parts = partition_indices(total, n)
    assert len(parts) == n
    # concatenating the parts in container order gives back the pod's
    # device indices exactly once each: disjoint, covering, contiguous
    assert [i for part in parts for i in part] == list(range(total))
    assert {len(part) for part in parts} == {total // n}


@given(st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_partition_rejects_indivisible_counts(n):
    total = 96
    if total % n == 0:
        assert len(partition_indices(total, n)) == n
    else:
        with pytest.raises(ValueError):
            partition_indices(total, n)


@given(st.floats(min_value=1e3, max_value=1e15,
                 allow_nan=False, allow_infinity=False),
       st.integers(0, 8),
       st.floats(min_value=0.0, max_value=0.9,
                 allow_nan=False, allow_infinity=False))
@settings(max_examples=200, deadline=None)
def test_feasible_counts_memory_bound_monotone(hbm, k, headroom):
    """Per-chip weight bytes grow with n (fewer chips per container), so
    feasibility is downward-closed: the feasible counts are exactly the
    first len(counts) powers of two."""
    total = 2 ** k
    counts = feasible_counts(CFG, total, hbm_bytes=hbm,
                             activation_headroom=headroom)
    assert counts == [2 ** i for i in range(len(counts))]
    assert all(c <= total for c in counts)


@given(st.integers(0, 120), st.integers(1, 8), st.randoms())
@settings(max_examples=100, deadline=None)
def test_split_serve_combine_order_roundtrip(n_items, n, rnd):
    """The pool's combination step (reorder each container's completions
    by its segment's submission order, then splice segments with the
    splitter) restores the original request order regardless of the order
    each container finished in — serve is order-invisible."""
    rids = list(range(n_items))
    segments = splitter.split(rids, n)
    served_segments = []
    for seg in segments:
        finish_order = list(seg)
        rnd.shuffle(finish_order)                   # container finish order
        completions = {rid: (rid, pos)              # (rid, completion slot)
                       for pos, rid in enumerate(finish_order)}
        served_segments.append([completions[rid] for rid in seg])
    combined = splitter.combine(served_segments)
    assert [rid for rid, _ in combined] == rids
