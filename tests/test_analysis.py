"""Static-analysis suite (repro.analysis): every analyzer runs clean on
the repo as it stands, AND catches a seeded violation — the second half
is what makes the first half evidence instead of vacuity."""
from __future__ import annotations

import json
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import ANALYZERS, run_analyzers
from repro.analysis.report import (Finding, Report, apply_suppressions,
                                   line_suppressed)
from repro.core.hlo_analysis import parse_donation

REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# report plumbing
# ---------------------------------------------------------------------------
def test_report_json_shape():
    rep = Report()
    rep.analyzers_run.append("donation")
    rep.extend([Finding("donation", "DON001", "x/y", "msg")])
    data = json.loads(rep.to_json())
    assert data["schema"] == 1
    assert data["counts"] == {"errors": 1, "warnings": 0}
    assert data["findings"][0]["code"] == "DON001"
    assert not rep.ok


def test_line_suppression_same_line_and_above():
    lines = ["a = 1", "x = sync()  # analysis: allow(host-sync)",
             "# analysis: allow(concurrency)", "y = 2"]
    assert line_suppressed(lines, 2, "host-sync")
    assert not line_suppressed(lines, 2, "concurrency")
    assert line_suppressed(lines, 4, "concurrency")
    assert not line_suppressed(lines, 1, "host-sync")


def test_code_suppression():
    fs = [Finding("kernels", "KRN002", "a", "m"),
          Finding("kernels", "KRN004", "b", "m")]
    assert [f.code for f in apply_suppressions(fs, ["KRN002"])] \
        == ["KRN004"]


def test_unknown_analyzer_rejected():
    with pytest.raises(KeyError):
        run_analyzers(["not-an-analyzer"])


# ---------------------------------------------------------------------------
# donation auditor
# ---------------------------------------------------------------------------
def test_donation_audit_clean_all_families():
    from repro.analysis import donation
    findings = donation.run()
    assert findings == [], "\n".join(map(str, findings))


def test_donation_catches_silent_copy():
    """A donated operand whose buffer cannot be reused (shape-changing
    slice) lowers WITHOUT an aliasing marker — the exact silent-copy
    the auditor exists to flag."""
    from repro.analysis.donation import _check
    buf = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    with pytest.warns(UserWarning, match="donated"):
        low = jax.jit(lambda b: b[:1, :4] * 2.0,
                      donate_argnums=0).lower(buf)
    findings = _check("seed/silent-copy", low, buf)
    assert [f.code for f in findings] == ["DON001"]


def test_donation_catches_alias_on_pure_read():
    low = jax.jit(lambda b: b + 1.0, donate_argnums=0).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    from repro.analysis.donation import _check
    findings = _check("seed/aliased-read", low, None, expect_none=True)
    assert [f.code for f in findings] == ["DON002"]


# ---------------------------------------------------------------------------
# host-sync auditor
# ---------------------------------------------------------------------------
def test_host_sync_clean():
    from repro.analysis import host_sync
    findings = host_sync.run()
    assert findings == [], "\n".join(map(str, findings))


def test_host_sync_catches_stray_device_get(tmp_path):
    from repro.analysis import host_sync
    engine_src = textwrap.dedent("""
        import jax
        class ServingEngine:
            def step(self):
                self._decode_chunk()
                self._collect()
            def _decode_chunk(self):
                block, emitted = jax.device_get((1, 2))
                return block
            def _collect(self):
                stats = jax.device_get(self.window)   # stray sync
                return stats
    """)
    cache_src = "class DenseCache:\n    pass\nclass PagedCache:\n    pass\n"
    ep = tmp_path / "engine.py"
    cp = tmp_path / "cache.py"
    ep.write_text(engine_src)
    cp.write_text(cache_src)
    findings = host_sync.run(ep, cp)
    assert [f.code for f in findings] == ["SYN001"]
    assert "_collect" in findings[0].message

    # the same sync under an allow marker passes
    ep.write_text(engine_src.replace(
        "jax.device_get(self.window)   # stray sync",
        "jax.device_get(self.window)  # analysis: allow(host-sync)"))
    assert host_sync.run(ep, cp) == []


def test_host_sync_budget_is_exact():
    """Two device_gets in _decode_chunk (allowance: one) is a finding."""
    import textwrap as tw

    from repro.analysis import host_sync
    src = tw.dedent("""
        import jax
        class ServingEngine:
            def step(self):
                self._decode_chunk()
            def _decode_chunk(self):
                a = jax.device_get(1)
                b = jax.device_get(2)
                return a, b
    """)
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        ep = pathlib.Path(d, "engine.py")
        cp = pathlib.Path(d, "cache.py")
        ep.write_text(src)
        cp.write_text("class DenseCache: pass\nclass PagedCache: pass\n")
        findings = host_sync.run(ep, cp)
    assert [f.code for f in findings] == ["SYN001"]


# ---------------------------------------------------------------------------
# compile-key enumerator
# ---------------------------------------------------------------------------
def test_compile_keys_clean_and_bounded():
    from repro.analysis import compile_keys
    findings = compile_keys.run()
    assert findings == [], "\n".join(map(str, findings))
    counts = compile_keys.count_keys()
    assert sum(counts.values()) <= compile_keys.DEFAULT_BUDGET
    assert set(counts) == compile_keys.KNOWN_KINDS


def test_compile_keys_catches_unmodelled_kind(tmp_path):
    from repro.analysis import compile_keys
    src = textwrap.dedent("""
        class ServingEngine:
            def _decode_chunk(self):
                n_tokens = 1 << (4).bit_length() - 1
                key = ("chunk", n_tokens)
                if key not in self._jits:
                    pass
                return self._jits[key]
            def _novel(self, n):
                key = ("per_prompt_exact", n)
                return self._jits[key]
    """)
    ep = tmp_path / "engine.py"
    cp = tmp_path / "cache.py"
    ep.write_text(src)
    cp.write_text("")
    findings = compile_keys.run(ep, cp)
    assert [f.code for f in findings] == ["KEY001"]
    assert "per_prompt_exact" in findings[0].message


def test_compile_keys_catches_lost_pow2_rounding(tmp_path):
    from repro.analysis import compile_keys
    src = textwrap.dedent("""
        class ServingEngine:
            def _decode_chunk(self, exact):
                n_tokens = exact          # "use the exact clamp"
                key = ("chunk", n_tokens)
                return self._jits[key]
    """)
    ep = tmp_path / "engine.py"
    cp = tmp_path / "cache.py"
    ep.write_text(src)
    cp.write_text("")
    findings = compile_keys.run(ep, cp)
    assert "KEY003" in [f.code for f in findings]


# ---------------------------------------------------------------------------
# Pallas kernel checkers
# ---------------------------------------------------------------------------
def test_kernel_checks_clean():
    from repro.analysis import kernels
    findings = kernels.run()
    assert findings == [], "\n".join(map(str, findings))


def _toy_spec(block, index_map, shape=(8, 128), grid=(2,)):
    import types

    from repro.analysis.kernels import KernelSpec
    aval = jax.ShapeDtypeStruct(shape, jnp.float32)
    bs = types.SimpleNamespace(block_shape=block, index_map=index_map)
    out_bs = types.SimpleNamespace(block_shape=block, index_map=index_map)
    return KernelSpec(name="toy", grid=grid, in_specs=[bs],
                      out_specs=[out_bs], scratch_shapes=[],
                      num_scalar_prefetch=0, prefetch_args=[],
                      operands=[aval], out_shapes=[aval])


def test_kernel_check_catches_oob_index_map():
    from repro.analysis.kernels import check_spec
    spec = _toy_spec((4, 128), lambda i: (i + 1, 0))   # last block OOB
    assert "KRN004" in [f.code for f in check_spec(spec)]


def test_kernel_check_catches_non_dividing_block():
    from repro.analysis.kernels import check_spec
    spec = _toy_spec((3, 128), lambda i: (i, 0))       # 3 does not divide 8
    assert "KRN002" in [f.code for f in check_spec(spec)]


def test_kernel_check_passes_valid_spec():
    from repro.analysis.kernels import check_spec
    spec = _toy_spec((4, 128), lambda i: (i, 0))
    assert check_spec(spec) == []


# ---------------------------------------------------------------------------
# concurrency lint
# ---------------------------------------------------------------------------
def test_concurrency_clean():
    from repro.analysis import concurrency
    findings = concurrency.run()
    assert findings == [], "\n".join(map(str, findings))


def test_concurrency_catches_cross_thread_write(tmp_path):
    from repro.analysis import concurrency
    bad = textwrap.dedent("""
        import threading
        class Pool:
            def start(self):
                threading.Thread(target=self._pump, daemon=True).start()
            def _pump(self):
                self.alive = True
            def stop(self):
                self.alive = False
            def fan(self):
                for i in range(3):
                    t = threading.Thread(target=self._work)
                    t.start()
                    t.join()
            def _work(self):
                self.count += 1
    """)
    p = tmp_path / "bad.py"
    p.write_text(bad)
    codes = sorted({f.code for f in concurrency.run((p,))})
    assert codes == ["CON001", "CON002"]


def test_concurrency_respects_lock_and_suppression(tmp_path):
    from repro.analysis import concurrency
    good = textwrap.dedent("""
        import threading
        class Pool:
            def start(self):
                threading.Thread(target=self._pump, daemon=True).start()
            def _pump(self):
                with self._lock:
                    self.alive = True
            def stop(self):
                with self._lock:
                    self.alive = False
            def mark(self):
                self.seen = True  # analysis: allow(concurrency)
            def bg(self):
                threading.Thread(target=self._set).start()
            def _set(self):
                self.seen = False  # analysis: allow(concurrency)
    """)
    p = tmp_path / "good.py"
    p.write_text(good)
    assert concurrency.run((p,)) == []


# ---------------------------------------------------------------------------
# wire: pre-affinity imports + pipe picklability
# ---------------------------------------------------------------------------
def test_wire_clean():
    from repro.analysis import wire
    findings = wire.run()
    assert findings == [], "\n".join(map(str, findings))


def test_wire_catches_module_scope_jax(tmp_path, monkeypatch):
    import repro.analysis.wire as wire
    pkg = tmp_path / "repro" / "fake"
    pkg.mkdir(parents=True)
    (tmp_path / "repro" / "__init__.py").write_text("")
    (pkg / "__init__.py").write_text("")
    (pkg / "leaf.py").write_text("import jax\n")
    (pkg / "root.py").write_text("from repro.fake import leaf\n")
    monkeypatch.setattr(wire, "_SRC", tmp_path)
    findings = wire._closure_findings("repro.fake.root")
    assert [f.code for f in findings] == ["WIR001"]
    assert "leaf.py" in findings[0].location


def test_wire_function_local_import_is_fine(tmp_path, monkeypatch):
    import repro.analysis.wire as wire
    pkg = tmp_path / "repro" / "fake"
    pkg.mkdir(parents=True)
    (tmp_path / "repro" / "__init__.py").write_text("")
    (pkg / "__init__.py").write_text("")
    (pkg / "root.py").write_text(
        "def body():\n    import jax\n    return jax\n")
    monkeypatch.setattr(wire, "_SRC", tmp_path)
    assert wire._closure_findings("repro.fake.root") == []


def test_wire_catches_unpicklable_dataclass():
    import dataclasses

    import repro.analysis.wire as wire

    @dataclasses.dataclass
    class Bad:
        fn: object = lambda: None      # local lambda: not picklable

    inst = wire._dummy_instance(Bad)
    import pickle
    with pytest.raises(Exception):
        pickle.dumps(inst)


def test_child_module_is_import_light():
    """The spawn payload's import closure must load with jax blocked —
    this is the property that keeps XLA's threadpool sized from the
    child's cpuset (regression: _serving_child used to live in
    backend.py, whose module scope imports the engine and hence jax)."""
    script = textwrap.dedent("""
        import importlib.abc, sys
        class Blk(importlib.abc.MetaPathFinder):
            def find_spec(self, name, path, target=None):
                if name.split(".")[0] in ("jax", "jaxlib"):
                    raise ImportError("jax imported pre-affinity")
        sys.meta_path.insert(0, Blk())
        import pickle
        import repro.serving.child as child
        import repro.core.testbed as testbed
        assert pickle.dumps(child._serving_child)
        assert pickle.dumps(testbed._pinned_main)
        print("import-light ok")
    """)
    env_src = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin"})
    assert out.returncode == 0, out.stderr
    assert "import-light ok" in out.stdout


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_cli_report_and_exit_codes(tmp_path, capsys):
    from repro.analysis.cli import main
    report = tmp_path / "report.json"
    rc = main(["--only", "compile-keys", "--only", "concurrency",
               "--report", str(report)])
    assert rc == 0
    data = json.loads(report.read_text())
    assert data["analyzers_run"] == ["compile-keys", "concurrency"]
    assert data["counts"]["errors"] == 0
    assert main(["--list"]) == 0
    out = capsys.readouterr().out
    for name in ANALYZERS:
        assert name in out


def test_cli_rejects_unknown_analyzer():
    from repro.analysis.cli import main
    assert main(["--only", "nope"]) == 2
