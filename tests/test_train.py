"""Training loop, optimizer, checkpointing, data pipeline."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.pipeline import LmTokenStream
from repro.models.model import Model
from repro.train import checkpoint
from repro.train.loop import TrainConfig, train
from repro.train.optimizer import (AdamWConfig, apply_update, init_opt_state,
                                   schedule)


def test_loss_decreases_over_short_run():
    cfg = get_config("qwen3-0.6b-reduced")
    model = Model(cfg)
    stream = LmTokenStream(cfg.vocab_size, seq_len=32, batch_size=8)
    tcfg = TrainConfig(opt=AdamWConfig(lr=3e-3, warmup_steps=5,
                                       total_steps=60))
    _, _, hist = train(model, tcfg, stream.batches(), n_steps=60,
                       log_every=59)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert last < first * 0.8, (first, last)


def test_microbatched_grads_match_full_batch():
    cfg = get_config("stablelm-1.6b-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                          cfg.vocab_size)}
    from repro.train.loop import make_train_step
    opt = init_opt_state(params)
    p1, _, m1 = jax.jit(make_train_step(model, TrainConfig()))(params, opt,
                                                               batch)
    p2, _, m2 = jax.jit(make_train_step(
        model, TrainConfig(microbatches=2)))(params, opt, batch)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p1, p2)
    assert max(jax.tree.leaves(diffs)) < 2e-5
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    end = float(schedule(cfg, jnp.asarray(110)))
    assert end == pytest.approx(0.1, abs=1e-3)


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1e-2, grad_clip=1.0, weight_decay=0.0,
                      warmup_steps=0)
    params = {"w": jnp.ones((4, 4))}
    huge = {"w": jnp.full((4, 4), 1e6)}
    state = init_opt_state(params)
    _, state, metrics = apply_update(cfg, params, huge, state)
    assert float(metrics["grad_norm"]) > 1e5
    # post-clip first moment is bounded by (1-b1)·clip
    assert float(jnp.max(jnp.abs(state["m"]["w"]))) <= 0.11


def test_weight_decay_only_on_matrices():
    cfg = AdamWConfig(lr=1e-2, weight_decay=1.0, warmup_steps=0)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    zeros = jax.tree.map(jnp.zeros_like, params)
    state = init_opt_state(params)
    new, _, _ = apply_update(cfg, params, zeros, state)
    assert float(jnp.max(new["w"])) < 1.0     # decayed
    np.testing.assert_allclose(new["b"], params["b"])  # not decayed


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("qwen3-0.6b-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    path = os.path.join(tmp_path, "ck")
    checkpoint.save(path, params, meta={"step": 17})
    restored = checkpoint.restore(path, jax.tree.map(
        lambda a: jnp.zeros_like(a), params))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, restored)
    assert checkpoint.load_meta(path)["step"] == 17


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    path = os.path.join(tmp_path, "ck2")
    checkpoint.save(path, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        checkpoint.restore(path, {"w": jnp.ones((3, 3))})


def test_lm_stream_deterministic_and_shaped():
    s = LmTokenStream(vocab_size=100, seq_len=32, batch_size=4, seed=9)
    b1, b2 = s.batch(5), s.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert b1["tokens"].max() < 100
    assert s.batch(6)["tokens"].tolist() != b1["tokens"].tolist()
