"""Request-level streaming serving: Router + ContainerBackend protocol.

The acceptance harness for the streaming redesign: concatenating a
handle's streamed ``ChunkEvent`` tokens must bit-match the blocking
``run()`` output for greedy decode — across model families and across
all three backends (thread, process, submesh) — plus event-ordering,
dispatch, windowed-adaptation and close-mid-stream behaviour. The
process-backend cases pay spawn+compile and are marked ``slow`` (the
streaming CI lane runs this module in full).
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import numpy as np
import pytest

try:
    HOST_CORES = len(os.sched_getaffinity(0))
except AttributeError:              # non-Linux dev host
    HOST_CORES = os.cpu_count() or 1

# process containers need pairwise-disjoint cpusets; tests that pin two
# real containers cannot run (rather than silently share cores) on a
# single-core host/CI runner
needs_two_cores = pytest.mark.skipif(
    HOST_CORES < 2, reason="needs >=2 cores for disjoint container "
                           f"cpusets (host exposes {HOST_CORES})")

from repro.serving import (ChunkEvent, ContainerBackend, DoneEvent,
                           ProcessBackend, Request, Router, ServingEngine,
                           SubmeshBackend, ThreadBackend)

# one representative per model-family decode path (whisper needs audio
# extras, so the encoder-decoder family is covered by test_decode_chunk)
STREAM_ARCHS = [
    "qwen3-0.6b",        # dense
    "gemma3-27b",        # sliding-window attention (unpadded admission)
    "mamba2-2.7b",       # ssm (unpadded admission, recurrent cache)
]


def _requests(cfg, plens_max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, (plen,),
                                        dtype=np.int32),
                    max_new_tokens=mn)
            for i, (plen, mn) in enumerate(plens_max_new)]


def _clone(reqs):
    return [Request(r.rid, r.prompt.copy(), r.max_new_tokens)
            for r in reqs]


def _blocking_tokens(model, params, reqs):
    eng = ServingEngine(model, params, n_slots=2, max_len=64)
    eng.submit_many(_clone(reqs))
    return {c.rid: list(c.tokens) for c in eng.run()}


def _streamed_tokens(router, reqs):
    """Submit everything, then consume each handle's stream; returns
    (concat tokens per rid, completion tokens per rid, events per rid)."""
    handles = [router.submit(r) for r in _clone(reqs)]
    concat, comp, events = {}, {}, {}
    for h in handles:
        evs = list(h.stream())
        events[h.rid] = evs
        concat[h.rid] = [t for ev in evs[:-1] for t in ev.tokens]
        comp[h.rid] = list(evs[-1].completion.tokens)
    return concat, comp, events


# ---------------------------------------------------------------------------
# stream == blocking run(), per family, thread backend
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", STREAM_ARCHS)
def test_stream_concat_bitmatches_blocking_run(arch, reduced_models):
    model, params = reduced_models[arch]
    reqs = _requests(model.cfg, [(6, 3), (9, 4), (5, 2), (7, 3), (6, 1)],
                     seed=1)
    want = _blocking_tokens(model, params, reqs)

    with Router(ThreadBackend(model, params, 2, n_slots_per_container=2,
                              max_len=64)) as router:
        concat, comp, events = _streamed_tokens(router, reqs)
    assert concat == want, f"{arch}: streamed chunks diverge from run()"
    assert comp == want, f"{arch}: DoneEvent completion diverges"
    for rid, evs in events.items():
        # ordering: every chunk strictly before the one terminal event
        assert all(isinstance(e, ChunkEvent) for e in evs[:-1])
        assert isinstance(evs[-1], DoneEvent)
        assert all(e.rid == rid for e in evs)
        stamps = [e.time_s for e in evs]
        assert stamps == sorted(stamps)


def test_stream_interleaved_submission_matches_batch(reduced_models):
    """Continuous admission: submitting while earlier requests are
    mid-decode must not change any request's tokens."""
    model, params = reduced_models["qwen3-0.6b"]
    reqs = _requests(model.cfg, [(6, 4), (8, 3), (5, 4), (7, 2)], seed=3)
    want = _blocking_tokens(model, params, reqs)

    with Router(ThreadBackend(model, params, 2, n_slots_per_container=2,
                              max_len=64)) as router:
        h0 = router.submit(_clone(reqs)[0])
        router.poll()                       # first request starts decoding
        rest = [router.submit(r) for r in _clone(reqs)[1:]]
        got = {h.rid: h.tokens() for h in [h0, *rest]}
    assert got == want


def test_time_to_first_chunk_recorded(reduced_models):
    model, params = reduced_models["qwen3-0.6b"]
    reqs = _requests(model.cfg, [(6, 3), (7, 2)], seed=5)
    with Router(ThreadBackend(model, params, 1, n_slots_per_container=2,
                              max_len=64)) as router:
        handles = [router.submit(r) for r in _clone(reqs)]
        router.drain()
        for h in handles:
            assert h.done
            assert h.ttfc_s is not None and 0 < h.ttfc_s < 600.0


def test_zero_budget_request_streams_done_only(reduced_models):
    """A max_new_tokens<=0 request completes empty: its stream is exactly
    one DoneEvent, no chunks, and neighbours are unaffected."""
    model, params = reduced_models["qwen3-0.6b"]
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, model.cfg.vocab_size, (5,), dtype=np.int32)
    with Router(ThreadBackend(model, params, 1, n_slots_per_container=2,
                              max_len=64)) as router:
        h = router.submit(Request(rid=0, prompt=prompt, max_new_tokens=0))
        evs = list(h.stream())
    assert len(evs) == 1 and isinstance(evs[0], DoneEvent)
    assert evs[0].completion.tokens == []
    assert h.ttfc_s is None


# ---------------------------------------------------------------------------
# wave shim
# ---------------------------------------------------------------------------
def test_router_wave_shim_matches_pool_contract(reduced_models):
    """serve_wave = submit-all + drain: same completions as the blocking
    engine, submission order preserved, per-container accounting present
    (assemble_wave reconstruction)."""
    model, params = reduced_models["qwen3-0.6b"]
    reqs = _requests(model.cfg, [(6, 3)] * 6, seed=9)
    want = _blocking_tokens(model, params, reqs)
    with Router(ThreadBackend(model, params, 2, n_slots_per_container=2,
                              max_len=64)) as router:
        ordered, per, wall, energy = router.serve_wave(_clone(reqs))
    assert [c.rid for c in ordered] == [r.rid for r in reqs]
    assert {c.rid: list(c.tokens) for c in ordered} == want
    assert wall > 0 and energy > 0
    assert len(per) == 2
    assert sum(r.n_requests for r in per) == len(reqs)
    for r in per:
        assert r.busy_s > 0 and r.energy_j > 0


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------
class ScriptedBackend:
    """Minimal in-memory ContainerBackend: each request completes with
    one chunk after ``delay_polls`` polls — deterministic substrate for
    dispatch and windowing tests (the streaming analogue of
    SyntheticContainerPool)."""

    def __init__(self, capacity: int, delay_polls: int = 1):
        self.capacity = capacity
        self.delay = delay_polls
        self._inflight: list[list] = [[] for _ in range(capacity)]
        self._stats = [(0.0, 0)] * capacity
        self.closed = False

    def submit(self, cid, req):
        self._inflight[cid].append([req, self.delay])

    def submit_many(self, cid, reqs):
        for r in reqs:
            self.submit(cid, r)

    def poll(self):
        out = []
        now = time.perf_counter()
        for cid, flight in enumerate(self._inflight):
            keep = []
            for entry in flight:
                req, left = entry
                if left > 1:
                    entry[1] = left - 1
                    keep.append(entry)
                    continue
                toks = tuple(range(req.max_new_tokens))
                busy, ntok = self._stats[cid]
                self._stats[cid] = (busy + 1e-4, ntok + len(toks))
                out.append(ChunkEvent(req.rid, cid, toks, now))
                from repro.serving.engine import Completion
                out.append(DoneEvent(req.rid, cid,
                                     Completion(req.rid, list(toks),
                                                len(req.prompt), 1e-4),
                                     now))
            self._inflight[cid] = keep
        return out

    def load(self, cid):
        return len(self._inflight[cid])

    def stats(self, cid):
        return self._stats[cid]

    def drain(self, concurrent=True):
        raise NotImplementedError

    def close(self):
        self.closed = True


def _req(rid, plen=6, max_new=2):
    return Request(rid=rid, prompt=np.zeros((plen,), np.int32),
                   max_new_tokens=max_new)


def test_scripted_backend_satisfies_protocol():
    # the protocol is structural: any object with the right methods is a
    # ContainerBackend — including test substrates like ScriptedBackend
    assert isinstance(ScriptedBackend(2), ContainerBackend)


def test_dispatch_least_loaded_then_bucket_aware():
    """Dispatch fills the least-loaded container first; among equal loads
    it prefers the container already holding the request's prompt-length
    bucket (those prefill together as one batch)."""
    backend = ScriptedBackend(2, delay_polls=1000)   # nothing completes
    router = Router(backend)
    a1 = router.submit(_req(0, plen=6))     # bucket 16 → cid 0 (all empty)
    b1 = router.submit(_req(1, plen=30))    # bucket 32 → cid 1 (least)
    a2 = router.submit(_req(2, plen=7))     # loads tie → bucket 16 → cid 0
    b2 = router.submit(_req(3, plen=20))    # loads 2/1 → cid 1 anyway
    b3 = router.submit(_req(4, plen=25))    # loads tie → bucket 32 → cid 1
    assert [h.container_id for h in (a1, b1, a2, b2, b3)] == [0, 1, 0, 1, 1]
    router.close()                          # drop without draining


def test_duplicate_rid_rejected():
    router = Router(ScriptedBackend(1, delay_polls=1000))
    router.submit(_req(0))
    with pytest.raises(ValueError, match="already in flight"):
        router.submit(_req(0))
    router.close()


# ---------------------------------------------------------------------------
# windowed adaptation
# ---------------------------------------------------------------------------
def test_windowed_scheduler_resizes_between_windows():
    """With a backend_factory the Router closes the online loop at window
    granularity: every `window` completions it records WindowStats,
    feeds the scheduler, and swaps to the picked count's (cached, warm)
    backend before admitting the next window."""
    built = []

    def factory(n):
        built.append(n)
        return ScriptedBackend(n)

    router = Router(backend_factory=factory, feasible_counts=[1, 2, 4],
                    window=4, epsilon=0.0)
    rid = 0
    for _ in range(5):                       # 5 windows of 4 requests
        handles = []
        for _ in range(4):
            handles.append(router.submit(_req(rid, max_new=3)))
            rid += 1
        router.drain()
    assert len(router.history) == 5
    for w in router.history:
        assert w.n_requests == 4 and w.n_tokens == 12
        assert w.n_containers in (1, 2, 4)
        assert w.wall_s > 0 and w.energy_j > 0
        assert w.tokens_per_s > 0
    # the scheduler saw one observation per window
    assert router.scheduler.n_observations == 5
    # bootstrap explores distinct counts, and each count's backend was
    # built exactly once (cached + reused across windows)
    assert len(built) == len(set(built))
    assert len(set(w.n_containers for w in router.history)) >= 3
    assert router.backend.capacity in (1, 2, 4)
    backends = list(router._backends.values())
    router.close()
    assert backends and all(b.closed for b in backends)


def test_resize_deferred_while_requests_in_flight():
    """A window boundary must not strand a mid-stream request: the swap
    waits until the stream drains."""
    built = []

    def factory(n):
        built.append(n)
        return ScriptedBackend(n, delay_polls=3)

    router = Router(backend_factory=factory, feasible_counts=[1, 2],
                    window=2, epsilon=0.0)
    before = router.backend
    h1, h2 = router.submit(_req(0)), router.submit(_req(1))
    h3 = router.submit(_req(2))              # still in flight at boundary
    # pump: h1..h3 complete on the same poll (same delay), so the window
    # rotates only once nothing is in flight
    router.drain()
    assert h1.done and h2.done and h3.done
    assert len(router.history) == 1          # one window, 3 completions
    assert router.history[0].n_requests == 3
    assert router.backend is not before or len(built) == 1
    router.close()


# ---------------------------------------------------------------------------
# close-mid-stream
# ---------------------------------------------------------------------------
def test_close_mid_stream_raises_instead_of_hanging(reduced_models):
    model, params = reduced_models["qwen3-0.6b"]

    def tiny_chunks(model, params, **kw):
        # one decode token per macro-step, so the request is guaranteed
        # to still be mid-stream when the router closes
        return ServingEngine(model, params, chunk_tokens=1, **kw)

    router = Router(ThreadBackend(model, params, 1,
                                  n_slots_per_container=2, max_len=64,
                                  engine_factory=tiny_chunks))
    h = router.submit(Request(rid=0,
                              prompt=np.arange(6, dtype=np.int32),
                              max_new_tokens=50))
    stream = h.stream()
    first = next(stream)                     # at least one chunk arrived
    assert isinstance(first, ChunkEvent)
    router.close()
    with pytest.raises(RuntimeError, match="closed"):
        for _ in stream:
            pass
    with pytest.raises(RuntimeError, match="closed"):
        router.submit(_req(1))


def test_consumed_handle_does_not_hang(reduced_models):
    """Regression: result()/tokens()/a second stream() on a handle whose
    stream was already consumed must return immediately (the completion
    is kept on the handle), not pump an idle backend forever."""
    model, params = reduced_models["qwen3-0.6b"]
    with Router(ThreadBackend(model, params, 1, n_slots_per_container=2,
                              max_len=64)) as router:
        h = router.submit(Request(rid=0,
                                  prompt=np.arange(6, dtype=np.int32),
                                  max_new_tokens=3))
        evs = list(h.stream())
        assert isinstance(evs[-1], DoneEvent)
        assert list(h.stream()) == []        # consumed: yields nothing
        assert h.result() is h.completion    # and result() returns now
        assert h.tokens() == list(h.completion.tokens)


def test_streamed_completions_do_not_accumulate(reduced_models):
    """Regression: poll-driven serving must drain each engine's done
    list (DoneEvents carry the completions) — a long-lived stream would
    otherwise grow one Completion per request forever, and a later wave
    drain() would return the stale backlog into its accounting."""
    model, params = reduced_models["qwen3-0.6b"]
    backend = ThreadBackend(model, params, 2, n_slots_per_container=2,
                            max_len=64)
    with Router(backend) as router:
        for base in (0, 10):
            handles = [router.submit(Request(
                rid=base + i, prompt=np.arange(6, dtype=np.int32) + i,
                max_new_tokens=2)) for i in range(4)]
            router.drain()
            assert all(h.done for h in handles)
        assert all(eng.done == [] for eng in backend.engines)
        # and the fixed-mode router itself retains nothing per request
        # (window accumulators exist only to feed a scheduler)
        assert router._window_done == [] and router._window_ttfc == []
        assert router._handles == {} and router._submit_t == {}
        # a wave through the shim right after streaming sees ONLY its own
        # completions, not the streamed backlog
        reqs = _requests(model.cfg, [(6, 2)] * 4, seed=17)
        out = backend.drain()
        assert all(comps == [] for comps, *_ in out)  # nothing stale
        for cid in range(2):
            backend.submit_many(cid, [reqs[2 * cid], reqs[2 * cid + 1]])
        out = backend.drain()
        assert sorted(c.rid for comps, *_ in out for c in comps) == \
            [r.rid for r in reqs]


def test_wave_shim_per_container_wall_is_container_local(reduced_models):
    """Regression: serve_wave must report each container's own wall
    (submit → its last completion), not the slowest sibling's — a wave
    where one container serves everything must not deflate the idle
    container's throughput accounting."""
    model, params = reduced_models["qwen3-0.6b"]
    with Router(ThreadBackend(model, params, 2, n_slots_per_container=2,
                              max_len=64)) as router:
        # 2 slots per container: two same-bucket requests land on cid 0
        # and cid 1 stays idle (least-loaded alternates, so use 2 reqs
        # and check walls individually)
        ordered, per, wall, _ = router.serve_wave(
            _requests(model.cfg, [(6, 3), (6, 3)], seed=19))
    assert len(ordered) == 2
    for r in per:
        assert r.wall_s <= wall + 1e-6
        if r.n_requests == 0:
            assert r.wall_s == 0.0 and r.tokens_per_s == 0.0


def test_stream_engine_error_propagates(reduced_models):
    """An engine failure mid-stream must surface as the original
    exception at the consumer's next pump — never a silent hang."""
    model, params = reduced_models["qwen3-0.6b"]

    class Boom(ServingEngine):
        def step(self):
            raise RuntimeError("boom mid-stream")

    router = Router(ThreadBackend(model, params, 2,
                                  n_slots_per_container=2, max_len=64,
                                  engine_factory=Boom))
    h = router.submit(_req(0))
    with pytest.raises(RuntimeError, match="boom mid-stream"):
        for _ in h.stream():
            pass


# ---------------------------------------------------------------------------
# process backend (spawn cost: slow; the streaming CI lane runs these)
# ---------------------------------------------------------------------------
@pytest.mark.slow
@needs_two_cores
def test_process_backend_stream_bitmatches_blocking(reduced_models):
    model, params = reduced_models["qwen3-0.6b"]
    cfg = model.cfg
    reqs = _requests(cfg, [(6, 3), (9, 4), (5, 2), (7, 3)], seed=11)
    want = _blocking_tokens(model, params, reqs)
    with Router(ProcessBackend(cfg, 2, n_slots_per_container=2,
                               max_len=64, params_seed=0)) as router:
        concat, comp, events = _streamed_tokens(router, reqs)
        assert concat == want
        assert comp == want
        for evs in events.values():
            assert isinstance(evs[-1], DoneEvent)
            assert all(isinstance(e, ChunkEvent) for e in evs[:-1])
        # warm children: a second streamed round bit-matches too
        reqs2 = [Request(r.rid + 100, r.prompt.copy(), r.max_new_tokens)
                 for r in reqs]
        handles = [router.submit(r) for r in reqs2]
        got2 = {h.rid - 100: h.tokens() for h in handles}
        assert got2 == want


@pytest.mark.slow
def test_process_backend_close_mid_stream(reduced_models):
    """Closing the router while a process container is mid-stream shuts
    the children down promptly (the child checks its pipe between steps)
    and the abandoned stream raises instead of hanging."""
    model, _ = reduced_models["qwen3-0.6b"]
    router = Router(ProcessBackend(model.cfg, 1, n_slots_per_container=2,
                                   max_len=64, params_seed=0,
                                   chunk_tokens=1))
    h = router.submit(Request(rid=0,
                              prompt=np.arange(6, dtype=np.int32),
                              max_new_tokens=40))
    stream = h.stream()
    assert isinstance(next(stream), ChunkEvent)
    procs = [proc for proc, _ in router.backend.workers]
    router.close()
    for proc in procs:
        proc.join(timeout=15)
        assert not proc.is_alive()
    with pytest.raises(RuntimeError, match="closed"):
        for _ in stream:
            pass


# ---------------------------------------------------------------------------
# submesh backend (needs a multi-device pod; the CI multidevice lane)
# ---------------------------------------------------------------------------
needs_pod = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs >=8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")


@needs_pod
def test_submesh_backend_stream_bitmatches_blocking(reduced_models):
    from repro.launch.mesh import make_container_meshes
    model, params = reduced_models["qwen3-0.6b"]
    reqs = _requests(model.cfg, [(6, 3), (9, 4), (5, 2), (7, 3), (6, 2)],
                     seed=13)
    want = _blocking_tokens(model, params, reqs)
    backend = SubmeshBackend(model, params, 2, n_slots_per_container=2,
                             max_len=64,
                             meshes=make_container_meshes(8, 2))
    with Router(backend) as router:
        concat, comp, _ = _streamed_tokens(router, reqs)
    assert concat == want
    assert comp == want


@needs_pod
def test_submesh_backend_requires_meshes(reduced_models):
    model, params = reduced_models["qwen3-0.6b"]
    with pytest.raises(ValueError, match="meshes"):
        SubmeshBackend(model, params, 2)


# ---------------------------------------------------------------------------
# event dataclasses
# ---------------------------------------------------------------------------
def test_events_are_frozen_and_picklable():
    import pickle

    from repro.serving.engine import Completion
    c = ChunkEvent(1, 0, (4, 5), 0.5)
    d = DoneEvent(1, 0, Completion(1, [4, 5], 6, 0.1), 0.6)
    for ev in (c, d):
        assert pickle.loads(pickle.dumps(ev)) == ev
        with pytest.raises(dataclasses.FrozenInstanceError):
            ev.rid = 9
