"""Shared benchmark utilities: result directory, markdown emission."""
from __future__ import annotations

import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def save(name: str, payload: dict, lines: list[str]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)
    md = "\n".join(lines) + "\n"
    with open(os.path.join(RESULTS_DIR, name + ".md"), "w") as f:
        f.write(md)
    return md


def table(headers: list[str], rows: list[list]) -> list[str]:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "---|" * len(headers)]
    for r in rows:
        out.append("| " + " | ".join(
            f"{v:.3f}" if isinstance(v, float) else str(v) for v in r) + " |")
    return out
