"""Shared benchmark utilities: result directory, markdown emission."""
from __future__ import annotations

import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def save(name: str, payload: dict, lines: list[str]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)
    md = "\n".join(lines) + "\n"
    with open(os.path.join(RESULTS_DIR, name + ".md"), "w") as f:
        f.write(md)
    return md


def make_requests(cfg, n_requests: int, max_new: int,
                  plen_range: tuple[int, int] = (8, 24), seed: int = 0):
    """Synthetic serving wave: random prompts with ragged lengths."""
    import numpy as np

    from repro.serving import Request

    rng = np.random.default_rng(seed)
    lo, hi = plen_range
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (int(rng.integers(lo, hi)),),
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(n_requests)]


def save_bench(name: str, metrics: dict) -> str:
    """Machine-readable perf-trajectory point: ``BENCH_<name>.json`` holds
    a flat dict of headline numbers (tokens/s, wall, energy proxy, …) so
    CI can archive one comparable artifact per benchmark across PRs —
    distinct from the human-oriented ``<name>.json``/``.md`` pair."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(metrics, f, indent=2, sort_keys=True, default=float)
    return path


def table(headers: list[str], rows: list[list]) -> list[str]:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "---|" * len(headers)]
    for r in rows:
        out.append("| " + " | ".join(
            f"{v:.3f}" if isinstance(v, float) else str(v) for v in r) + " |")
    return out
