"""Prefix sharing — copy-on-write prompt-block reuse, measured.

Workload: every request opens with the same 64-token system prefix
(4 full 16-token blocks) followed by a mixed-length private tail — the
shape shared-system-prompt serving actually produces. The same wave is
streamed through the Router twice at an **equal block budget**, once
with ``prefix_cache`` off and once on. With sharing on, a seed request
populates the content-hash index during warmup, so the timed wave maps
its leading blocks onto cache hits and only prefills the tail.

Headline numbers (``BENCH_prefix.json``): prefill tokens actually
executed, prefill FLOPs (roofline ``2·N_active`` per executed token)
and time-to-first-chunk p50 — all three must drop with sharing on.
Greedy outputs are bit-identical either way (tests/test_paged_cache.py
pins that across all six families); this lane measures only the cost.
"""
from __future__ import annotations

import time

from benchmarks.common import save, save_bench, table

PREFIX_LEN = 64        # 4 full blocks at block_size=16
BLOCK_SIZE = 16


def bench_config():
    from repro.configs.base import reduce_config
    from repro.configs.registry import get_config

    return reduce_config(get_config("qwen3-0.6b"), n_layers=4, d_model=512,
                         n_heads=8, n_kv_heads=4, d_ff=2048,
                         vocab_size=8192)


def shared_prefix_requests(cfg, n_requests: int, max_new: int, rid0: int,
                           tail_range: tuple[int, int] = (8, 24),
                           seed: int = 0):
    """One shared 64-token prefix, per-request private tails.

    The prefix rng is fixed so every wave emits the same prefix content
    (same block hashes → hits), while tail CONTENT varies with ``seed``
    so a later wave never hits a previous wave's tail blocks — only the
    shared prefix is reused, which is the effect under test. Tail
    LENGTHS are a fixed cycle, so every wave produces the same admission
    batch compositions and warmup compiles exactly the jit keys the
    timed waves use."""
    import numpy as np

    from repro.serving import Request

    prefix = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (PREFIX_LEN,), dtype=np.int32)
    rng = np.random.default_rng(1000 + seed)
    lo, hi = tail_range
    reqs = []
    for i in range(n_requests):
        tail = rng.integers(0, cfg.vocab_size,
                            (lo + (i * 5) % (hi - lo),), dtype=np.int32)
        reqs.append(Request(rid=rid0 + i,
                            prompt=np.concatenate([prefix, tail]),
                            max_new_tokens=max_new))
    return reqs


def measure(model, params, share: bool, n_requests: int, max_new: int,
            reps: int, n_slots: int = 4, max_len: int = 128,
            max_blocks: int = 32) -> dict:
    """One mode (sharing on/off) at a fixed block budget: warm the
    engine (compile + populate the prefix index when sharing), then
    stream ``reps`` timed waves and keep the fastest. Executed-token and
    hit counters are read as deltas around the timed waves, so warmup
    compilation does not pollute them."""
    import numpy as np

    from repro.serving import Router
    from repro.serving.backend import ThreadBackend
    from repro.serving.engine import EngineConfig

    config = EngineConfig(n_slots=n_slots, max_len=max_len, cache="paged",
                          block_size=BLOCK_SIZE, max_blocks=max_blocks,
                          prefix_cache=share)
    backend = ThreadBackend(model, params, 1, config=config)
    router = Router(backend)
    rid = 0

    def wave(n):
        nonlocal rid
        reqs = shared_prefix_requests(model.cfg, n, max_new, rid, seed=rid)
        rid += n
        handles = [router.submit(r) for r in reqs]
        router.drain()
        return handles

    # warmup: a lone seed request registers the prefix blocks (and
    # compiles the full-prefill bucket), then a full wave compiles the
    # suffix buckets + decode; both modes get the identical warmup
    wave(1)
    wave(n_requests)
    eng = backend.engines[0]
    exec0 = eng.prefill_tokens_executed
    hits0 = eng.prefix_hit_tokens_total

    best = None
    for _ in range(reps):
        t0 = time.perf_counter()
        handles = wave(n_requests)
        wall = time.perf_counter() - t0
        ttfc = [h.ttfc_s for h in handles if h.ttfc_s is not None]
        toks = sum(len(h.completion.tokens) for h in handles)
        hit_toks = sum(h.completion.prefix_hit_tokens for h in handles)
        row = {"wall_s": wall,
               "tokens_per_s": toks / wall if wall > 0 else 0.0,
               "ttfc_p50_s": float(np.percentile(ttfc, 50)),
               "ttfc_p95_s": float(np.percentile(ttfc, 95)),
               "hit_tokens": hit_toks}
        if best is None or row["wall_s"] < best["wall_s"]:
            best = row
    reps_exec = eng.prefill_tokens_executed - exec0
    reps_hits = eng.prefix_hit_tokens_total - hits0
    router.close()

    from repro.core.roofline import prefill_flops
    best.update({
        "share": share,
        # per-wave averages over the timed reps (every wave is identical)
        "prefill_tokens_executed": reps_exec / reps,
        "prefix_hit_tokens": reps_hits / reps,
        "prefill_flops": prefill_flops(
            model.cfg, (reps_exec + reps_hits) // reps, reps_hits // reps)})
    return best


def run(quick: bool = False) -> str:
    import jax

    # reps >= 2 even in smoke: the first shared-mode wave pays a one-time
    # warm-in (first real execution of the gather→suffix→insert chain)
    # that best-of-reps filters like any other first-run noise
    n_requests, max_new, reps = (6, 4, 2) if quick else (16, 8, 3)
    if quick:
        from repro.configs.registry import get_config as _get
        cfg = _get("qwen3-0.6b-reduced")
    else:
        cfg = bench_config()
    from repro.models.model import Model
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rows = [measure(model, params, share, n_requests, max_new, reps)
            for share in (False, True)]
    off, on = rows
    exec_drop = 1.0 - (on["prefill_tokens_executed"]
                       / off["prefill_tokens_executed"])
    flop_drop = 1.0 - on["prefill_flops"] / off["prefill_flops"]
    ttfc_drop = 1.0 - on["ttfc_p50_s"] / off["ttfc_p50_s"]

    lines = ["# Prefix sharing — CoW prompt-block reuse (equal block "
             "budget)", "",
             f"{n_requests} requests × {max_new} new tokens, shared "
             f"{PREFIX_LEN}-token prefix + mixed tails, arch {cfg.name}; "
             f"paged cache, block_size={BLOCK_SIZE}, same max_blocks "
             "both modes; streamed via the Router, warm engine", ""]
    lines += table(
        ["prefix_cache", "prefill tok executed", "hit tok",
         "prefill GFLOP", "ttfc p50 (s)", "ttfc p95 (s)", "wall (s)"],
        [[("on" if r["share"] else "off"), r["prefill_tokens_executed"],
          r["prefix_hit_tokens"], r["prefill_flops"] / 1e9,
          r["ttfc_p50_s"], r["ttfc_p95_s"], r["wall_s"]] for r in rows])
    lines += ["", f"prefill tokens executed: -{exec_drop:.1%}   "
              f"prefill FLOPs: -{flop_drop:.1%}   "
              f"ttfc p50: -{ttfc_drop:.1%}"]

    save_bench("prefix", {
        "config": cfg.name, "prefix_len": PREFIX_LEN,
        "block_size": BLOCK_SIZE, "n_requests": n_requests,
        "prefill_tokens_executed_off": off["prefill_tokens_executed"],
        "prefill_tokens_executed_on": on["prefill_tokens_executed"],
        "prefill_flops_off": off["prefill_flops"],
        "prefill_flops_on": on["prefill_flops"],
        "prefix_hit_tokens_on": on["prefix_hit_tokens"],
        "ttfc_p50_off_s": off["ttfc_p50_s"],
        "ttfc_p50_on_s": on["ttfc_p50_s"],
        "exec_tokens_reduction": exec_drop,
        "ttfc_p50_reduction": ttfc_drop})
    return save("prefix_sharing", {"measured": rows}, lines)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", "--smoke", action="store_true", dest="quick",
                    help="tiny config / fewer requests (CI smoke)")
    args = ap.parse_args()
    print(run(quick=args.quick))
