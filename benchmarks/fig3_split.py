"""Fig. 3 — n containers: normalised time / energy / power.

Three columns of evidence:
  (a) paper's fitted models evaluated (ground truth being reproduced),
  (b) calibrated TX2/Orin device simulators (our §VI reproduction),
  (c) REAL measurements on the host CPU testbed (pinned processes).
All normalised to the 1-container benchmark, as in the paper.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save, table
from repro.core import testbed
from repro.core.energy_model import (PAPER_MODELS, eval_model, orin_model,
                                     tx2_model)


def run(quick: bool = False) -> str:
    payload: dict = {"devices": {}, "measured": []}
    rows = []
    for name, dev, n_max in (("tx2", tx2_model(), 6),
                             ("orin", orin_model(), 12)):
        ns = list(range(1, n_max + 1))
        t1, e1, p1 = dev.time(1), dev.energy(1), dev.power(1)
        sim = {"n": ns,
               "time": [dev.time(n) / t1 for n in ns],
               "energy": [dev.energy(n) / e1 for n in ns],
               "power": [dev.power(n) / p1 for n in ns]}
        paper = {m: eval_model(*PAPER_MODELS[(name, m)][0:1],
                               PAPER_MODELS[(name, m)][1], np.array(ns))
                 for m in ("time", "energy", "power")}
        payload["devices"][name] = {"sim": sim,
                                    "paper": {k: v.tolist()
                                              for k, v in paper.items()}}
        for i, n in enumerate(ns):
            rows.append([name, n, sim["time"][i], float(paper["time"][i]),
                         sim["energy"][i], float(paper["energy"][i]),
                         sim["power"][i], float(paper["power"][i])])

    lines = ["# Fig. 3 — n containers (normalised to 1-container benchmark)",
             "", "## TX2 / Orin: simulator vs paper's fitted models", ""]
    lines += table(["device", "n", "t sim", "t paper", "E sim", "E paper",
                    "P sim", "P paper"], rows)

    # ---- real host measurements
    n_frames = 64 if quick else 192
    total_cores = 8
    frames = testbed.make_video(n_frames)
    base = testbed.run_split(frames, 1, total_cores=total_cores)
    meas_rows = []
    for n in (1, 2, 4, 8):
        # allow_shared: on hosts with fewer than 8 cores the high counts
        # time-share cores (explicitly — run_split refuses silent overlap)
        r = testbed.run_split(frames, n, total_cores=total_cores,
                              allow_shared=True)
        ok = bool(np.allclose(r.outputs, base.outputs, atol=1e-5))
        payload["measured"].append(
            {"n": n, "wall_s": r.wall_s, "power_w": r.avg_power_w,
             "energy_j": r.energy_j, "outputs_match": ok,
             "disjoint_cores": r.disjoint})
        meas_rows.append([n, r.wall_s / base.wall_s,
                          r.energy_j / base.energy_j,
                          r.avg_power_w / base.avg_power_w,
                          "✓" if ok else "✗"])
    lines += ["", f"## Host testbed (REAL wall times, {total_cores} cores, "
              f"{n_frames} frames)", ""]
    lines += table(["n", "time (norm)", "energy (norm)", "power (norm)",
                    "outputs=="], meas_rows)

    # ---- serving-pool analogue: threads on the shared device (the LM
    # counterpart of the pinned-process video testbed above)
    import jax

    from benchmarks import pool_scaling
    from repro.models.model import Model

    cfg = pool_scaling.bench_config()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = pool_scaling.make_requests(cfg, 8 if quick else 16,
                                      4 if quick else 8)
    pool_rows = pool_scaling.measure_pool(model, params, reqs,
                                          reps=1 if quick else 2)
    payload["serving_pool"] = pool_rows
    base_w = pool_rows[0]["wall_seq_s"]
    lines += ["", "## Serving pool (REAL wall times, threaded engines on "
              "the shared device)", ""]
    lines += table(["n", "seq (norm)", "conc (norm)", "speedup"],
                   [[r["n"], r["wall_seq_s"] / base_w,
                     r["wall_conc_s"] / base_w, r["speedup"]]
                    for r in pool_rows])
    return save("fig3_split", payload, lines)


if __name__ == "__main__":
    print(run())
