import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- device-count override must precede jax import (run this module as a
# --- subprocess: `python -m benchmarks.tpu_split`; benchmarks.run does).

"""TPU "divide and save" — the paper's method on the production pod.

The pod's 256 chips are factorised as (data=n, model=256/n): n independent
model replicas ("containers"), each over 256/n chips, the request batch
split n ways (core/splitter.py semantics). For every factorisation we lower
the serve step, derive the 3-term roofline, the step time and the
activity-model energy — the TPU analogue of Fig. 3 — then fit the paper's
convex model forms and let the DivideAndSave scheduler pick n*.
"""

import argparse
import json
import sys

import numpy as np

import jax

from benchmarks.common import save, table
from repro.configs.registry import get_config, get_shape
from repro.core import containers
from repro.core.energy_model import fit_best
from repro.core.hlo_analysis import analyze_hlo
from repro.core.roofline import build_report
from repro.core.scheduler import DivideAndSaveScheduler
from repro.launch.mesh import make_container_mesh
from repro.launch.sharding import ShardingRules
from repro.launch.specs import lowering_args
from repro.models.model import Model

TOTAL_CHIPS = 256
HBM_BYTES = 16e9


def measure(arch: str, shape_name: str, n: int) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    spec = containers.ContainerSpec(n, TOTAL_CHIPS // n, TOTAL_CHIPS)
    feasible = containers.feasible(cfg, spec, hbm_bytes=HBM_BYTES)
    mesh = make_container_mesh(TOTAL_CHIPS, n)
    model = Model(cfg)
    step, args = lowering_args(model, shape)
    rules = ShardingRules(mesh, train=False, fsdp=False)
    if shape.kind == "train":
        in_sh = (rules.params(args[0]), rules.opt_state(args[1]),
                 rules.batch(args[2]))
    elif shape.kind == "prefill":
        in_sh = (rules.params(args[0]), rules.batch(args[1]))
    else:
        in_sh = (rules.params(args[0]),
                 rules.cache(args[1], args[2]["tokens"].shape[0]),
                 rules.batch(args[2]))
    with jax.set_mesh(mesh):
        compiled = jax.jit(step, in_shardings=in_sh).lower(*args).compile()
        txt = compiled.as_text()
    cost = analyze_hlo(txt)
    rep = build_report(arch, shape, cfg, f"({n},{TOTAL_CHIPS//n})",
                       TOTAL_CHIPS, cost)
    return {"n": n, "chips_per_container": TOTAL_CHIPS // n,
            "feasible": feasible,
            "weight_gb_per_chip":
                containers.weight_bytes_per_chip(cfg, spec) / 1e9,
            "t_compute": rep.t_compute, "t_memory": rep.t_memory,
            "t_collective": rep.t_collective, "step_time": rep.step_time,
            "dominant": rep.dominant, "energy_j": rep.energy_j}


def run(arch: str = "qwen3-8b", shape: str = "decode_32k",
        quick: bool = False) -> str:
    B = get_shape(shape).global_batch
    ns = [n for n in (1, 2, 4, 8, 16, 32, 64, 128, 256)
          if TOTAL_CHIPS % n == 0 and (B % n == 0 or B >= n)]
    if quick:
        ns = [1, 4, 16, 64]
    points = []
    for n in ns:
        try:
            points.append(measure(arch, shape, n))
            p = points[-1]
            print(f"[n={n:3d}] step {p['step_time']*1e3:8.2f} ms  "
                  f"E {p['energy_j']:9.1f} J  dom {p['dominant']}"
                  f"{'' if p['feasible'] else '  (infeasible: HBM)'}",
                  flush=True)
        except Exception as e:  # noqa: BLE001 — record and continue
            print(f"[n={n}] FAILED: {e}", flush=True)

    feas = [p for p in points if p["feasible"]]
    base = points[0]
    rows = [[p["n"], p["chips_per_container"],
             "Y" if p["feasible"] else "n",
             p["step_time"] / base["step_time"],
             p["energy_j"] / base["energy_j"], p["dominant"],
             p["weight_gb_per_chip"]] for p in points]
    lines = [f"# Divide-and-save on the pod — {arch} × {shape}",
             "", "Normalised to the n=1 (whole-pod single container) "
             "benchmark.", ""]
    lines += table(["n", "chips/ctr", "feasible", "step (norm)",
                    "energy (norm)", "dominant", "weights GB/chip"], rows)

    # convex fits + online scheduler choice over feasible factorisations
    if len(feas) >= 3:
        xs = np.array([p["n"] for p in feas], float)
        tfit = fit_best(xs, [p["step_time"] / base["step_time"]
                             for p in feas])
        efit = fit_best(xs, [p["energy_j"] / base["energy_j"]
                             for p in feas])
        sched = DivideAndSaveScheduler([p["n"] for p in feas],
                                       objective="energy", epsilon=0.0)
        for p in feas:
            sched.observe(p["n"], p["step_time"], p["energy_j"])
        best = sched.pick()
        lines += ["", f"time fit: {tfit.kind} {tuple(round(c, 4) for c in tfit.coef)}",
                  f"energy fit: {efit.kind} {tuple(round(c, 4) for c in efit.coef)}",
                  f"scheduler (energy objective) picks n* = {best}"]
    payload = {"arch": arch, "shape": shape, "points": points}
    return save(f"tpu_split_{arch}_{shape}", payload, lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    print(run(a.arch, a.shape, quick=a.quick))
    sys.exit(0)
