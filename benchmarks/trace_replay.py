"""Trace replay — the SLO-scheduling claim, measured.

The headline for ``BENCH_trace.json``: over one hour of the frozen
diurnal+bursty trace, the ``energy_under_slo`` scheduler **meets an
interactive ttfc-p95 target the mean-energy-optimal baseline violates,
at equal or lower energy per completed request**. The baseline is not a
strawman — it runs the same Router admission control (bounded queue,
client deadlines), just mean-optimally and SLO-blind: no priority
ordering, no per-class sheds, no quantile constraint on the count. Its
interactive tail then blows up twice over — FIFO head-of-line blocking
behind long batch prompts during bursts, and the count argmin parked at
the mean-energy optimum with no burst headroom — and the interactive
requests that die at their client deadline after queueing behind batch
work are exactly the completions the SLO run saves, which is where its
energy-per-done edge comes from.

The committed numbers run on the deterministic virtual-time simulator
(``workload/sim.py`` — real scheduler, real SLO arithmetic, bit-for-bit
reproducible; the full hour replays in seconds). ``--smoke`` replays a
short trace open-loop against the live Router/ThreadBackend stack
first, proving the wire path works, then runs a shortened simulated
comparison for the CI ``trace-replay-smoke`` lane.
"""
from __future__ import annotations

import argparse
import dataclasses

from benchmarks.common import save, save_bench, table
from repro.configs.registry import get_config
from repro.models.model import Model
from repro.serving import Router
from repro.serving.backend import ThreadBackend
from repro.serving.engine import EngineConfig
from repro.workload.replay import ReplayReport, replay
from repro.workload.sim import FleetModel, simulate
from repro.workload.slo import SLOSpec
from repro.workload.traces import get_preset, synthesize

# ---------------------------------------------------------------------------
# the frozen benchmark configuration — every number in the committed
# BENCH_trace.json derives from these and nothing else
# ---------------------------------------------------------------------------
TRACE_SEED = 1
SIM_SEED = 0
DURATION_S = 3600.0
SLO_TEXT = "interactive:0.5,batch:8.0"
# client-side deadlines (what the *users* tolerate — distinct from the
# SLO targets the operator schedules against)
DEADLINES = {"interactive": 1.2, "batch": 30.0, "default": 30.0}
SIM_KW = dict(feasible_counts=[1, 2, 3, 4], window=32, window_s=20.0,
              max_queue=64, epsilon=0.05)


def bench_trace(duration_s: float, seed: int):
    spec = dataclasses.replace(get_preset("diurnal-bursty"),
                               duration_s=duration_s,
                               max_requests=200_000)
    return synthesize(spec, seed=seed)


def run_pair(duration_s: float, smoke: bool) -> tuple[ReplayReport,
                                                      ReplayReport]:
    """The comparison: mean-energy baseline vs SLO-constrained run on
    the SAME trace, same fleet, same admission machinery."""
    trace = bench_trace(duration_s, TRACE_SEED)
    slo = SLOSpec.parse(SLO_TEXT)
    fleet = FleetModel()
    kw = dict(**SIM_KW, seed=SIM_SEED, fleet=fleet,
              deadline_by_class=DEADLINES)
    base = simulate(trace, objective="energy", **kw)
    cons = simulate(trace, objective="energy_under_slo", slo=slo, **kw)
    if not smoke:
        # the reproducibility contract: identical seed + trace must
        # reproduce the report bit-for-bit
        again = simulate(trace, objective="energy_under_slo", slo=slo, **kw)
        assert again == cons, "simulate() is not deterministic"
    return base, cons


def bench_live_smoke() -> dict:
    """Open-loop replay against the real Router + ThreadBackend: the
    wire path (trace -> Request -> priority dispatch -> per-class
    windows) exercised live, compressed 10x. Numbers are wall-clock and
    NOT comparable across hosts — rot check only."""
    import jax

    cfg = get_config("qwen3-0.6b-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec = dataclasses.replace(get_preset("diurnal-bursty"),
                               duration_s=40.0, max_requests=200)
    trace = synthesize(spec, seed=TRACE_SEED)
    slo = SLOSpec.parse(SLO_TEXT)
    ecfg = EngineConfig(n_slots=4, max_len=192, chunk_tokens=4)

    def factory(n):
        return ThreadBackend(model, params, n, config=ecfg)

    with Router(backend_factory=factory, feasible_counts=[1, 2],
                objective="energy_under_slo", slo=slo,
                window=8, window_s=5.0, max_queue=32,
                seed=SIM_SEED) as router:
        rep = replay(trace, router, time_scale=10.0,
                     vocab_size=cfg.vocab_size)
    assert rep.n_done > 0, "live replay completed nothing"
    return {"live_n_requests": rep.n_requests, "live_n_done": rep.n_done,
            "live_n_shed": rep.n_shed, "live_goodput_rps": rep.goodput_rps,
            "live_ttfc_p95_s": rep.ttfc_p95_s,
            "live_counts_visited": list(rep.counts_visited)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="live wire-path replay + shortened simulation")
    args = ap.parse_args()

    live = bench_live_smoke() if args.smoke else {}
    duration = 600.0 if args.smoke else DURATION_S
    base, cons = run_pair(duration, args.smoke)

    target = SLOSpec.parse(SLO_TEXT).constraint.ttfc_p95_s
    bi = base.per_class["interactive"]
    ci = cons.per_class["interactive"]
    rows = [
        ["energy (mean-optimal)", base.final_n, bi.ttfc_p95_s,
         str(base.slo_attained), base.n_done, base.goodput_rps,
         base.energy_per_done_j],
        ["energy_under_slo", cons.final_n, ci.ttfc_p95_s,
         str(cons.slo_attained), cons.n_done, cons.goodput_rps,
         cons.energy_per_done_j],
    ]
    lines = [f"# trace replay — {base.trace} "
             f"(trace seed {TRACE_SEED}, sim seed {SIM_SEED}, "
             f"{duration:.0f}s{', smoke' if args.smoke else ''})", ""]
    lines += table(["objective", "final n", "interactive p95 (s)",
                    "attained", "done", "goodput rps", "J/done"], rows)
    lines += ["", f"interactive ttfc-p95 target: {target}s; client "
              f"deadlines {DEADLINES}"]

    if not args.smoke:
        # the claim the committed artifact exists to witness
        assert cons.slo_attained, "SLO run failed its own targets"
        assert bi.ttfc_p95_s > target, \
            "baseline met the target — no violation to beat"
        assert cons.energy_per_done_j <= base.energy_per_done_j, \
            "SLO run spent more energy per completion than the baseline"

    payload = {"smoke": args.smoke, "target_ttfc_p95_s": target,
               "slo": SLO_TEXT, "deadlines": DEADLINES,
               "base": base.to_dict(), "slo_run": cons.to_dict(), **live}
    print(save("trace_replay", payload, lines))
    save_bench("trace", {
        "smoke": args.smoke, "duration_s": duration,
        "trace_seed": TRACE_SEED, "sim_seed": SIM_SEED,
        "target_ttfc_p95_s": target,
        "base_final_n": base.final_n,
        "base_interactive_ttfc_p95_s": bi.ttfc_p95_s,
        "base_n_done": base.n_done,
        "base_goodput_rps": base.goodput_rps,
        "base_energy_per_done_j": base.energy_per_done_j,
        "slo_final_n": cons.final_n,
        "slo_interactive_ttfc_p95_s": ci.ttfc_p95_s,
        "slo_attained": bool(cons.slo_attained),
        "slo_n_done": cons.n_done,
        "slo_goodput_rps": cons.goodput_rps,
        "slo_energy_per_done_j": cons.energy_per_done_j,
        **live})


if __name__ == "__main__":
    main()
