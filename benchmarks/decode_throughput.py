"""Decode throughput: fused chunked decode vs the per-token baseline.

The fused path (``Model.decode_chunk`` + donated caches) replaces one XLA
dispatch, one full KV-cache copy, and one blocking host sync *per token*
with one dispatch + one transfer *per chunk*. This benchmark measures the
resulting tokens/s on the same engines the container pool runs, at
n ∈ {1, 2, 4} containers — the per-container multiplier the paper's
divide-and-save splits compound on top of.

Emits ``results/decode_throughput.{json,md}`` (human-oriented) and
``results/BENCH_decode.json`` (machine-readable perf trajectory; uploaded
as a CI artifact). ``--smoke`` runs a tiny single-chunk configuration so
CI can keep the benchmark from rotting without paying bench time.
"""
from __future__ import annotations

import functools

import numpy as np

from benchmarks.common import make_requests, save, save_bench, table
from repro.configs.base import reduce_config
from repro.configs.registry import get_config
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.pool import ContainerServingPool


def bench_config(smoke: bool = False):
    """Edge-class serving reduction: decode at this size is
    dispatch/overhead-bound — exactly the regime the fused chunk targets.
    (At pool_scaling's larger d512 reduction this CPU is compute-bound
    per step and the fused win shrinks to noise; both points are real,
    this benchmark tracks the overhead-dominated one.)"""
    if smoke:
        return reduce_config(get_config("qwen3-0.6b"), n_layers=2,
                             d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                             vocab_size=512)
    return reduce_config(get_config("qwen3-0.6b"), n_layers=2, d_model=128,
                         n_heads=4, n_kv_heads=2, d_ff=512,
                         vocab_size=1024)


def measure(model, params, requests, ns=(1, 2, 4), n_slots=2,
            max_len=128, chunk_tokens=None, reps: int = 3) -> list[dict]:
    """Per-token vs chunked tokens/s per container count. Modes are
    interleaved and the best of ``reps`` kept (standard wall-time noise
    filter on a shared host)."""
    rows = []
    for n in ns:
        pools = {}
        for mode, chunked in (("token", False), ("chunk", True)):
            factory = functools.partial(ServingEngine, chunked=chunked,
                                        chunk_tokens=chunk_tokens)
            pools[mode] = ContainerServingPool(
                model, params, n, n_slots_per_container=n_slots,
                max_len=max_len, engine_factory=factory)
            pools[mode].serve_timed(list(requests))       # compile warmup
        best: dict = {m: (np.inf, 0.0, 0) for m in pools}
        for _ in range(reps):
            for mode, pool in pools.items():
                _, per, wall, energy = pool.serve_timed(list(requests))
                toks = sum(r.n_tokens for r in per)
                if wall < best[mode][0]:
                    best[mode] = (wall, energy, toks)
        (w_tok, e_tok, t_tok), (w_chk, e_chk, t_chk) = (best["token"],
                                                        best["chunk"])
        rows.append({
            "n": n,
            "wall_token_s": w_tok, "wall_chunk_s": w_chk,
            "tokens": t_chk,
            "tps_token": t_tok / w_tok, "tps_chunk": t_chk / w_chk,
            "speedup": (t_chk / w_chk) / (t_tok / w_tok),
            "energy_token_j": e_tok, "energy_chunk_j": e_chk,
        })
    return rows


def run(quick: bool = False, smoke: bool = False) -> str:
    import jax

    # budgets are chunk-aligned (max_new - 1 lands on a power-of-two
    # chunk length) so the steady state is one fused dispatch per slot
    # generation — the deployment fast path the README documents
    if smoke:
        ns, n_requests, max_new, reps, chunk = (1,), 2, 5, 1, 4
    elif quick:
        ns, n_requests, max_new, reps, chunk = (1, 2), 8, 33, 3, None
    else:
        ns, n_requests, max_new, reps, chunk = (1, 2, 4), 16, 33, 5, None
    cfg = bench_config(smoke)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    requests = make_requests(cfg, n_requests, max_new)

    rows = measure(model, params, requests, ns=ns, chunk_tokens=chunk,
                   reps=reps)
    payload = {"measured": rows, "config": cfg.name, "smoke": smoke,
               "n_requests": n_requests, "max_new_tokens": max_new}
    md_rows = [[r["n"], r["wall_token_s"], r["wall_chunk_s"],
                r["tps_token"], r["tps_chunk"], r["speedup"],
                r["energy_token_j"], r["energy_chunk_j"]] for r in rows]
    lines = ["# Decode throughput — fused chunked decode vs per-token",
             "", f"{n_requests} requests × {max_new} new tokens, "
             f"arch {cfg.name} (bench reduction)", ""]
    lines += table(["n", "token wall (s)", "chunk wall (s)", "tok/s token",
                    "tok/s chunk", "speedup", "E token (J)", "E chunk (J)"],
                   md_rows)
    n1 = rows[0]
    lines += ["", f"n=1 chunked speedup: {n1['speedup']:.2f}× "
              f"({n1['tps_token']:.1f} → {n1['tps_chunk']:.1f} tokens/s)"]
    save_bench("decode", {
        "config": cfg.name, "smoke": smoke,
        "n1_tokens_per_s_token": n1["tps_token"],
        "n1_tokens_per_s_chunk": n1["tps_chunk"],
        "n1_speedup": n1["speedup"],
        "per_n": {str(r["n"]): {"tokens_per_s_chunk": r["tps_chunk"],
                                "tokens_per_s_token": r["tps_token"],
                                "wall_s": r["wall_chunk_s"],
                                "energy_j": r["energy_chunk_j"]}
                  for r in rows}})
    return save("decode_throughput", payload, lines)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config, one chunk — CI rot check only")
    args = ap.parse_args()
    print(run(quick=args.quick, smoke=args.smoke))
