"""Fig. 1 — single container, varying CPU allocation.

Two views:
  (a) the calibrated TX2/Orin analytic device models (paper's own hardware),
  (b) a REAL measurement on this host's CPU testbed (one pinned container,
      1..8 cores) — demonstrating the same flattening with real wall times.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save, table
from repro.core import testbed
from repro.core.energy_model import orin_model, tx2_model


def run(quick: bool = False) -> str:
    rows, payload = [], {"model": {}, "measured": []}
    for name, dev in (("tx2", tx2_model()), ("orin", orin_model())):
        cores = np.linspace(0.5, dev.cores, 8)
        ts = [dev.single_container_time(float(c)) for c in cores]
        es = [dev.p_idle_w * t + dev.p_core_w * min(c, dev.cores) * t * 0.9
              for c, t in zip(cores, ts)]
        payload["model"][name] = {"cores": cores.tolist(), "time_s": ts,
                                  "energy_j": es}
        for c, t, e in zip(cores, ts, es):
            rows.append([f"{name} (model)", f"{c:.1f}", t, e])

    n_frames = 48 if quick else 120
    frames = testbed.make_video(n_frames)
    for c in (1, 2, 4, 8):
        wall = testbed.run_single_container(frames, cores=c)
        energy = (testbed.P_IDLE_W + testbed.P_CORE_W * c * 0.9) * wall
        payload["measured"].append({"cores": c, "time_s": wall,
                                    "energy_j": energy})
        rows.append(["host (measured)", str(c), wall, energy])

    lines = ["# Fig. 1 — one container, varying CPU cores", ""]
    lines += table(["device", "cores", "time (s)", "energy (J)"], rows)
    t1 = payload["measured"][0]["time_s"]
    t8 = payload["measured"][-1]["time_s"]
    lines += ["", f"host speedup 1→8 cores: {t1 / t8:.2f}× "
              "(sub-linear — the flattening that motivates splitting)"]
    return save("fig1_cores", payload, lines)


if __name__ == "__main__":
    print(run())
