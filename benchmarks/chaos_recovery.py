"""Chaos recovery — the fault-tolerance claims, measured.

Two headline numbers for the ``BENCH_chaos.json`` perf trajectory:

  (a) **recovery latency**: a scripted ``FaultPlan`` kills one of two
      containers mid-stream; the Router re-dispatches the lost requests
      to the survivor (and the supervisor respawns the casualty). The
      metric is the wall time from the ``ContainerFailure`` record to the
      last lost request's completion — how long a container crash is
      visible in request latency.
  (b) **shed rate under overload**: a burst far beyond ``max_queue`` hits
      a single container; admission control must shed the excess as fast
      typed rejections while every admitted request still completes. The
      metric is the shed fraction plus the rejection turnaround (shed
      requests must fail in microseconds, not queue).

Both run the in-process ``ThreadBackend`` (deterministic, no spawn cost)
with ``chunk_tokens=1`` so step-indexed faults land mid-stream by
construction. ``--smoke`` shrinks the workload for the CI chaos lane.
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import make_requests, save, save_bench, table
from repro.configs.base import reduce_config
from repro.configs.registry import get_config
from repro.models.model import Model
from repro.serving import Fault, FaultPlan, RejectedEvent, Router
from repro.serving.backend import ThreadBackend
from repro.serving.engine import EngineConfig


def bench_config(smoke: bool):
    if smoke:
        return get_config("qwen3-0.6b-reduced")
    return reduce_config(get_config("qwen3-0.6b"), n_layers=4, d_model=512,
                         n_heads=8, n_kv_heads=4, d_ff=2048,
                         vocab_size=8192)


def bench_recovery(model, params, n_requests: int, max_new: int) -> dict:
    """Kill container 0 after 3 macro-steps; how long until its lost
    requests are done on the survivor/respawn?"""
    cfg = model.cfg
    plan = FaultPlan((Fault("kill", container_id=0, after_steps=3),))
    config = EngineConfig(n_slots=2, max_len=128, chunk_tokens=1)
    backend = ThreadBackend(model, params, 2, config=config,
                            fault_plan=plan, max_respawns=2)
    reqs = make_requests(cfg, n_requests, max_new)
    with Router(backend, max_retries=2) as router:
        t0 = time.perf_counter()
        handles = {r.rid: router.submit(r) for r in reqs}
        router.drain()
        wall = time.perf_counter() - t0
        assert router.container_failures, "the injected kill never fired"
        fail = router.container_failures[0]
        lost = set(fail.lost_rids)
        completed = {rid: h for rid, h in handles.items()
                     if h.completion is not None}
        assert set(completed) == set(handles), "requests lost to the kill"
        recovery_s = (max(completed[rid].done_at for rid in lost)
                      - fail.time_s) if lost else 0.0
    return {"wall_s": wall, "n_requests": n_requests,
            "n_lost": len(lost), "n_retried": router.retry_total,
            "recovery_latency_s": recovery_s}


def bench_overload(model, params, n_requests: int, max_queue: int,
                   max_new: int) -> dict:
    """One container, a burst of ``n_requests`` against ``max_queue``
    admission: shed fraction + rejection turnaround, and every admitted
    request must still complete."""
    cfg = model.cfg
    config = EngineConfig(n_slots=2, max_len=128)
    backend = ThreadBackend(model, params, 1, config=config)
    reqs = make_requests(cfg, n_requests, max_new, seed=1)
    with Router(backend, max_queue=max_queue) as router:
        t0 = time.perf_counter()
        admitted, shed_turnaround = [], []
        for r in reqs:
            ts = time.perf_counter()
            h = router.submit(r)
            if isinstance(h.failure, RejectedEvent):
                shed_turnaround.append(time.perf_counter() - ts)
            else:
                admitted.append(h)
        router.drain()
        wall = time.perf_counter() - t0
        assert all(h.completion is not None for h in admitted)
        n_shed = router.shed_total
    return {"overload_wall_s": wall, "n_burst": n_requests,
            "max_queue": max_queue, "n_admitted": len(admitted),
            "n_shed": n_shed, "shed_rate": n_shed / n_requests,
            "shed_turnaround_s": (max(shed_turnaround)
                                  if shed_turnaround else 0.0)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config / small workload (CI chaos lane)")
    args = ap.parse_args()
    cfg = bench_config(args.smoke)
    model = Model(cfg)
    import jax
    params = model.init(jax.random.PRNGKey(0))
    n_req, max_new = (6, 8) if args.smoke else (16, 24)
    rec = bench_recovery(model, params, n_req, max_new)
    over = bench_overload(model, params, n_requests=4 * n_req,
                          max_queue=max(2, n_req // 2), max_new=max_new)
    payload = {"smoke": args.smoke, "recovery": rec, "overload": over}
    lines = ["# Chaos recovery", "",
             "## Recovery after an injected container kill", ""]
    lines += table(list(rec), [list(rec.values())])
    lines += ["", "## Load-shedding under a burst", ""]
    lines += table(list(over), [list(over.values())])
    print(save("chaos_recovery", payload, lines))
    save_bench("chaos", {**rec, **over})


if __name__ == "__main__":
    main()
