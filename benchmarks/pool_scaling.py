"""Pool scaling — the "save" half of divide-and-save, measured.

Three pieces of evidence:
  (a) REAL wall times: a fixed request batch served by the container pool
      at n ∈ {1, 2, 4}, sequential vs concurrent engines. Concurrency is
      thread-per-container on the shared device (jax releases the GIL
      during XLA execution), so the speedup is genuine overlap, not
      simulation.
  (b) the online scheduler loop on a synthetic convex time/energy profile
      (§VI-style simulation): the adaptive pool must find the known
      argmin within a handful of waves.
  (c) ``--isolation process``: the same wave served thread-per-container
      vs **process-per-container with pinned disjoint cpusets**
      (serving/process_pool.py — the paper's actual ``--cpus`` mechanism)
      at n ∈ {1, 2, 4}, emitting ``BENCH_process_pool.json``. Counts past
      the host's core budget fall back to explicit round-robin shared
      cores (flagged per row) rather than silently overlapping.
  (d) ``--streaming``: the same wave admitted request-by-request through
      the ``Router`` (serving/router.py) and consumed as chunk events,
      recording **time-to-first-chunk p50/p95** and streamed tokens/s per
      count, emitting ``BENCH_streaming.json`` — the latency axis the
      wave API could not observe at all.

The measured model is a mid-size reduction — large enough that XLA compute
dominates Python dispatch, which is what lets threads overlap on CPU.
"""
from __future__ import annotations

import time

from benchmarks.common import make_requests, save, save_bench, table
from repro.configs.base import reduce_config
from repro.configs.registry import get_config
from repro.models.model import Model
from repro.serving.adaptive import AdaptiveServingPool, synthetic_pool_factory
from repro.serving.pool import ContainerServingPool


def bench_config():
    """Mid-size serving config: big enough per-step compute to overlap."""
    return reduce_config(get_config("qwen3-0.6b"), n_layers=4, d_model=512,
                         n_heads=8, n_kv_heads=4, d_ff=2048,
                         vocab_size=8192)


def measure_pool(model, params, requests, ns=(1, 2, 4), n_slots=2,
                 max_len=128, reps: int = 3) -> list[dict]:
    """Sequential vs concurrent wall/energy per container count.

    Modes are interleaved and the best of ``reps`` kept — min is the
    standard noise filter for wall timings on a shared, small host."""
    rows = []
    for n in ns:
        pool = ContainerServingPool(model, params, n,
                                    n_slots_per_container=n_slots,
                                    max_len=max_len)
        pool.serve_timed(list(requests), concurrent=False)  # compile warmup
        seq, con = [], []
        for _ in range(reps):
            _, _, w, e = pool.serve_timed(list(requests), concurrent=False)
            seq.append((w, e))
            _, _, w, e = pool.serve_timed(list(requests), concurrent=True)
            con.append((w, e))
        (w_seq, e_seq), (w_con, e_con) = min(seq), min(con)
        rows.append({"n": n, "wall_seq_s": w_seq, "wall_conc_s": w_con,
                     "speedup": w_seq / w_con,
                     "energy_seq_j": e_seq, "energy_conc_j": e_con})
    return rows


def adaptive_convergence(feasible=(1, 2, 4, 8), waves: int = 8):
    """Drive the adaptive pool against a convex synthetic profile; returns
    (per-wave picks, per-wave exploitation choices, known argmin)."""
    def t(n):
        return 1.0 / n + 0.02 * n * n      # convex, argmin at n=4

    def e(n):
        return t(n) * (40.0 + 7.0 * n)

    apool = AdaptiveServingPool(None, None, list(feasible),
                                objective="time",
                                pool_factory=synthetic_pool_factory(t, e))
    choices = []
    for _ in range(waves):
        apool.serve_wave([])
        choices.append(apool.choice)
    picks = [w.n_containers for w in apool.history]
    known = min(feasible, key=t)
    return picks, choices, known


def measure_process_pool(cfg, requests, ns=(1, 2, 4), n_slots=2,
                         max_len=128, reps: int = 2,
                         params_seed: int = 0) -> list[dict]:
    """Thread-per-container (shared runtime) vs process-per-container
    (pinned disjoint cpusets) wall/energy per count. Each lane is warmed
    (compile / spawn+compile) before timing, so rows compare steady-state
    serving, not startup."""
    import jax

    from repro.core.testbed import available_cores
    from repro.models.model import Model
    from repro.serving.process_pool import ProcessContainerPool

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(params_seed))
    avail = len(available_cores())
    rows = []
    for n in ns:
        tpool = ContainerServingPool(model, params, n,
                                     n_slots_per_container=n_slots,
                                     max_len=max_len)
        tpool.serve_timed(list(requests))              # compile warmup
        thread = min((tpool.serve_timed(list(requests))[2:]
                      for _ in range(reps)))
        shared = n > avail
        with ProcessContainerPool(cfg, n, n_slots_per_container=n_slots,
                                  max_len=max_len, params_seed=params_seed,
                                  allow_shared_cores=shared) as ppool:
            t0 = time.perf_counter()
            ppool.serve_timed(list(requests))          # spawn + compile
            spawn_s = time.perf_counter() - t0
            proc = min((ppool.serve_timed(list(requests))[2:]
                        for _ in range(reps)))
        rows.append({"n": n, "wall_thread_s": thread[0],
                     "wall_process_s": proc[0],
                     "energy_thread_j": thread[1],
                     "energy_process_j": proc[1],
                     "process_spawn_s": spawn_s,
                     "shared_cores": shared})
    return rows


def run_process(quick: bool = False) -> str:
    """The thread-vs-process lane: emits ``BENCH_process_pool.json``."""
    from repro.core.testbed import available_cores

    ns = (1, 2) if quick else (1, 2, 4)
    n_requests, max_new, reps = (6, 4, 1) if quick else (16, 8, 3)
    if quick:
        from repro.configs.registry import get_config as _get
        cfg = _get("qwen3-0.6b-reduced")
    else:
        cfg = bench_config()
    requests = make_requests(cfg, n_requests, max_new, plen_range=(20, 60))
    rows = measure_process_pool(cfg, requests, ns=ns, reps=reps)
    avail = len(available_cores())
    lines = ["# Pool scaling — thread vs process (pinned cpuset) containers",
             "", f"{n_requests} requests × {max_new} new tokens, arch "
             f"{cfg.name}, {avail} host cores; wall excludes spawn+compile "
             "(warm pools)", ""]
    lines += table(
        ["n", "thread wall (s)", "process wall (s)", "thread E (J)",
         "process E (J)", "spawn+compile (s)", "shared cores"],
        [[r["n"], r["wall_thread_s"], r["wall_process_s"],
          r["energy_thread_j"], r["energy_process_j"],
          r["process_spawn_s"], str(r["shared_cores"])] for r in rows])
    save_bench("process_pool", {
        "config": cfg.name, "host_cores": avail,
        "per_n": {str(r["n"]): {k: v for k, v in r.items() if k != "n"}
                  for r in rows}})
    return save("pool_scaling_process", {"measured": rows}, lines)


def measure_streaming(model, params, requests, ns=(1, 2, 4), n_slots=2,
                      max_len=128, reps: int = 3) -> list[dict]:
    """Request-level streaming through the Router: per count, the wave is
    admitted one request at a time (continuous admission, least-loaded +
    bucket-aware dispatch) and consumed as chunk events. Records wall,
    tokens/s and time-to-first-chunk p50/p95 — the latency axis the wave
    API could not even observe."""
    import numpy as np

    from repro.serving import Request, Router
    from repro.serving.backend import ThreadBackend

    def clone(reqs):
        return [Request(r.rid, r.prompt.copy(), r.max_new_tokens)
                for r in reqs]

    rows = []
    for n in ns:
        router = Router(ThreadBackend(model, params, n,
                                      n_slots_per_container=n_slots,
                                      max_len=max_len))
        # compile warmup (prefill buckets + chunk lengths)
        for h in [router.submit(r) for r in clone(requests)]:
            h.result()
        best = None
        for _ in range(reps):
            t0 = time.perf_counter()
            handles = [router.submit(r) for r in clone(requests)]
            router.drain()
            wall = time.perf_counter() - t0
            ttfc = [h.ttfc_s for h in handles if h.ttfc_s is not None]
            toks = sum(len(h.completion.tokens) for h in handles)
            row = {"n": n, "wall_s": wall,
                   "tokens_per_s": toks / wall if wall > 0 else 0.0,
                   "ttfc_p50_s": float(np.percentile(ttfc, 50)),
                   "ttfc_p95_s": float(np.percentile(ttfc, 95))}
            if best is None or row["wall_s"] < best["wall_s"]:
                best = row
        router.close()
        rows.append(best)
    return rows


def run_streaming(quick: bool = False) -> str:
    """The streaming lane: emits ``BENCH_streaming.json`` (time-to-first-
    chunk percentiles + streamed throughput per container count)."""
    import jax

    ns = (1, 2) if quick else (1, 2, 4)
    n_requests, max_new, reps = (6, 4, 1) if quick else (16, 8, 3)
    if quick:
        from repro.configs.registry import get_config as _get
        cfg = _get("qwen3-0.6b-reduced")
    else:
        cfg = bench_config()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    requests = make_requests(cfg, n_requests, max_new, plen_range=(20, 60))
    rows = measure_streaming(model, params, requests, ns=ns, reps=reps,
                             max_len=128)
    lines = ["# Pool scaling — request-level streaming (Router)",
             "", f"{n_requests} requests × {max_new} new tokens, arch "
             f"{cfg.name}; continuous admission, chunk-event consumption; "
             "warm engines (compile excluded)", ""]
    lines += table(
        ["n", "wall (s)", "tok/s", "ttfc p50 (s)", "ttfc p95 (s)"],
        [[r["n"], r["wall_s"], r["tokens_per_s"], r["ttfc_p50_s"],
          r["ttfc_p95_s"]] for r in rows])
    save_bench("streaming", {
        "config": cfg.name,
        "per_n": {str(r["n"]): {k: v for k, v in r.items() if k != "n"}
                  for r in rows}})
    return save("pool_scaling_streaming", {"measured": rows}, lines)


def measure_paged(model, params, requests, ns=(1, 2), n_slots=2,
                  max_len=128, block_size=16, reps: int = 3) -> list[dict]:
    """Dense vs paged KV cache at EQUAL HBM budget (the paged pool
    defaults to the dense footprint: ``n_slots × max_len / block_size``
    blocks). Same streamed wave through the Router both ways; per row:
    tokens/s, time-to-first-chunk p50/p95, and the max sustained
    in-flight per container (``engine.peak_active``) — the paged engine
    must exceed ``n_slots``, the dense engine cannot."""
    import numpy as np

    from repro.serving import EngineConfig, Request, Router
    from repro.serving.backend import ThreadBackend

    def clone(reqs):
        return [Request(r.rid, r.prompt.copy(), r.max_new_tokens)
                for r in reqs]

    rows = []
    for n in ns:
        for cache in ("dense", "paged"):
            ecfg = EngineConfig(n_slots=n_slots, max_len=max_len,
                                cache=cache, block_size=block_size)
            backend = ThreadBackend(model, params, n, config=ecfg)
            router = Router(backend)
            # compile warmup (prefill buckets + chunk lengths)
            for h in [router.submit(r) for r in clone(requests)]:
                h.result()
            best = None
            for _ in range(reps):
                t0 = time.perf_counter()
                handles = [router.submit(r) for r in clone(requests)]
                router.drain()
                wall = time.perf_counter() - t0
                ttfc = [h.ttfc_s for h in handles if h.ttfc_s is not None]
                toks = sum(len(h.completion.tokens) for h in handles)
                row = {"n": n, "cache": cache, "wall_s": wall,
                       "tokens_per_s": toks / wall if wall > 0 else 0.0,
                       "ttfc_p50_s": float(np.percentile(ttfc, 50)),
                       "ttfc_p95_s": float(np.percentile(ttfc, 95))}
                if best is None or row["wall_s"] < best["wall_s"]:
                    best = row
            best["n_slots"] = n_slots
            best["kv_blocks"] = ecfg.resolved_max_blocks
            best["max_in_flight"] = max(e.peak_active
                                        for e in backend.engines)
            router.close()
            rows.append(best)
    return rows


def run_paged(quick: bool = False) -> str:
    """The paged-cache lane: emits ``BENCH_paged.json``. The headline
    number is ``max_in_flight``: at the same HBM budget the paged engine
    packs strictly more concurrent short requests per container than the
    dense engine has slots."""
    import jax

    ns = (1,) if quick else (1, 2)
    n_requests, max_new, reps = (8, 4, 1) if quick else (24, 6, 3)
    if quick:
        from repro.configs.registry import get_config as _get
        cfg = _get("qwen3-0.6b-reduced")
    else:
        cfg = bench_config()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # short prompts + small budgets: the workload the dense layout wastes
    # a full max_len row on, and the paged layout packs by the block
    requests = make_requests(cfg, n_requests, max_new, plen_range=(8, 24))
    rows = measure_paged(model, params, requests, ns=ns, reps=reps)
    n_slots = rows[0]["n_slots"]
    paged_rows = [r for r in rows if r["cache"] == "paged"]
    exceeds = all(r["max_in_flight"] > n_slots for r in paged_rows)
    lines = ["# Pool scaling — dense vs paged KV cache (equal HBM budget)",
             "", f"{n_requests} requests × {max_new} new tokens, arch "
             f"{cfg.name}; n_slots={n_slots}, paged pool = dense footprint "
             f"({paged_rows[0]['kv_blocks']} blocks); streamed via the "
             "Router, warm engines", ""]
    lines += table(
        ["n", "cache", "wall (s)", "tok/s", "ttfc p50 (s)", "ttfc p95 (s)",
         "max in-flight"],
        [[r["n"], r["cache"], r["wall_s"], r["tokens_per_s"],
          r["ttfc_p50_s"], r["ttfc_p95_s"], r["max_in_flight"]]
         for r in rows])
    lines += ["", f"paged max in-flight > n_slots={n_slots} on every "
              f"count: {exceeds}"]
    save_bench("paged", {
        "config": cfg.name, "n_slots": n_slots,
        "kv_blocks": paged_rows[0]["kv_blocks"],
        "paged_exceeds_slots": exceeds,
        "per_n": {f"{r['n']}_{r['cache']}":
                  {k: v for k, v in r.items() if k not in ("n", "cache")}
                  for r in rows}})
    return save("pool_scaling_paged", {"measured": rows}, lines)


def run(quick: bool = False) -> str:
    import jax

    n_requests, max_new, reps = (8, 4, 2) if quick else (16, 8, 3)
    cfg = bench_config()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    requests = make_requests(cfg, n_requests, max_new, plen_range=(20, 60))

    rows = measure_pool(model, params, requests, reps=reps)
    payload: dict = {"measured": rows}
    base = rows[0]["wall_seq_s"]
    md_rows = [[r["n"], r["wall_seq_s"], r["wall_conc_s"], r["speedup"],
                r["wall_conc_s"] / base, r["energy_seq_j"],
                r["energy_conc_j"]] for r in rows]
    lines = ["# Pool scaling — concurrent vs sequential container pool",
             "", f"{n_requests} requests × {max_new} new tokens, "
             f"arch {cfg.name} (bench reduction)", ""]
    lines += table(["n", "seq wall (s)", "conc wall (s)", "speedup",
                    "conc vs n=1 seq", "E seq (J)", "E conc (J)"], md_rows)

    picks, choices, known = adaptive_convergence()
    converged_at = next((i for i in range(len(choices))
                         if all(c == known for c in choices[i:])), None)
    payload["adaptive"] = {"picks": picks, "choices": choices,
                           "known_optimum": known,
                           "converged_at_wave": converged_at}
    lines += ["", "## Adaptive pool on synthetic convex profile "
              f"(known optimum n={known})", "",
              f"per-wave picks:   {picks}",
              f"per-wave choices: {choices}",
              f"converged at wave: {converged_at}"]
    best = max(rows, key=lambda r: r["speedup"])
    save_bench("pool_scaling", {
        "config": cfg.name,
        "best_speedup": best["speedup"], "best_speedup_n": best["n"],
        "adaptive_converged_at_wave": converged_at,
        "per_n": {str(r["n"]): {"wall_seq_s": r["wall_seq_s"],
                                "wall_conc_s": r["wall_conc_s"],
                                "energy_seq_j": r["energy_seq_j"],
                                "energy_conc_j": r["energy_conc_j"]}
                  for r in rows}})
    return save("pool_scaling", payload, lines)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", "--smoke", action="store_true", dest="quick",
                    help="tiny config / fewer counts (CI smoke)")
    ap.add_argument("--isolation", default="thread",
                    choices=("thread", "process"),
                    help="thread: sequential-vs-concurrent lane (default); "
                         "process: thread-vs-pinned-process lane emitting "
                         "BENCH_process_pool.json")
    ap.add_argument("--streaming", action="store_true",
                    help="request-level streaming lane (Router): "
                         "time-to-first-chunk p50/p95 + streamed tok/s, "
                         "emitting BENCH_streaming.json")
    ap.add_argument("--paged", action="store_true",
                    help="dense vs paged KV cache at equal HBM budget: "
                         "tok/s, ttfc p50/p95, max sustained in-flight, "
                         "emitting BENCH_paged.json")
    args = ap.parse_args()
    if args.paged:
        print(run_paged(quick=args.quick))
    elif args.streaming:
        print(run_streaming(quick=args.quick))
    elif args.isolation == "process":
        print(run_process(quick=args.quick))
    else:
        print(run(quick=args.quick))
