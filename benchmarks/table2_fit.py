"""Table II — convex model fitting.

Fits the paper's two model families (quadratic, saturating exponential) to
(a) the calibrated device simulators and (b) the host testbed measurements,
and compares the recovered coefficients / curve shapes against the paper's
published fits.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save, table
from repro.core import testbed
from repro.core.energy_model import (PAPER_MODELS, eval_model, fit_best,
                                     orin_model, tx2_model)


def run(quick: bool = False) -> str:
    payload, rows = {}, []
    for name, dev, n_max in (("tx2", tx2_model(), 6),
                             ("orin", orin_model(), 12)):
        ns = np.arange(1, n_max + 1, dtype=float)
        for metric, series in (
                ("time", [dev.time(int(n)) / dev.time(1) for n in ns]),
                ("energy", [dev.energy(int(n)) / dev.energy(1) for n in ns]),
                ("power", [dev.power(int(n)) / dev.power(1) for n in ns])):
            fit = fit_best(ns, series)
            pk, pc = PAPER_MODELS[(name, metric)]
            paper_vals = eval_model(pk, pc, ns)
            # normalise the paper model to its own x=1 value so both curves
            # share the benchmark-relative scale
            paper_vals = paper_vals / paper_vals[0]
            ours = fit(ns) / fit(ns)[0]
            shape_rmse = float(np.sqrt(np.mean((ours - paper_vals) ** 2)))
            payload[f"{name}.{metric}"] = {
                "fit_kind": fit.kind, "coef": list(fit.coef),
                "rmse": fit.rmse, "paper_kind": pk,
                "shape_rmse_vs_paper": shape_rmse}
            rows.append([name, metric, fit.kind,
                         ", ".join(f"{c:.3f}" for c in fit.coef),
                         pk, fit.rmse, shape_rmse])

    lines = ["# Table II — fitted convex models (normalised)",
             "",
             "`shape_rmse` compares our fitted curve against the paper's "
             "published fit over the same n range.", ""]
    lines += table(["device", "metric", "fit", "coef", "paper form",
                    "fit rmse", "shape rmse"], rows)

    # fits on the REAL testbed measurements
    n_frames = 64 if quick else 192
    frames = testbed.make_video(n_frames)
    ns = [1, 2, 3, 4, 6, 8]
    meas_t, meas_e = [], []
    for n in ns:
        # explicit time-sharing for counts past this host's core budget
        r = testbed.run_split(frames, n, total_cores=8, allow_shared=True)
        meas_t.append(r.wall_s)
        meas_e.append(r.energy_j)
    t_fit = fit_best(np.array(ns, float), np.array(meas_t) / meas_t[0])
    e_fit = fit_best(np.array(ns, float), np.array(meas_e) / meas_e[0])
    payload["host.time"] = {"kind": t_fit.kind, "coef": list(t_fit.coef),
                            "rmse": t_fit.rmse,
                            "argmin": t_fit.argmin(8), "samples": meas_t}
    payload["host.energy"] = {"kind": e_fit.kind, "coef": list(e_fit.coef),
                              "rmse": e_fit.rmse,
                              "argmin": e_fit.argmin(8), "samples": meas_e}
    lines += ["", "## Host testbed fits (real wall times)", ""]
    lines += table(
        ["metric", "fit", "coef", "rmse", "argmin n"],
        [["time", t_fit.kind, ", ".join(f"{c:.3f}" for c in t_fit.coef),
          t_fit.rmse, t_fit.argmin(8)],
         ["energy", e_fit.kind, ", ".join(f"{c:.3f}" for c in e_fit.coef),
          e_fit.rmse, e_fit.argmin(8)]])
    return save("table2_fit", payload, lines)


if __name__ == "__main__":
    print(run())
