"""Benchmark harness entry point: one benchmark per paper table/figure +
the TPU adaptation sweep.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-tpu]
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-tpu", action="store_true",
                    help="skip the (slower) pod-factorisation sweep")
    args = ap.parse_args()

    from benchmarks import (decode_throughput, fig1_cores, fig3_split,
                            pool_scaling, table2_fit)

    t0 = time.time()
    print("=" * 72)
    print("fig1_cores — single container, varying CPU allocation")
    print("=" * 72)
    print(fig1_cores.run(quick=args.quick))

    print("=" * 72)
    print("fig3_split — n containers: time / energy / power")
    print("=" * 72)
    print(fig3_split.run(quick=args.quick))

    print("=" * 72)
    print("table2_fit — convex model fits")
    print("=" * 72)
    print(table2_fit.run(quick=args.quick))

    print("=" * 72)
    print("pool_scaling — concurrent container pool + adaptive scheduler")
    print("=" * 72)
    print(pool_scaling.run(quick=args.quick))

    print("=" * 72)
    print("pool_scaling (process) — thread vs pinned-process containers")
    print("=" * 72)
    print(pool_scaling.run_process(quick=args.quick))

    print("=" * 72)
    print("pool_scaling (streaming) — Router time-to-first-chunk")
    print("=" * 72)
    print(pool_scaling.run_streaming(quick=args.quick))

    print("=" * 72)
    print("decode_throughput — fused chunked decode vs per-token")
    print("=" * 72)
    print(decode_throughput.run(quick=args.quick))

    if not args.skip_tpu:
        sweeps = [("qwen3-8b", "decode_32k")]
        if not args.quick:
            sweeps.append(("qwen3-8b", "prefill_32k"))
        for arch, shape in sweeps:
            print("=" * 72)
            print(f"tpu_split — divide-and-save on the 256-chip pod: "
                  f"{arch} × {shape} (subprocess: 512-device override)")
            print("=" * 72)
            cmd = [sys.executable, "-m", "benchmarks.tpu_split",
                   "--arch", arch, "--shape", shape]
            if args.quick:
                cmd.append("--quick")
            r = subprocess.run(cmd)
            if r.returncode != 0:
                print("tpu_split FAILED")
                return 1

    print(f"\nall benchmarks done in {time.time()-t0:.0f}s "
          f"(results in benchmarks/results/)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
