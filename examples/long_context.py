"""Long-context decode: why the SSM/hybrid/SWA/MLA architectures run the
500k-token shape and the pure full-attention ones don't.

Decodes with growing context on reduced variants of one architecture per
long-context family and prints the per-token state/cache footprint — the
quantity that decides long_500k feasibility (DESIGN.md
§Arch-applicability). The SSM state is CONSTANT in context length; SWA is
constant beyond its window; MLA grows linearly but ~9× slimmer than a GQA
cache; full attention grows linearly at full width.

    PYTHONPATH=src python examples/long_context.py [--tokens 96]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.model import Model

FAMILIES = [
    ("mamba2-2.7b", "SSM — O(1) state"),
    ("zamba2-7b", "hybrid — SSM state + shared-attn cache"),
    ("gemma3-27b", "5:1 local:global SWA"),
    ("deepseek-v2-lite-16b", "MLA latent cache"),
    ("qwen3-0.6b", "full attention (long_500k SKIPPED on the pod)"),
]


def cache_bytes(cache) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))


def full_cache_bytes_at(arch: str, ctx: int) -> float:
    """FULL config cache footprint at context length ``ctx`` (analytic,
    bytes, bf16 cache) — the pod-feasibility number."""
    cfg = get_config(arch)
    if cfg.is_ssm:
        per_layer = (cfg.ssm_n_heads * cfg.ssm_head_dim * cfg.ssm_state
                     + (cfg.ssm_conv_width - 1)
                     * (cfg.d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_state))
        n_ssm = cfg.n_layers
        attn = 0
        if cfg.shared_attn_every:
            n_applications = cfg.n_layers // cfg.shared_attn_every
            attn = (n_applications * 2 * ctx * cfg.n_kv_heads
                    * cfg.head_dim)
        return (per_layer * n_ssm + attn) * 2.0
    if cfg.mla:
        return cfg.n_layers * ctx * (cfg.kv_lora_rank
                                     + cfg.qk_rope_head_dim) * 2.0
    per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * 2.0
    if cfg.local_global_pattern:
        k = cfg.local_global_pattern
        n_local = cfg.n_layers * k // (k + 1)
        n_global = cfg.n_layers - n_local
        return (n_local * min(ctx, cfg.sliding_window)
                + n_global * ctx) * per_tok
    win = cfg.sliding_window or ctx
    return cfg.n_layers * min(ctx, win) * per_tok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=96)
    args = ap.parse_args()

    print(f"{'architecture':24s} {'family':44s} "
          f"{'cache @32k':>12s} {'cache @512k':>12s} growth")
    for arch, family in FAMILIES:
        c32 = full_cache_bytes_at(arch, 32_768) / 1e9
        c512 = full_cache_bytes_at(arch, 524_288) / 1e9
        growth = "O(1)" if c512 / max(c32, 1e-9) < 1.5 else \
            f"{c512 / c32:.1f}× linear"
        print(f"{arch:24s} {family:44s} {c32:10.2f} GB {c512:10.2f} GB "
              f"{growth}")

    # live demo: a reduced SSM decodes a long stream with constant state
    print("\nreduced mamba2, decoding a growing context (REAL run):")
    cfg = get_config("mamba2-2.7b-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(1, args.tokens + 8)
    rng = np.random.default_rng(0)
    tok = jnp.asarray([[int(rng.integers(0, cfg.vocab_size))]], jnp.int32)
    decode = jax.jit(model.decode_step)
    base = cache_bytes(cache)
    for t in range(args.tokens):
        logits, cache = decode(params, tok, cache,
                               jnp.asarray([t], jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        if t in (0, args.tokens // 2, args.tokens - 1):
            assert cache_bytes(cache) == base, "SSM state must not grow"
            print(f"  t={t:4d}: state {cache_bytes(cache)/1e3:.1f} kB "
                  f"(constant), next token {int(tok[0, 0])}")
    print("state footprint constant over the whole stream ✓")


if __name__ == "__main__":
    main()
