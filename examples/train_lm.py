"""End-to-end training driver: a ~100M-parameter qwen3-family LM trained
for a few hundred steps on the synthetic stream, with checkpointing.

    PYTHONPATH=src python examples/train_lm.py --steps 300

The 100M config is the qwen3 block structure (GQA + qk_norm + SwiGLU) at
d_model 640 — same code path the pod runs at 8B, shrunk to CPU scale.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax

from repro.configs.base import ArchConfig
from repro.data.pipeline import LmTokenStream
from repro.models.model import Model
from repro.train import checkpoint
from repro.train.loop import TrainConfig, train
from repro.train.optimizer import AdamWConfig

LM100M = ArchConfig(
    name="lm100m",
    arch_type="dense",
    source="qwen3 family, scaled to ~100M for the CPU example",
    n_layers=10,
    d_model=640,
    n_heads=10,
    n_kv_heads=5,
    head_dim=64,
    d_ff=1792,
    vocab_size=50_304,
    qk_norm=True,
    rope_theta=1_000_000.0,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--out", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    model = Model(LM100M)
    print(f"params: {LM100M.param_count():,} (~100M target)")
    stream = LmTokenStream(LM100M.vocab_size, seq_len=args.seq,
                           batch_size=args.batch)
    tcfg = TrainConfig(opt=AdamWConfig(
        lr=args.lr, warmup_steps=20, total_steps=args.steps,
        weight_decay=0.1, grad_clip=1.0))

    t0 = time.time()
    history = []

    def log(step, m):
        history.append(m)
        print(f"step {step:4d}  loss {m['loss']:.4f}  "
              f"gnorm {m.get('grad_norm', 0):.2f}  lr {m.get('lr', 0):.2e}  "
              f"{m['wall_s']:.0f}s", flush=True)

    params, opt_state, hist = train(model, tcfg, stream.batches(),
                                    n_steps=args.steps, log_every=10,
                                    logger=log)
    os.makedirs(args.out, exist_ok=True)
    checkpoint.save(os.path.join(args.out, "final"), params,
                    meta={"steps": args.steps,
                          "final_loss": hist[-1]["loss"]})
    with open(os.path.join(args.out, "history.json"), "w") as f:
        json.dump(hist, f, indent=2)
    print(f"done in {time.time()-t0:.0f}s; "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}; "
          f"checkpoint at {args.out}")
    assert hist[-1]["loss"] < hist[0]["loss"], "training did not improve"


if __name__ == "__main__":
    main()
