"""Serving example: continuous batching + the divide-and-save container
pool.

Serves the same request set with 1, 2 and 4 containers (each container is a
ServingEngine replica given an equal share of the requests — §V), in both
sequential and concurrent mode, verifies the completions are identical
everywhere, and reports wall time + the energy proxy per configuration.

    PYTHONPATH=src python examples/serve_requests.py [--arch mamba2-2.7b]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import ARCH_NAMES, get_config
from repro.models.model import Model
from repro.serving.engine import Request
from repro.serving.pool import ContainerServingPool


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_NAMES)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=6)
    args = ap.parse_args()

    cfg = get_config(args.arch + "-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (int(rng.integers(4, 12)),),
                                        dtype=np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]

    reference = None
    for n in (1, 2, 4):
        pool = ContainerServingPool(model, params, n_containers=n,
                                    n_slots_per_container=2, max_len=64)
        pool.serve(list(reqs), concurrent=False)       # compile warmup
        _, _, w_seq, e_seq = pool.serve_timed(list(reqs), concurrent=False)
        ordered, per, w_con, e_con = pool.serve_timed(list(reqs),
                                                      concurrent=True)
        outs = [tuple(c.tokens) for c in ordered]
        if reference is None:
            reference = outs
        match = "✓" if outs == reference else "✗ MISMATCH"
        sizes = [r.n_requests for r in per]
        print(f"n={n}: seq {w_seq:6.2f}s ~{e_seq:5.1f}J | "
              f"conc {w_con:6.2f}s ~{e_con:5.1f}J "
              f"({w_seq/w_con:.2f}x)  split {sizes}  outputs {match}")
    print(f"\n{len(reference)} requests served; sample completion "
          f"(rid=0): {list(reference[0])}")


if __name__ == "__main__":
    main()
