"""Quickstart: build an assigned architecture, train a few steps, serve.

Runs in ~1 minute on CPU (reduced configs).

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-0.6b]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import ARCH_NAMES, get_config
from repro.data.pipeline import LmTokenStream
from repro.models.model import Model
from repro.serving.engine import Request, ServingEngine
from repro.train.loop import TrainConfig, train
from repro.train.optimizer import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_NAMES)
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    # 1. any assigned architecture, reduced to CPU scale
    cfg = get_config(args.arch + "-reduced")
    model = Model(cfg)
    print(f"arch={cfg.name}  params={cfg.param_count():,}")

    # 2. a short training run on the synthetic LM stream
    stream = LmTokenStream(cfg.vocab_size, seq_len=32, batch_size=8)
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=5,
                                       total_steps=args.steps))
    params, _, hist = train(model, tcfg, stream.batches(),
                            n_steps=args.steps, log_every=10,
                            logger=lambda s, m: print(
                                f"  step {s:3d}  loss {m['loss']:.3f}"))
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    # 3. serve a few requests with continuous batching
    eng = ServingEngine(model, params, n_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(4):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab_size, (8,),
                                               dtype=np.int32),
                           max_new_tokens=8))
    for c in eng.run():
        print(f"  request {c.rid}: generated {c.tokens}")


if __name__ == "__main__":
    main()
