"""The paper's experiment, literally: video object detection split among
CPU-pinned containers.

A synthetic video (independent frames) is processed by a YOLOv4-tiny-shaped
detector. The workload is split into n equal segments; n OS processes
("containers") are pinned to disjoint CPU-core sets (the in-process
equivalent of ``docker run --cpus``) and run simultaneously; outputs are
recombined in frame order. Real wall times; energy from the activity model
(no power sensor on this host — constants in core/testbed.py).

Finally the DivideAndSave scheduler consumes the observations and picks the
optimal container count online (paper §VII's proposed application).

``--stream`` serves the paper's *continuous* form of the same workload
through the request-level ``Router`` (serving/router.py): the video
becomes a stream of per-frame requests (``VideoRequestStream``) admitted
one at a time, completions stream back as per-chunk events, and the
scheduler resizes the container count between observation windows — no
explicit waves anywhere.

    PYTHONPATH=src python examples/serve_video_detection.py \
        --frames 240 --cores 8
    PYTHONPATH=src python examples/serve_video_detection.py \
        --stream --frames 48 --window 12
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import testbed
from repro.core.energy_model import fit_best
from repro.core.scheduler import DivideAndSaveScheduler


def stream_main(args) -> None:
    """The continuous-workload mode: per-frame requests through the
    Router, windowed online scheduling instead of waves."""
    import jax

    from repro.configs.registry import get_config
    from repro.data.pipeline import VideoRequestStream
    from repro.models.model import Model
    from repro.serving import Request, Router, ThreadBackend

    cfg = get_config("qwen3-0.6b-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stream = VideoRequestStream(n_frames=args.frames, seed=0)
    prompts = stream.prompt_requests(cfg.vocab_size, prompt_len=8)
    print(f"video stream: {args.frames} frame-requests, window "
          f"{args.window}, feasible counts {args.counts}\n")

    router = Router(
        backend_factory=lambda n: ThreadBackend(
            model, params, n, n_slots_per_container=2, max_len=64),
        feasible_counts=list(args.counts),
        objective="energy", window=args.window)
    handles = []
    for rid, prompt in enumerate(prompts):
        handles.append(router.submit(Request(rid=rid, prompt=prompt,
                                             max_new_tokens=4)))
        router.poll()               # frames keep arriving mid-decode
        if (rid + 1) % args.window == 0:
            # arrival pause (the camera's next GOP): the stream drains,
            # which is when a pending resize takes effect
            router.drain()
    router.drain()
    assert all(h.done for h in handles)
    for w in router.history:
        print(f"window {w.window}: n={w.n_containers} wall {w.wall_s:.2f}s"
              f" {w.tokens_per_s:.1f} tok/s energy {w.energy_j:.1f}J "
              f"ttfc p50 {w.ttfc_p50_s * 1e3:.0f}ms "
              f"p95 {w.ttfc_p95_s * 1e3:.0f}ms")
    print(f"\n{len(handles)} frames served in submission order: "
          f"{[h.rid for h in handles] == list(range(args.frames))}")
    print(f"scheduler's converged choice: n={router.choice}")
    router.close()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=240)
    ap.add_argument("--cores", type=int, default=8)
    ap.add_argument("--counts", type=int, nargs="*",
                    default=[1, 2, 3, 4, 6, 8])
    ap.add_argument("--stream", action="store_true",
                    help="continuous per-frame requests through the "
                         "Router (windowed online scheduling)")
    ap.add_argument("--window", type=int, default=16,
                    help="scheduler observation window (requests)")
    args = ap.parse_args()
    if args.stream:
        stream_main(args)
        return

    frames = testbed.make_video(args.frames)
    print(f"video: {args.frames} frames {frames.shape[1:]}  "
          f"device: {args.cores} cores\n")
    base = None
    observations = []
    print("  n  cores/ctr   wall (s)   power (W)   energy (J)   "
          "t/t1    E/E1   outputs")
    for n in args.counts:
        # allow_shared: counts past this host's core budget fall back to
        # explicit round-robin time-sharing (run_split refuses the old
        # silent overlap) so the paper-style sweep works on small hosts
        r = testbed.run_split(frames, n, total_cores=args.cores,
                              allow_shared=True)
        if base is None:
            base = r
        ok = "✓" if np.allclose(r.outputs, base.outputs, atol=1e-5) else "✗"
        observations.append((n, r.wall_s, r.energy_j))
        print(f"  {n:2d}  {r.cores_per_container:9d}   {r.wall_s:8.2f}   "
              f"{r.avg_power_w:9.1f}   {r.energy_j:10.1f}   "
              f"{r.wall_s/base.wall_s:5.2f}  {r.energy_j/base.energy_j:5.2f}"
              f"   {ok}")

    ns = np.array([o[0] for o in observations], float)
    tfit = fit_best(ns, np.array([o[1] for o in observations]) / base.wall_s)
    efit = fit_best(ns, np.array([o[2] for o in observations])
                    / base.energy_j)
    print(f"\nfitted time model:   {tfit.kind} "
          f"{tuple(round(c, 3) for c in tfit.coef)} (rmse {tfit.rmse:.3f})")
    print(f"fitted energy model: {efit.kind} "
          f"{tuple(round(c, 3) for c in efit.coef)} (rmse {efit.rmse:.3f})")

    sched = DivideAndSaveScheduler(list(args.counts), objective="energy",
                                   epsilon=0.0)
    for n, t, e in observations:
        sched.observe(n, t, e)
    print(f"scheduler picks n* = {sched.pick()} (energy objective)")

    n_best, t_best, e_best = min(observations, key=lambda o: o[2])
    print(f"\nbest measured: n={n_best}: "
          f"time −{(1-t_best/base.wall_s)*100:.0f}%  "
          f"energy −{(1-e_best/base.energy_j)*100:.0f}% vs one container")


if __name__ == "__main__":
    main()
