"""SLO specifications and the shared admission-policy arithmetic.

ECORE (PAPERS.md) frames edge serving as energy minimisation *subject
to* latency constraints per request class; this module is that
constraint vocabulary. An ``SLOSpec`` names priority classes, each with
a time-to-first-chunk p95 target, a rank (0 = most important) and a
queue share. Three consumers read it:

* the ``Router`` (serving/router.py) — priority-ordered dispatch,
  SLO-derived shed thresholds, per-tenant quotas, per-class window
  attainment;
* the ``DivideAndSaveScheduler`` (core/scheduler.py) — the binding
  class's target becomes the quantile constraint of the
  ``energy_under_slo`` objective;
* the virtual-time fleet simulator (workload/sim.py) — which calls the
  SAME threshold helpers below, so simulated scheduling claims exercise
  the real policy arithmetic, not a reimplementation.

``queue_limit`` / ``shed_ttfc_threshold`` are deliberately tiny pure
functions: single-sourcing them is what "SLO-derived shed thresholds"
means — nothing recomputes a threshold from a class target anywhere
else. All dataclasses here are frozen, picklable wire types registered
with the static wire auditor. Import-light (stdlib only).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One priority class. ``rank`` orders dispatch (0 first);
    ``queue_frac`` is the fraction of the router's ``max_queue`` this
    class may fill before it sheds — lower classes get smaller
    fractions, so overload degrades bottom-up instead of uniformly."""
    name: str = "default"
    ttfc_p95_s: float = 1.0
    rank: int = 0
    queue_frac: float = 1.0
    latency_p95_s: float | None = None

    def __post_init__(self):
        if self.ttfc_p95_s <= 0:
            raise ValueError(f"class {self.name!r}: ttfc_p95_s must be "
                             f"positive, got {self.ttfc_p95_s}")
        if not 0.0 < self.queue_frac <= 1.0:
            raise ValueError(f"class {self.name!r}: queue_frac must be in "
                             f"(0, 1], got {self.queue_frac}")


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    classes: tuple = (SLOClass(),)

    def __post_init__(self):
        names = [c.name for c in self.classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate class names in {names}")

    def cls(self, name: str) -> SLOClass:
        """The class for a request's ``priority`` string. Unknown names
        map to the WORST class (highest rank): unlabelled traffic must
        not jump the queue."""
        for c in self.classes:
            if c.name == name:
                return c
        return max(self.classes, key=lambda c: c.rank)

    @property
    def constraint(self) -> SLOClass:
        """The binding class for the scheduler's quantile constraint:
        the tightest ttfc target."""
        return min(self.classes, key=lambda c: c.ttfc_p95_s)

    def names(self) -> tuple:
        return tuple(c.name for c in self.classes)

    @staticmethod
    def parse(text: str) -> "SLOSpec":
        """``"interactive:0.5,batch:4.0"`` → classes ranked in listed
        order, with queue shares stepping down 1.0, 0.5, 0.25… per rank
        (an optional third ``:frac`` field overrides the share)."""
        classes = []
        for rank, part in enumerate(p for p in text.split(",") if p):
            fields = part.split(":")
            if len(fields) not in (2, 3):
                raise ValueError(
                    f"bad SLO class {part!r} (want name:ttfc_p95_s"
                    "[:queue_frac])")
            frac = float(fields[2]) if len(fields) == 3 \
                else 1.0 / (2 ** rank)
            classes.append(SLOClass(name=fields[0],
                                    ttfc_p95_s=float(fields[1]),
                                    rank=rank, queue_frac=frac))
        if not classes:
            raise ValueError(f"no SLO classes in {text!r}")
        return SLOSpec(classes=tuple(classes))


@dataclasses.dataclass(frozen=True)
class ClassWindow:
    """Per-class slice of one observation window (or of a whole replay
    report): counts, tails, and SLO attainment. ``attained`` is None
    when the class saw no completions (nothing to attain or violate)."""
    name: str = "default"
    n_done: int = 0
    n_shed: int = 0
    n_failed: int = 0
    ttfc_p50_s: float = 0.0
    ttfc_p95_s: float = 0.0
    latency_p50_s: float = 0.0
    latency_p95_s: float = 0.0
    target_ttfc_p95_s: float | None = None
    attained: bool | None = None


# ---------------------------------------------------------------------------
# the shared threshold arithmetic (Router AND simulator call these)
# ---------------------------------------------------------------------------
def queue_limit(cls: SLOClass, max_queue: int | None) -> int | None:
    """How many requests may be in flight before THIS class sheds:
    ``max_queue`` scaled by the class's queue share (≥1 so a class is
    never statically locked out). None = unbounded."""
    if max_queue is None:
        return None
    return max(1, int(max_queue * cls.queue_frac))


# headroom over a class's target before admission control sheds it:
# sheds exist to stop hopeless overload, not to enforce the target —
# shedding AT the target throws away arrivals that would still have
# completed within their deadlines (the scheduler enforces the target
# by picking a feasible container count, not by dropping work)
SHED_HEADROOM = 2.0


def shed_ttfc_threshold(cls: SLOClass,
                        override: float | None) -> float | None:
    """The ttfc-p95 level past which this class sheds new arrivals: an
    explicit router-wide ``shed_p95_s`` wins; otherwise the class's own
    SLO target with ``SHED_HEADROOM`` slack — once the tail is that far
    past the promise, admitting more of the class only deepens the
    violation."""
    return override if override is not None \
        else SHED_HEADROOM * cls.ttfc_p95_s


def censored_ttfc_p95(ttfc: list, n_lost: int,
                      cap_s: float) -> float | None:
    """p95 of a class's ttfc **counting lost arrivals as violations**
    (value ``cap_s``, the censoring cap — e.g. 2× the class target).
    ``n_lost`` is shed + failed: admission control pins the *admitted*
    p95 near the shed threshold and deadline expiry removes exactly the
    requests that waited longest, so both losses censor the tail — drop
    them from the sample and every container count looks SLO-feasible
    to the scheduler. None with no observations at all."""
    total = len(ttfc) + n_lost
    if total == 0:
        return None
    k = max(0, -(-95 * total // 100) - 1)   # ceil(0.95·total) - 1
    s = sorted(ttfc)
    return float(s[k]) if k < len(s) else float(cap_s)


def class_window(cls: SLOClass | None, name: str,
                 ttfc: list, latency: list,
                 n_shed: int = 0, n_failed: int = 0) -> ClassWindow:
    """Assemble one per-class window summary from raw samples (shared
    by the Router's window rotation and the replay reports)."""
    import numpy as np
    p = (lambda v, q: float(np.percentile(v, q)) if v else 0.0)
    target = cls.ttfc_p95_s if cls is not None else None
    p95 = p(ttfc, 95)
    return ClassWindow(
        name=name, n_done=len(latency), n_shed=n_shed, n_failed=n_failed,
        ttfc_p50_s=p(ttfc, 50), ttfc_p95_s=p95,
        latency_p50_s=p(latency, 50), latency_p95_s=p(latency, 95),
        target_ttfc_p95_s=target,
        attained=(p95 <= target if target is not None and ttfc else None))
