"""Trace-driven workload subsystem: seeded arrival-process + length
generators (``traces``), SLO class vocabulary and the shared admission
arithmetic (``slo``), an open-loop replayer over the live Router
(``replay``) and its deterministic virtual-time twin (``sim``)."""
from repro.workload.slo import ClassWindow, SLOClass, SLOSpec
from repro.workload.traces import (PRESETS, Trace, TraceRequest, TraceSpec,
                                   get_preset, load_or_synthesize,
                                   synthesize)

__all__ = [
    "ClassWindow", "SLOClass", "SLOSpec",
    "PRESETS", "Trace", "TraceRequest", "TraceSpec",
    "get_preset", "load_or_synthesize", "synthesize",
]
