"""Open-loop trace replay — drive a live ``Router`` at trace timestamps.

Closed-loop load generators (submit, wait, submit) hide overload: the
generator slows down with the system and the tail never materialises.
Replay here is **open-loop**: every ``TraceRequest`` is submitted at its
trace arrival time (scaled by ``time_scale``) whether or not earlier
requests finished, so queueing, shedding and tail latency appear exactly
as they would under the real arrival process. The replayer never blocks
on a handle — it pumps the router while waiting for the next arrival and
drains once the trace is exhausted.

The outcome is a ``ReplayReport``: goodput (completions whose ttfc met
their class target, per second), per-class tails + SLO attainment
(``workload.slo.ClassWindow``), shed/failed accounting and the energy
ledger summed over the router's observation windows. The report is a
frozen picklable wire dataclass (registered with the static wire
auditor) with a ``to_dict`` for the benchmark JSON.

Wall-clock replay is inherently non-reproducible bit-for-bit; the
deterministic virtual-time twin lives in ``workload/sim.py`` and returns
the SAME report type, so benchmarks can smoke-test live and commit
simulated numbers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

from repro.serving.engine import Request
from repro.serving.events import RejectedEvent
from repro.workload.slo import ClassWindow, SLOSpec, class_window
from repro.workload.traces import Trace, TraceRequest, prompt_tokens

_POLL_SLEEP_S = 0.001


@dataclasses.dataclass(frozen=True)
class ReplayReport:
    """Everything a replay (live or simulated) says about one trace run.
    ``goodput_rps`` counts only completions whose ttfc met their class
    target (all completions when no SLO is in force) — completing a
    request after blowing its target is not good throughput.
    ``energy_per_done_j`` is the ledger the paper's objective actually
    cares about: shed and failed requests still burned energy."""
    trace: str = ""
    seed: int = 0
    n_requests: int = 0
    n_done: int = 0
    n_shed: int = 0
    n_failed: int = 0
    duration_s: float = 0.0
    goodput_rps: float = 0.0
    energy_j: float = 0.0
    energy_per_done_j: float = 0.0
    ttfc_p50_s: float = 0.0
    ttfc_p95_s: float = 0.0
    latency_p50_s: float = 0.0
    latency_p95_s: float = 0.0
    slo_attained: bool | None = None   # every class met its target
    time_scale: float = 1.0
    counts_visited: tuple = ()         # container counts the run used
    final_n: int = 0
    per_class: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["per_class"] = {name: dataclasses.asdict(cw)
                          for name, cw in self.per_class.items()}
        return d


def build_request(tr: TraceRequest, *, vocab_size: int = 256,
                  deadline_s: float | None = None) -> Request:
    """Materialise one serving ``Request`` from a trace record (prompt
    ids regenerated from ``prompt_seed`` — traces store no token
    arrays)."""
    return Request(
        rid=tr.rid,
        prompt=np.asarray(prompt_tokens(tr, vocab_size), dtype=np.int32),
        max_new_tokens=tr.max_new_tokens,
        deadline_s=deadline_s,
        priority=tr.priority,
        tenant=tr.tenant,
    )


def assemble_report(trace: Trace, *, slo: SLOSpec | None,
                    done: list, shed: list, failed: list,
                    duration_s: float, energy_j: float,
                    time_scale: float = 1.0,
                    counts_visited: tuple = (),
                    final_n: int = 0) -> ReplayReport:
    """Shared report assembly for the live replayer AND the simulator.
    ``done`` holds (priority, ttfc_s, latency_s) triples; ``shed`` and
    ``failed`` hold priority names."""
    by_cls: dict[str, dict] = {}

    def acc(name: str) -> dict:
        return by_cls.setdefault(
            name, {"ttfc": [], "lat": [], "shed": 0, "failed": 0})

    for pri, ttfc, lat in done:
        a = acc(pri)
        if ttfc is not None:
            a["ttfc"].append(ttfc)
        a["lat"].append(lat)
    for pri in shed:
        acc(pri)["shed"] += 1
    for pri in failed:
        acc(pri)["failed"] += 1

    per_class: dict[str, ClassWindow] = {}
    for name, a in sorted(by_cls.items()):
        cls = slo.cls(name) if slo is not None else None
        per_class[name] = class_window(cls, name, a["ttfc"], a["lat"],
                                       a["shed"], a["failed"])

    good = 0
    for pri, ttfc, _ in done:
        target = slo.cls(pri).ttfc_p95_s if slo is not None else None
        if target is None or (ttfc is not None and ttfc <= target):
            good += 1
    ttfc_all = sorted(t for _, t, _ in done if t is not None)
    lat_all = sorted(l for _, _, l in done)
    p = (lambda v, q: float(np.percentile(v, q)) if v else 0.0)
    attained = None
    judged = [cw.attained for cw in per_class.values()
              if cw.attained is not None]
    if judged:
        attained = all(judged)
    n_done = len(done)
    return ReplayReport(
        trace=trace.name, seed=trace.seed,
        n_requests=len(trace.requests),
        n_done=n_done, n_shed=len(shed), n_failed=len(failed),
        duration_s=duration_s,
        goodput_rps=good / duration_s if duration_s > 0 else 0.0,
        energy_j=energy_j,
        energy_per_done_j=energy_j / n_done if n_done else 0.0,
        ttfc_p50_s=p(ttfc_all, 50), ttfc_p95_s=p(ttfc_all, 95),
        latency_p50_s=p(lat_all, 50), latency_p95_s=p(lat_all, 95),
        slo_attained=attained, time_scale=time_scale,
        counts_visited=tuple(counts_visited), final_n=final_n,
        per_class=per_class)


def replay(trace: Trace, router: Any, *, time_scale: float = 1.0,
           vocab_size: int = 256,
           max_requests: int | None = None) -> ReplayReport:
    """Replay ``trace`` against a live Router, open-loop. ``time_scale``
    compresses trace time (10.0 → a 600 s trace replays in 60 s — the
    arrival *pattern* is preserved, absolute rates are 10× — use for
    smoke runs only, and say so next to the numbers). Energy is the sum
    over the router's closed observation windows (scheduler mode); a
    fixed router without windows reports 0 and the caller should meter
    externally."""
    if time_scale <= 0:
        raise ValueError(f"time_scale must be positive, got {time_scale}")
    reqs = trace.requests[:max_requests] if max_requests else trace.requests
    slo = getattr(router, "slo", None)
    t0 = time.perf_counter()
    handles = []
    for tr in reqs:
        due = t0 + tr.arrival_s / time_scale
        while time.perf_counter() < due:
            router.poll()
            time.sleep(_POLL_SLEEP_S)
        handles.append((tr, router.submit(
            build_request(tr, vocab_size=vocab_size))))
    router.drain()
    duration = time.perf_counter() - t0

    done: list = []
    shed: list = []
    failed: list = []
    counts: list[int] = []
    for tr, h in handles:
        pri = (slo.cls(tr.priority).name if slo is not None
               else tr.priority)
        if h.completion is not None:
            lat = ((h.done_at - (t0 + tr.arrival_s / time_scale))
                   if h.done_at is not None else 0.0)
            done.append((pri, h.ttfc_s, lat))
        elif isinstance(h.failure, RejectedEvent):
            shed.append(pri)
        else:
            failed.append(pri)
    for w in getattr(router, "history", []):
        if w.n_containers not in counts:
            counts.append(w.n_containers)
    energy = sum(w.energy_j for w in getattr(router, "history", []))
    return assemble_report(
        trace, slo=slo, done=done, shed=shed, failed=failed,
        duration_s=duration, energy_j=energy, time_scale=time_scale,
        counts_visited=tuple(counts),
        final_n=getattr(router, "n_containers", 0))
