"""Deterministic virtual-time fleet simulator — the replayer's twin.

Wall-clock replay (``workload/replay.py``) can never reproduce a report
bit-for-bit: scheduler decisions depend on measured latencies, which
depend on the host's timing that run. This module removes the host: a
discrete-event simulation in **virtual time** where service and energy
come from an explicit ``FleetModel``, so the same (trace, seed, knobs)
always produces the identical ``ReplayReport`` — the property the
committed benchmark numbers rely on.

What is simulated vs real:

* **Real**: the ``DivideAndSaveScheduler`` (observations, convex fits,
  quantile model, ``energy_under_slo`` constraint, ε-greedy RNG) and the
  SLO policy arithmetic (``queue_limit`` / ``shed_ttfc_threshold`` /
  ``class_window`` from ``workload/slo.py``) — the exact objects the
  Router runs, so a scheduling claim proven here is about the real
  policy code, not a reimplementation.
* **Modelled**: container service and power. ``FleetModel`` splits a
  device of ``cores`` among ``n`` containers with Amdahl efficiency
  (the paper's observed divide-and-save effect: more containers extract
  more aggregate throughput from the same cores, sublinearly), burns
  static power per *provisioned* container plus idle floor, and dynamic
  power per actively-used core. That shape creates the paper's tension:
  calm traffic wants few containers (static power dominates), bursts
  want many (queueing blows the ttfc tail and sheds load).

The admission/dispatch policy mirrors the Router's SLO mode: per-class
queue shares and shed thresholds, rank-ordered backlog, windowed
scheduler observation (count- or virtual-time-closed, with the same
sparse-window normalisation), resize at window boundaries.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict, deque

import numpy as np

from repro.core.scheduler import DivideAndSaveScheduler
from repro.workload.replay import ReplayReport, assemble_report
from repro.workload.slo import (SLOSpec, censored_ttfc_p95, class_window,
                                queue_limit, shed_ttfc_threshold)
from repro.workload.traces import Trace, TraceRequest


@dataclasses.dataclass(frozen=True)
class FleetModel:
    """Service + power model of one edge device split into containers.

    ``speed(c)`` is Amdahl speedup of one container on ``c`` cores
    relative to one core; a fleet of ``n`` containers each gets
    ``cores / n``. Aggregate fleet throughput ``n * rate(n)`` *rises*
    with ``n`` (splitting recovers parallelism a single container's
    serial fraction wastes) — the paper's central observation — while
    static power ``p_container_w * n`` rises linearly, which is what
    gives energy-vs-n its convex interior optimum.

    The defaults are the frozen BENCH_trace device: Amdahl f = 0.5
    puts the mean-energy optimum at n = 1 while splitting still buys
    real burst capacity (n·rate(n) at n = 2 is ~1.75× n = 1), so the
    mean-optimal and SLO-feasible container counts genuinely differ."""
    cores: float = 4.0
    parallel_frac: float = 0.5        # Amdahl f within one container
    tokens_per_s_core: float = 170.0  # one container, one core
    prompt_token_cost: float = 0.25   # prefill token vs decode token work
    p_idle_w: float = 2.5             # device floor, always on
    p_container_w: float = 1.4        # static, per provisioned container
    p_core_w: float = 2.0             # dynamic, per actively-used core

    def speed(self, c: float) -> float:
        f = self.parallel_frac
        return 1.0 / ((1.0 - f) + f / max(c, 1e-9))

    def rate(self, n: int) -> float:
        """One container's token rate when the device is split n ways."""
        return self.tokens_per_s_core * self.speed(self.cores / max(n, 1))

    def work_tokens(self, tr: TraceRequest) -> float:
        return self.prompt_token_cost * tr.prompt_len + tr.max_new_tokens

    def prefill_tokens(self, tr: TraceRequest) -> float:
        # first chunk lands after prefill + one decode token
        return self.prompt_token_cost * tr.prompt_len + 1.0

    def power_w(self, provisioned: int, busy: int) -> float:
        cores_per = self.cores / max(provisioned, 1)
        return (self.p_idle_w + self.p_container_w * provisioned
                + self.p_core_w * cores_per * min(busy, provisioned))


@dataclasses.dataclass
class _InFlight:
    tr: TraceRequest
    cls_name: str
    start_s: float
    finish_s: float
    ttfc_s: float                     # absolute virtual stamp


def simulate(trace: Trace, *,
             feasible_counts: list[int],
             objective: str = "energy",
             slo: SLOSpec | None = None,
             fleet: FleetModel | None = None,
             window: int = 32,
             window_s: float | None = None,
             max_queue: int | None = None,
             shed_p95_s: float | None = None,
             shed_window_s: float = 30.0,
             deadline_by_class: dict | None = None,
             epsilon: float = 0.1,
             seed: int = 0) -> ReplayReport:
    """Run ``trace`` through the modelled fleet under the REAL scheduler.
    ``objective="energy"`` is the mean-optimal baseline;
    ``objective="energy_under_slo"`` (needs ``slo``) adds the quantile
    constraint. ``deadline_by_class`` maps a priority name to the
    client-imposed end-to-end deadline: a request still queued when its
    deadline passes fails at dispatch time without consuming service
    (the engine's queue-expiry path) — deadlines apply identically with
    or without an SLOSpec, which is what makes the SLO-blind baseline
    comparable. Returns the same ``ReplayReport`` the live replayer
    produces — bit-for-bit identical across runs for identical
    inputs."""
    fleet = fleet or FleetModel()
    slo_kw = {}
    if objective == "energy_under_slo":
        if slo is None:
            raise ValueError("energy_under_slo needs an SLOSpec")
        slo_kw["slo_ttfc_p95_s"] = slo.constraint.ttfc_p95_s
    sched = DivideAndSaveScheduler(
        list(feasible_counts), objective=objective,
        epsilon=epsilon, seed=seed, **slo_kw)

    n = sched.pick()
    counts_visited = [n]
    now = 0.0
    energy_j = 0.0
    busy: list[tuple[float, int]] = []     # heap of (finish_s, idx)
    backlog: list[tuple[int, int, int]] = []  # (rank, seq, idx) heap
    inflight: dict[int, _InFlight] = {}
    done: list = []                         # (cls, ttfc, latency)
    shed: list = []                         # cls names
    failed: list = []                       # cls names (deadline expiry)
    recent: dict[str, deque] = defaultdict(lambda: deque(maxlen=64))
    win = {"done": [], "t0": 0.0, "work": 0.0, "warmup": False}
    win_cls: dict[str, dict] = defaultdict(
        lambda: {"ttfc": [], "lat": [], "shed": 0, "failed": 0})
    seq = 0

    def advance(to: float) -> None:
        nonlocal now, energy_j
        if to <= now:       # coincident events (window edge == finish)
            return
        provisioned = max(n, len(busy))
        energy_j += fleet.power_w(provisioned, len(busy)) * (to - now)
        now = to

    def cls_of(tr: TraceRequest):
        return slo.cls(tr.priority) if slo is not None else None

    def aged_p95(name: str) -> float | None:
        dq = recent[name]
        horizon = now - shed_window_s
        while dq and dq[0][0] < horizon:
            dq.popleft()
        if len(dq) < 8:
            return None
        return float(np.percentile([v for _, v in dq], 95))

    def shed_reason(tr: TraceRequest) -> bool:
        cls = cls_of(tr)
        in_flight = len(inflight) + len(backlog)
        if max_queue is not None:
            limit = (queue_limit(cls, max_queue)
                     if cls is not None else max_queue)
            if in_flight >= limit:
                return True
        threshold = (shed_ttfc_threshold(cls, shed_p95_s)
                     if cls is not None else shed_p95_s)
        if threshold is not None:
            name = cls.name if cls is not None else "default"
            p95 = aged_p95(name)
            if p95 is not None and p95 > threshold:
                return True
        return False

    def start(idx: int) -> bool:
        """Dispatch (or expire) the backlog entry; False = it died at
        the deadline check and consumed no service."""
        tr = trace.requests[idx]
        cls = cls_of(tr)
        name = cls.name if cls is not None else tr.priority
        if deadline_by_class is not None:
            dl = deadline_by_class.get(name)
            if dl is not None and now - tr.arrival_s > dl:
                failed.append(name)
                win_cls[name]["failed"] += 1
                return False
        r = fleet.rate(n)
        ttfc_abs = now + fleet.prefill_tokens(tr) / r
        finish = now + fleet.work_tokens(tr) / r
        inflight[idx] = _InFlight(tr, name, now, finish, ttfc_abs)
        heapq.heappush(busy, (finish, idx))
        return True

    def drain_backlog() -> None:
        while backlog and len(busy) < n:
            _, _, idx = heapq.heappop(backlog)
            start(idx)

    def finish(idx: int) -> None:
        f = inflight.pop(idx)
        ttfc = f.ttfc_s - f.tr.arrival_s
        lat = f.finish_s - f.tr.arrival_s
        done.append((f.cls_name, ttfc, lat))
        recent[f.cls_name].append((f.ttfc_s, ttfc))
        win["done"].append(lat)
        win["work"] += fleet.work_tokens(f.tr)
        acc = win_cls[f.cls_name]
        acc["ttfc"].append(ttfc)
        acc["lat"].append(lat)

    def close_window() -> None:
        nonlocal n
        wall = now - win["t0"]
        n_done = len(win["done"])
        if n_done == 0 or wall <= 0:
            reset_window()
            return
        if win["warmup"]:
            # first window at a fresh count drains the PREVIOUS count's
            # backlog — it measures the transition, not the count, and
            # its (loss-censored) tail would brand the new count
            # infeasible before it ever ran clean
            win["warmup"] = False
            reset_window()
            return
        # the window's energy share: integrate-as-you-go already put it
        # in energy_j; re-derive the share for the scheduler from the
        # same power model over this window's span and busy work
        e_static = (fleet.p_idle_w + fleet.p_container_w * n) * wall
        busy_s = win["work"] / fleet.rate(n)
        e_dyn = fleet.p_core_w * (fleet.cores / n) * busy_s
        e_win = e_static + e_dyn
        scale = 1.0
        if window_s is not None and 0 < n_done < window:
            scale = window / n_done
        q95: float | None = None
        if slo is not None:
            cname = slo.constraint.name
            acc = win_cls.get(cname)
            if acc is not None:
                # loss-censored: admission keeps the admitted p95 pinned
                # at the threshold and deadline expiry removes the worst
                # waiters, so shed + failed arrivals must count as
                # violations or every count looks feasible
                q95 = censored_ttfc_p95(
                    acc["ttfc"], acc["shed"] + acc["failed"],
                    2.0 * slo.constraint.ttfc_p95_s)
        elif win_cls:
            all_ttfc = [t for a in win_cls.values() for t in a["ttfc"]]
            if all_ttfc:
                q95 = float(np.percentile(all_ttfc, 95))
        sched.observe(n, wall * scale, e_win * scale, ttfc_p95_s=q95)
        new_n = sched.pick()
        if new_n != n:
            n = new_n
            if n not in counts_visited:
                counts_visited.append(n)
            # the recent-ttfc tails described the OLD count's fleet; kept
            # across the resize they would shed (and loss-censor) the new
            # count's first windows and brand it infeasible forever
            recent.clear()
            win["warmup"] = True
        reset_window()

    def reset_window() -> None:
        win["done"] = []
        win["work"] = 0.0
        win["t0"] = now
        win_cls.clear()

    arrivals = list(trace.requests)
    ai = 0
    while ai < len(arrivals) or busy or backlog:
        next_arrival = (arrivals[ai].arrival_s if ai < len(arrivals)
                        else float("inf"))
        next_finish = busy[0][0] if busy else float("inf")
        next_window = (win["t0"] + window_s if window_s is not None
                       else float("inf"))
        t = min(next_arrival, next_finish, next_window)
        if t == float("inf"):
            break                      # backlog with n == 0 cannot happen
        advance(t)
        if t == next_window and t < next_arrival and t < next_finish:
            close_window()
            drain_backlog()
            continue
        if next_finish <= next_arrival:
            _, idx = heapq.heappop(busy)
            finish(idx)
            if len(win["done"]) >= window:
                close_window()
            drain_backlog()
        else:
            tr, idx = arrivals[ai], ai
            ai += 1
            cls = cls_of(tr)
            name = cls.name if cls is not None else tr.priority
            if shed_reason(tr):
                shed.append(name)
                win_cls[name]["shed"] += 1
                continue
            rank = cls.rank if cls is not None else 0
            heapq.heappush(backlog, (rank, seq, idx))
            seq += 1
            drain_backlog()

    duration = max(now, trace.spec.duration_s)
    advance(duration)   # idle tail power until the trace's nominal end
    return assemble_report(
        trace, slo=slo, done=done, shed=shed, failed=failed,
        duration_s=duration, energy_j=energy_j,
        counts_visited=tuple(counts_visited), final_n=n)
