"""Pure-jnp oracles for every Pallas kernel.

These are the numerical ground truth: each kernel test sweeps shapes/dtypes
and asserts allclose against these, and they are also the CPU execution path
(the models call ``kernels.ops`` which dispatches here off-TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _softcap(scores: jax.Array, softcap: float) -> jax.Array:
    if softcap and softcap > 0.0:
        return jnp.tanh(scores / softcap) * softcap
    return scores


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0) -> jax.Array:
    """Full attention oracle.

    q: (B, Sq, H, K); k/v: (B, Skv, Hkv, K) with H % Hkv == 0 (GQA).
    window > 0 masks keys further than ``window-1`` positions behind the
    query (sliding-window attention). Returns (B, Sq, H, K).
    """
    B, Sq, H, K = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Kv = v.shape[3]
    g = H // Hkv
    qg = q.reshape(B, Sq, Hkv, g, K)
    scores = jnp.einsum("bqhgk,bshk->bhgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (K ** -0.5)
    scores = _softcap(scores, softcap)
    q_pos = jnp.arange(Sq)[:, None] + (Skv - Sq)  # right-aligned queries
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window and window > 0:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqs,bshk->bqhgk", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Kv).astype(q.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid: jax.Array, *, softcap: float = 0.0) -> jax.Array:
    """Single-token decode oracle.

    q: (B, H, K); k/v: (B, W, Hkv, K); valid: (B, W) bool — which ring slots
    hold live entries for each sequence. Returns (B, H, K).
    """
    B, H, K = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, K)
    scores = jnp.einsum("bhgk,bshk->bhgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (K ** -0.5)
    scores = _softcap(scores, softcap)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshk->bhgk", w, v.astype(jnp.float32))
    return out.reshape(B, H, K).astype(q.dtype)


def decode_attention_partial(q: jax.Array, k: jax.Array, v: jax.Array,
                             valid: jax.Array, *, softcap: float = 0.0,
                             k_scale: jax.Array | None = None,
                             v_scale: jax.Array | None = None
                             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Partial flash-decode over a LOCAL slice of the KV cache: returns the
    unnormalised accumulator plus the (max, normaliser) statistics so a
    cross-shard merge can combine slices (sequence-parallel decode — see
    attention._seq_parallel_decode). Handles int8 caches via scales."""
    B, H, K = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale[..., None].astype(jnp.float32)
        vf = vf * v_scale[..., None].astype(jnp.float32)
    qg = q.reshape(B, Hkv, G, K).astype(jnp.float32)
    s = jnp.einsum("bhgk,bshk->bhgs", qg, kf) * (K ** -0.5)
    s = _softcap(s, softcap)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                  # (B,Hkv,G)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)           # exp(-inf-(-inf))
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgs,bshk->bhgk", p, vf)
    return (acc.reshape(B, H, vf.shape[-1]), m.reshape(B, H),
            l.reshape(B, H))


def decode_attention_blocked(q: jax.Array, k: jax.Array, v: jax.Array,
                             valid: jax.Array, *, softcap: float = 0.0,
                             k_scale: jax.Array | None = None,
                             v_scale: jax.Array | None = None,
                             block: int = 1024) -> jax.Array:
    """Flash-decode reference: ``lax.scan`` over KV blocks with an online
    softmax, so only one (B, Hkv, block, hd) tile is live at a time — the
    lowering/roofline counterpart of the Pallas decode kernel (the plain
    oracle above materialises (B, H, W) scores).

    Supports quantised caches: when ``k_scale``/``v_scale`` (B, W, Hkv) are
    given, k/v are int8 and dequantised per tile (in-kernel on TPU).
    """
    B, H, K = q.shape
    W, Hkv = k.shape[1], k.shape[2]
    Kv = v.shape[3]
    G = H // Hkv
    blk = min(block, W)
    pad = (-W) % blk
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
    nb = (W + pad) // blk
    qg = q.reshape(B, Hkv, G, K).astype(jnp.float32)

    def to_blocks(a):
        return jnp.moveaxis(
            a.reshape(B, nb, blk, *a.shape[2:]), 1, 0)

    xs = [to_blocks(k), to_blocks(v), to_blocks(valid)]
    if k_scale is not None:
        xs += [to_blocks(k_scale), to_blocks(v_scale)]

    def step(carry, inp):
        m_run, l_run, acc = carry
        if k_scale is not None:
            kb, vb, vb_ok, ksb, vsb = inp
            kb = kb.astype(jnp.float32) * ksb[..., None].astype(jnp.float32)
            vb = vb.astype(jnp.float32) * vsb[..., None].astype(jnp.float32)
        else:
            kb, vb, vb_ok = inp
            kb = kb.astype(jnp.float32)
            vb = vb.astype(jnp.float32)
        s = jnp.einsum("bhgk,bshk->bhgs", qg, kb) * (K ** -0.5)
        s = _softcap(s, softcap)
        s = jnp.where(vb_ok[:, None, None, :], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_run, m_cur)
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_run + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhgs,bshk->bhgk", p, vb)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, G, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, 1), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Kv), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(step, (m0, l0, a0), tuple(xs))
    out = acc / jnp.maximum(l_f, 1e-30)
    return out.reshape(B, H, Kv).astype(q.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, table: jax.Array,
                           lengths: jax.Array, *, softcap: float = 0.0,
                           k_scale_pages: jax.Array | None = None,
                           v_scale_pages: jax.Array | None = None
                           ) -> jax.Array:
    """Paged flash-decode oracle: gather K/V through a per-sequence block
    table, then run the SAME blocked online softmax as the dense path.

    q: (B, H, K); k_pages/v_pages: (P, bs, Hkv, K) — a shared physical
    page pool (the last page is conventionally scratch); table: (B, nblk)
    int32 page indices per logical block; lengths: (B,) — tokens [0, len)
    are live. Masked positions (scratch garbage included) contribute an
    exact 0.0 to the accumulator, so for equal live prefixes the output is
    bitwise identical to ``decode_attention_blocked`` over a dense
    (B, nblk*bs) cache — the bit-parity contract the paged serving engine
    tests pin down.
    """
    B = q.shape[0]
    nblk = table.shape[1]
    bs = k_pages.shape[1]
    W = nblk * bs
    k = k_pages[table].reshape(B, W, *k_pages.shape[2:])
    v = v_pages[table].reshape(B, W, *v_pages.shape[2:])
    valid = jnp.arange(W)[None, :] < lengths[:, None]
    ks = vs = None
    if k_scale_pages is not None:
        ks = k_scale_pages[table].reshape(B, W, k_scale_pages.shape[2])
        vs = v_scale_pages[table].reshape(B, W, v_scale_pages.shape[2])
    return decode_attention_blocked(q, k, v, valid, softcap=softcap,
                                    k_scale=ks, v_scale=vs)


def mla_decode_ctx(q_lat: jax.Array, q_rope: jax.Array, ckv: jax.Array,
                   k_rope: jax.Array, valid: jax.Array, *,
                   scale: float) -> jax.Array:
    """Absorbed-MLA decode oracle: attention in the latent space.

    q_lat: (B, H, r); q_rope: (B, H, dr); ckv: (B, S, r);
    k_rope: (B, S, dr); valid: (B, S). Returns ctx (B, H, r) — the gated
    latent context (the caller applies W_uv and W_o).
    """
    scores = jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32),
                        ckv.astype(jnp.float32))
    scores += jnp.einsum("bhk,bsk->bhs", q_rope.astype(jnp.float32),
                         k_rope.astype(jnp.float32))
    scores *= scale
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bsr->bhr", w,
                      ckv.astype(jnp.float32)).astype(q_lat.dtype)


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < l <= i} x[..., l]."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B_: jax.Array,
             C_: jax.Array, D: jax.Array, *, chunk: int = 64,
             init_state: jax.Array | None = None
             ) -> tuple[jax.Array, jax.Array]:
    """Mamba2 SSD (state-space duality) chunked scan oracle.

    x: (B, S, nh, hd); dt: (B, S, nh) (post-softplus, >=0); A: (nh,) (<0);
    B_/C_: (B, S, ng, ds); D: (nh,). Returns (y, final_state) with
    y: (B, S, nh, hd), state: (B, nh, hd, ds).

    Implements eq. (SSD) of arXiv:2405.21060: within-chunk quadratic form +
    across-chunk linear recurrence.
    """
    Bb, S, nh, hd = x.shape
    ng, ds = B_.shape[2], B_.shape[3]
    rep = nh // ng
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    f32 = jnp.float32

    xc = x.reshape(Bb, nc, chunk, nh, hd).astype(f32)
    dtc = dt.reshape(Bb, nc, chunk, nh).astype(f32)
    Bc = B_.reshape(Bb, nc, chunk, ng, ds).astype(f32)
    Cc = C_.reshape(Bb, nc, chunk, ng, ds).astype(f32)
    Bh = jnp.repeat(Bc, rep, axis=3)  # (B, nc, Q, nh, ds)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A.astype(f32)[None, None, None, :]        # (B, nc, Q, nh)
    dA_cum = jnp.cumsum(dA, axis=2)                      # within-chunk
    # ---- intra-chunk (quadratic) term
    L = jnp.exp(_segsum(jnp.moveaxis(dA, 3, 2)))         # (B, nc, nh, Q, Q)
    G = jnp.einsum("bcqhd,bckhd->bchqk", Ch, Bh)         # (B, nc, nh, Q, Q)
    M = G * L
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", M, dtc, xc)
    # ---- chunk states
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (B, nc, Q, nh)
    states = jnp.einsum("bcqhd,bcqh,bcqh,bcqhp->bchpd",
                        Bh, dtc, decay_to_end, xc)          # (B, nc, nh, hd, ds)
    # ---- inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])              # (B, nc, nh)
    s0 = (jnp.zeros((Bb, nh, hd, ds), f32) if init_state is None
          else init_state.astype(f32))

    def step(carry, inp):
        st, dec = inp           # st: (B, nh, hd, ds), dec: (B, nh)
        new = carry * dec[:, :, None, None] + st
        return new, carry       # emit state *entering* this chunk

    final_state, prev_states = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (B, nc, nh, hd, ds)
    # ---- contribution of carried-in state
    decay_from_start = jnp.exp(dA_cum)                      # (B, nc, Q, nh)
    y_off = jnp.einsum("bcqhd,bcqh,bchpd->bcqhp",
                       Ch, decay_from_start, prev_states)
    y = (y_diag + y_off).reshape(Bb, S, nh, hd)
    y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), final_state.astype(x.dtype)


def ssd_scan_seq(x: jax.Array, dt: jax.Array, A: jax.Array, B_: jax.Array,
                 C_: jax.Array, D: jax.Array, *, chunk: int = 64,
                 init_state: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Memory-honest SSD: ``lax.scan`` over chunks so only ONE chunk's
    quadratic form (nh, Q, Q) is live at a time — the lowering/roofline
    counterpart of the Pallas kernel's sequential-chunk grid (the vectorised
    oracle above materialises all (B, nc, nh, Q, Q) decay tiles at once).
    Numerically identical to ``ssd_scan`` (tested)."""
    Bb, S, nh, hd = x.shape
    ng, ds = B_.shape[2], B_.shape[3]
    rep = nh // ng
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    f32 = jnp.float32

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(Bb, nc, chunk, *a.shape[2:]), 1, 0)

    xs = (to_chunks(x), to_chunks(dt), to_chunks(B_), to_chunks(C_))
    s0 = (jnp.zeros((Bb, nh, hd, ds), f32) if init_state is None
          else init_state.astype(f32))
    Af = A.astype(f32)
    Df = D.astype(f32)

    def step(state, inp):
        xc, dtc, Bc, Cc = inp
        xc = xc.astype(f32)                       # (B, Q, nh, hd)
        dtc = dtc.astype(f32)                     # (B, Q, nh)
        Bh = jnp.repeat(Bc.astype(f32), rep, axis=2)   # (B, Q, nh, ds)
        Ch = jnp.repeat(Cc.astype(f32), rep, axis=2)
        dA = dtc * Af[None, None, :]
        dA_cum = jnp.cumsum(dA, axis=1)
        L = jnp.exp(_segsum(jnp.moveaxis(dA, 2, 1)))       # (B, nh, Q, Q)
        G = jnp.einsum("bqhd,bkhd->bhqk", Ch, Bh)
        y_diag = jnp.einsum("bhqk,bkh,bkhp->bqhp", G * L, dtc, xc)
        y_off = jnp.einsum("bqhd,bqh,bhpd->bqhp",
                           Ch, jnp.exp(dA_cum), state)
        decay_to_end = jnp.exp(dA_cum[:, -1:, :] - dA_cum)
        new_contrib = jnp.einsum("bqhd,bqh,bqh,bqhp->bhpd",
                                 Bh, dtc, decay_to_end, xc)
        chunk_decay = jnp.exp(dA_cum[:, -1, :])
        new_state = state * chunk_decay[:, :, None, None] + new_contrib
        y = y_diag + y_off + xc * Df[None, None, :, None]
        return new_state, y.astype(x.dtype)

    final_state, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, nh, hd)
    return y, final_state.astype(x.dtype)


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    A: jax.Array, B_: jax.Array, C_: jax.Array,
                    D: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One-token SSD recurrence. state: (B, nh, hd, ds); x: (B, nh, hd);
    dt: (B, nh); B_/C_: (B, ng, ds)."""
    nh, ng = x.shape[1], B_.shape[1]
    rep = nh // ng
    f32 = jnp.float32
    Bh = jnp.repeat(B_.astype(f32), rep, axis=1)  # (B, nh, ds)
    Ch = jnp.repeat(C_.astype(f32), rep, axis=1)
    dA = jnp.exp(dt.astype(f32) * A.astype(f32)[None, :])   # (B, nh)
    upd = jnp.einsum("bh,bhp,bhd->bhpd", dt.astype(f32), x.astype(f32), Bh)
    new_state = state.astype(f32) * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpd,bhd->bhp", new_state, Ch)
    y = y + x.astype(f32) * D.astype(f32)[None, :, None]
    return y.astype(x.dtype), new_state.astype(state.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
