"""Pallas TPU flash-attention (prefill) kernel.

Tiling: grid (batch, q_heads, Sq/BQ, Skv/BK); the last grid axis is the
TPU-sequential one, so the online-softmax running max / normaliser / output
accumulator live in VMEM scratch and are carried across KV tiles. Block
shapes default to (128, head_dim) — MXU-aligned on the contraction dims.

GQA is handled in the index map (kv head = q head // group); causal and
sliding-window masking is applied per tile, in-register.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int, softcap: float,
                 block_q: int, block_k: int, seq_kv: int, seq_q: int):
    kv_idx = pl.program_id(3)
    q_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :, :].astype(jnp.float32)       # (BQ, K)
    k = k_ref[0, 0, :, :].astype(jnp.float32)       # (BK, K)
    v = v_ref[0, 0, :, :].astype(jnp.float32)       # (BK, K)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if softcap and softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = q_idx * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (seq_kv - seq_q)
    k_pos = kv_idx * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window and window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                             # (BQ, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kv_idx == pl.num_programs(3) - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, K); k/v: (B, Skv, Hkv, K). Returns (B, Sq, H, K)."""
    B, Sq, H, K = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Kv = v.shape[3]
    group = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    grid = (B, H, Sq // block_q, Skv // block_k)

    # layout: move heads ahead of seq so each tile is a contiguous (S, K) slab
    qt = jnp.moveaxis(q, 2, 1)  # (B, H, Sq, K)
    kt = jnp.moveaxis(k, 2, 1)  # (B, Hkv, Skv, K)
    vt = jnp.moveaxis(v, 2, 1)

    kernel = functools.partial(
        _attn_kernel, scale=K ** -0.5, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, seq_kv=Skv,
        seq_q=Sq)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, K), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, K),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, Kv),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Kv),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Kv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, Kv), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)
