"""Pallas TPU kernel for absorbed-MLA decode (DeepSeek latent attention).

One query token attends to the LATENT cache: scores combine a latent-space
dot (r = kv_lora_rank, e.g. 512) and a shared-rope dot (dr, e.g. 64); the
context is re-read from the same latent tiles. Grid (batch, S/BS); the
sequence axis is TPU-sequential so the online softmax (m, l) and the
(H, r) context accumulator live in VMEM scratch — each ckv tile is read
from HBM exactly ONCE and used for both the score and the context matmul
(the jnp oracle reads it twice).

This is the hot decode loop of deepseek-v2-lite (§Perf carry-over: MLA
decode is latent-cache-read bound, so single-read tiling is the roofline
move the kernel encodes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mla_kernel(ql_ref, qr_ref, ckv_ref, kr_ref, valid_ref, o_ref,
                m_scr, l_scr, acc_scr, *, scale: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ql = ql_ref[0].astype(jnp.float32)            # (H, r)
    qr = qr_ref[0].astype(jnp.float32)            # (H, dr)
    ckv = ckv_ref[0].astype(jnp.float32)          # (BS, r)
    kr = kr_ref[0].astype(jnp.float32)            # (BS, dr)
    valid = valid_ref[0, :]                       # (BS,)

    s = jax.lax.dot_general(ql, ckv, (((1,), (1,)), ((), ())))
    s += jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())))
    s *= scale                                    # (H, BS)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, ckv, (((1,), (0,)), ((), ())))         # (H, r)
    m_scr[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "block_s", "interpret"))
def mla_decode_ctx(q_lat: jax.Array, q_rope: jax.Array, ckv: jax.Array,
                   k_rope: jax.Array, valid: jax.Array, *, scale: float,
                   block_s: int = 512, interpret: bool = False) -> jax.Array:
    """Shapes as in ref.mla_decode_ctx. Returns ctx (B, H, r)."""
    B, H, r = q_lat.shape
    S = ckv.shape[1]
    dr = q_rope.shape[2]
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    grid = (B, S // block_s)

    kernel = functools.partial(_mla_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, r), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, H, dr), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_s, r), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_s, dr), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_s), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, H, r), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, r), q_lat.dtype),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, r), jnp.float32),
        ],
        interpret=interpret,
    )(q_lat, q_rope, ckv, k_rope, valid)
