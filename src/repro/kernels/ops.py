"""Jit'd dispatch wrappers: Pallas on TPU, jnp oracle elsewhere.

The models call these — never the kernels or oracles directly — so the same
model code runs the Pallas path on real TPU hardware and the numerically
identical jnp path on CPU (tests, dry-run lowering). Set
``REPRO_FORCE_PALLAS=interpret`` to exercise the Pallas kernels in interpret
mode from the model layer (slow; used by a couple of integration tests).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_ref, ref
from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd


def _mode() -> str:
    forced = os.environ.get("REPRO_FORCE_PALLAS", "")
    if forced == "interpret":
        return "interpret"
    if jax.default_backend() == "tpu":
        return "tpu"
    return "ref"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0):
    mode = _mode()
    if mode == "ref":
        # flash-structured jnp path: same tiles/memory behaviour as the
        # Pallas kernel (flash_ref docstring) — this is what the dry-run
        # lowers, so the roofline describes the kernel we'd actually run.
        return flash_ref.flash_attention(q, k, v, causal=causal,
                                         window=window, softcap=softcap)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap,
                               interpret=(mode == "interpret"))


def decode_attention(q, k, v, valid, *, softcap: float = 0.0,
                     k_scale=None, v_scale=None):
    """Optional k/v scales mean an int8-quantised cache (dequant per tile —
    the blocked paths keep the dequantised tiles in VMEM/registers)."""
    mode = _mode()
    if mode == "ref":
        return ref.decode_attention_blocked(q, k, v, valid, softcap=softcap,
                                            k_scale=k_scale, v_scale=v_scale)
    if k_scale is not None:  # Pallas int8 kernel: dequant in VMEM
        return _da.decode_attention_int8(q, k, v, valid, k_scale, v_scale,
                                         softcap=softcap,
                                         interpret=(mode == "interpret"))
    return _da.decode_attention(q, k, v, valid, softcap=softcap,
                                interpret=(mode == "interpret"))


def paged_decode_attention(q, k_pages, v_pages, table, lengths, *,
                           softcap: float = 0.0, k_scale_pages=None,
                           v_scale_pages=None):
    """Paged flash-decode: K/V gathered through a per-sequence block table
    over a shared physical page pool. q: (B, H, hd); pages:
    (P, block_size, Hkv, hd); table: (B, nblk) int32; lengths: (B,).
    Optional scale pages mean int8 pages (dequant in VMEM)."""
    mode = _mode()
    if mode == "ref":
        return ref.paged_decode_attention(q, k_pages, v_pages, table,
                                          lengths, softcap=softcap,
                                          k_scale_pages=k_scale_pages,
                                          v_scale_pages=v_scale_pages)
    from repro.kernels import paged_attention as _pa
    if k_scale_pages is not None:
        return _pa.paged_decode_attention_int8(
            q, k_pages, v_pages, k_scale_pages, v_scale_pages, table,
            lengths, softcap=softcap, interpret=(mode == "interpret"))
    return _pa.paged_decode_attention(q, k_pages, v_pages, table, lengths,
                                      softcap=softcap,
                                      interpret=(mode == "interpret"))


def decode_cross_attention(q, k, v, *, softcap: float = 0.0):
    """Single-token cross-attention against a fixed (fully valid) memory,
    routed through the flash-*decode* kernel path: during chunked decode
    the query is one token, so the prefill flash kernel's S×S tiling is
    the wrong shape — the decode kernel streams the memory K/V once per
    query instead. q: (B, H, hd); k/v: (B, S_mem, Hkv, hd)."""
    valid = jnp.ones(k.shape[:2], bool)
    return decode_attention(q, k, v, valid, softcap=softcap)


def ssd_scan(x, dt, A, B_, C_, D, *, chunk: int = 64):
    mode = _mode()
    if mode == "ref":
        return ref.ssd_scan_seq(x, dt, A, B_, C_, D, chunk=chunk)
    return _ssd.ssd_scan(x, dt, A, B_, C_, D, chunk=chunk,
                         interpret=(mode == "interpret"))


def mla_decode_ctx(q_lat, q_rope, ckv, k_rope, valid, *, scale: float):
    mode = _mode()
    if mode == "ref":
        return ref.mla_decode_ctx(q_lat, q_rope, ckv, k_rope, valid,
                                  scale=scale)
    from repro.kernels import mla_decode as _mla
    return _mla.mla_decode_ctx(q_lat, q_rope, ckv, k_rope, valid,
                               scale=scale, interpret=(mode == "interpret"))


def rmsnorm(x, scale, *, eps: float = 1e-6):
    mode = _mode()
    if mode == "ref":
        return ref.rmsnorm(x, scale, eps=eps)
    return _rn.rmsnorm(x, scale, eps=eps, interpret=(mode == "interpret"))
