"""Pallas TPU paged flash-decode kernel: one query token vs. a block-table
KV cache (vLLM-style paged attention).

Extends kernels/decode_attention.py to the paged layout: K/V live in a
shared physical page pool ``(P, block_size, Hkv, hd)`` and each sequence
owns a row of page indices (the block table). The gather happens INSIDE
the grid: the per-sequence block table and live lengths ride along as
scalar-prefetch operands (``pltpu.PrefetchScalarGridSpec``), so the K/V
BlockSpec index maps can look the physical page up per grid step —
``(table[b, j], h, 0, 0)`` — and the DMA engine fetches exactly the pages
the sequence owns, in logical order. Grid is ``(batch, kv_head, nblk)``
with the block axis TPU-sequential, carrying the online-softmax partials
(running max / normaliser / accumulator) in VMEM scratch exactly like the
dense decode kernel.

Validity is reconstructed in-kernel from the prefetched lengths
(``j·bs + iota < len[b]``) instead of a materialised (B, W) mask — pages
past a sequence's live prefix (including the conventional scratch page)
are masked to -inf before the softmax, so their garbage contributes an
exact 0.0. The int8 variant dequantises pages in VMEM via scale pages
``(P, block_size, Hkv)``, mirroring ``_decode_kernel_int8``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(table_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, block_size: int, scale: float,
                  softcap: float):
    del table_ref  # consumed by the BlockSpec index maps (page lookup)
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :, :].astype(jnp.float32)      # (G, K)
    k = k_ref[0, 0, :, :].astype(jnp.float32)      # (bs, K)
    v = v_ref[0, 0, :, :].astype(jnp.float32)      # (bs, K)
    # live slots of this logical block, from the prefetched lengths
    # (TPU iota must be >= 2D: broadcasted_iota over (1, bs))
    offs = jax.lax.broadcasted_iota(jnp.int32, (1, block_size), 1)
    valid = j * block_size + offs < lengths_ref[b]  # (1, bs)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, bs)
    if softcap and softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def _paged_kernel_int8(table_ref, lengths_ref, q_ref, k_ref, v_ref,
                       ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr, *,
                       block_size: int, scale: float, softcap: float):
    """int8-page variant: pages are dequantised IN VMEM (per-token,
    per-head absmax scale pages) — HBM traffic is int8 bytes + scales."""
    del table_ref
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :, :].astype(jnp.float32)               # (G, K)
    ks = ks_ref[0, 0, :].astype(jnp.float32)                # (bs,)
    vs = vs_ref[0, 0, :].astype(jnp.float32)
    k = k_ref[0, 0, :, :].astype(jnp.float32) * ks[:, None]
    v = v_ref[0, 0, :, :].astype(jnp.float32) * vs[:, None]
    offs = jax.lax.broadcasted_iota(jnp.int32, (1, block_size), 1)
    valid = j * block_size + offs < lengths_ref[b]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if softcap and softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, table: jax.Array,
                           lengths: jax.Array, *, softcap: float = 0.0,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, K); k_pages/v_pages: (P, bs, Hkv, K); table: (B, nblk)
    int32; lengths: (B,) int32 -> (B, H, K)."""
    B, H, K = q.shape
    bs, Hkv = k_pages.shape[1], k_pages.shape[2]
    nblk = table.shape[1]
    G = H // Hkv
    grid = (B, Hkv, nblk)

    qg = q.reshape(B, Hkv, G, K)
    kt = jnp.moveaxis(k_pages, 2, 1)               # (P, Hkv, bs, K)
    vt = jnp.moveaxis(v_pages, 2, 1)

    kernel = functools.partial(_paged_kernel, block_size=bs,
                               scale=K ** -0.5, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # table, lengths
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, K), lambda b, h, j, t, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, K),
                         lambda b, h, j, t, ln: (t[b, j], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, K),
                         lambda b, h, j, t, ln: (t[b, j], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, K),
                               lambda b, h, j, t, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, K), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, K), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), lengths.astype(jnp.int32), qg, kt, vt)
    return out.reshape(B, H, K)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_decode_attention_int8(q: jax.Array, k_pages: jax.Array,
                                v_pages: jax.Array,
                                k_scale_pages: jax.Array,
                                v_scale_pages: jax.Array,
                                table: jax.Array, lengths: jax.Array, *,
                                softcap: float = 0.0,
                                interpret: bool = False) -> jax.Array:
    """q: (B,H,K) fp; k/v pages: (P, bs, Hkv, K) int8; scale pages:
    (P, bs, Hkv) f32; table: (B, nblk) int32; lengths: (B,) int32."""
    B, H, K = q.shape
    bs, Hkv = k_pages.shape[1], k_pages.shape[2]
    nblk = table.shape[1]
    G = H // Hkv
    grid = (B, Hkv, nblk)

    qg = q.reshape(B, Hkv, G, K)
    kt = jnp.moveaxis(k_pages, 2, 1)               # (P, Hkv, bs, K)
    vt = jnp.moveaxis(v_pages, 2, 1)
    kst = jnp.moveaxis(k_scale_pages, 2, 1)        # (P, Hkv, bs)
    vst = jnp.moveaxis(v_scale_pages, 2, 1)

    kernel = functools.partial(_paged_kernel_int8, block_size=bs,
                               scale=K ** -0.5, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, K), lambda b, h, j, t, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, K),
                         lambda b, h, j, t, ln: (t[b, j], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, K),
                         lambda b, h, j, t, ln: (t[b, j], h, 0, 0)),
            pl.BlockSpec((1, 1, bs),
                         lambda b, h, j, t, ln: (t[b, j], h, 0)),
            pl.BlockSpec((1, 1, bs),
                         lambda b, h, j, t, ln: (t[b, j], h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, K),
                               lambda b, h, j, t, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, K), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, K), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, kt, vt, kst, vst)
    return out.reshape(B, H, K)
