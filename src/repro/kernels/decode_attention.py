"""Pallas TPU flash-decode kernel: one query token vs. a ring-buffer KV cache.

Grid (batch, kv_head, W/BK); the KV axis is TPU-sequential so the partial
softmax (running max / normaliser / accumulator) is carried in VMEM scratch
— the flash-decoding pattern adapted to a single grid pass. All ``group``
query heads of a kv head are processed together as the matmul M dimension
(group × BK hits the MXU as a skinny matmul; for kv-replicated GQA this is
the best obtainable shape without head-batching, which ops.py applies by
folding batch into the grid).

``valid`` marks live ring slots (slots whose reconstructed absolute position
is non-negative); dead slots are masked to -inf before the softmax.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, softcap: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :, :].astype(jnp.float32)      # (G, K)
    k = k_ref[0, 0, :, :].astype(jnp.float32)      # (BK, K)
    v = v_ref[0, 0, :, :].astype(jnp.float32)      # (BK, K)
    valid = valid_ref[0, :]                        # (BK,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, BK)
    if softcap and softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def _decode_kernel_int8(q_ref, k_ref, v_ref, valid_ref, ks_ref, vs_ref,
                        o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                        softcap: float):
    """int8-cache variant: k/v tiles are dequantised IN VMEM (per-token,
    per-head absmax scales) — HBM traffic is the int8 bytes + scales."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :, :].astype(jnp.float32)               # (G, K)
    ks = ks_ref[0, 0, :].astype(jnp.float32)                # (BK,)
    vs = vs_ref[0, 0, :].astype(jnp.float32)
    k = k_ref[0, 0, :, :].astype(jnp.float32) * ks[:, None]
    v = v_ref[0, 0, :, :].astype(jnp.float32) * vs[:, None]
    valid = valid_ref[0, :]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if softcap and softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("softcap", "block_k", "interpret"))
def decode_attention_int8(q: jax.Array, k: jax.Array, v: jax.Array,
                          valid: jax.Array, k_scale: jax.Array,
                          v_scale: jax.Array, *, softcap: float = 0.0,
                          block_k: int = 512,
                          interpret: bool = False) -> jax.Array:
    """q: (B,H,K) fp; k/v: (B,W,Hkv,K) int8; scales: (B,W,Hkv) f32."""
    B, H, K = q.shape
    W, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    block_k = min(block_k, W)
    assert W % block_k == 0, (W, block_k)
    grid = (B, Hkv, W // block_k)

    qg = q.reshape(B, Hkv, G, K)
    kt = jnp.moveaxis(k, 2, 1)                              # (B, Hkv, W, K)
    vt = jnp.moveaxis(v, 2, 1)
    kst = jnp.moveaxis(k_scale, 2, 1)                       # (B, Hkv, W)
    vst = jnp.moveaxis(v_scale, 2, 1)

    kernel = functools.partial(_decode_kernel_int8, scale=K ** -0.5,
                               softcap=softcap)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, K), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, K), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, K), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, j: (b, j)),
            pl.BlockSpec((1, 1, block_k), lambda b, h, j: (b, h, j)),
            pl.BlockSpec((1, 1, block_k), lambda b, h, j: (b, h, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, K), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, K), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, K), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt, vt, valid, kst, vst)
    return out.reshape(B, H, K)


@functools.partial(jax.jit,
                   static_argnames=("softcap", "block_k", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid: jax.Array, *, softcap: float = 0.0,
                     block_k: int = 512, interpret: bool = False) -> jax.Array:
    """q: (B, H, K); k/v: (B, W, Hkv, K); valid: (B, W) bool -> (B, H, K)."""
    B, H, K = q.shape
    W, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    block_k = min(block_k, W)
    assert W % block_k == 0, (W, block_k)
    grid = (B, Hkv, W // block_k)

    qg = q.reshape(B, Hkv, G, K)
    kt = jnp.moveaxis(k, 2, 1)                     # (B, Hkv, W, K)
    vt = jnp.moveaxis(v, 2, 1)
    valid2 = valid

    kernel = functools.partial(_decode_kernel, scale=K ** -0.5,
                               softcap=softcap)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, K), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, K), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, K), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, K), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, K), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, K), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt, vt, valid2)
    return out.reshape(B, H, K)
