"""Memory-honest flash attention in pure jnp (the roofline reference).

This is the CPU/dry-run execution path for full-sequence attention. Unlike
the quadratic oracle in ``ref.py`` it never materialises an (Sq, Skv)
score tensor: the forward is a two-level ``lax.scan`` over (q-block,
kv-block) tiles with an online softmax, and the backward is a ``custom_vjp``
implementing the flash-attention backward (recompute scores blockwise,
save only out + per-row logsumexp — O(S) residuals).

Why it exists: the multi-pod dry-run lowers the model on CPU and reads the
compiled HLO for the roofline. If the lowered attention materialised S²
tensors, the memory/bytes terms would describe an implementation we would
never run on TPU — this module makes the lowered graph structurally match
what the Pallas kernel (flash_attention.py) does on real hardware, tile for
tile. Like that kernel's grid, every (q, kv) tile is visited (no static
causal-block skipping) — the compute term reflects the full grid.

Numerics are validated against ``ref.flash_attention`` (values and grads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _blockify(x, n_blocks: int, block: int):
    """(..., S, K) -> (n_blocks, ..., block, K) for scan xs."""
    S = x.shape[-2]
    lead = x.shape[:-2]
    x = x.reshape(*lead, n_blocks, block, x.shape[-1])
    return jnp.moveaxis(x, -3, 0)


def _mask(q0, k0, bq, bk, *, sq, skv, causal, window):
    """(bq, bk) bool mask for tile at (q0, k0) with right-aligned queries."""
    q_pos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (skv - sq)
    k_pos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = k_pos < skv  # guard for padded keys
    if causal:
        m &= k_pos <= q_pos
    if window and window > 0:
        m &= k_pos > q_pos - window
    return m


def _scores(qb, kb, q0, k0, *, scale, softcap, sq, skv, causal, window):
    """Raw+capped masked scores for one tile. qb: (B,Hkv,G,bq,K),
    kb: (B,Hkv,bk,K) -> (B,Hkv,G,bq,bk) f32."""
    s = jnp.einsum("bhgqk,bhsk->bhgqs", qb.astype(jnp.float32),
                   kb.astype(jnp.float32)) * scale
    if softcap and softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    bq, bk = qb.shape[-2], kb.shape[-2]
    m = _mask(q0, k0, bq, bk, sq=sq, skv=skv, causal=causal, window=window)
    return jnp.where(m[None, None, None], s, NEG_INF)


def _fwd_impl(q, k, v, *, causal, window, softcap, block_q, block_k):
    """Returns (out, lse). q: (B,Sq,H,K); k/v: (B,Skv,Hkv,K)."""
    B, Sq, H, K = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Kv = v.shape[3]
    G = H // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    pq = (-Sq) % bq
    pk = (-Skv) % bk
    scale = K ** -0.5

    qg = jnp.moveaxis(q.reshape(B, Sq, Hkv, G, K), 1, 3)   # (B,Hkv,G,Sq,K)
    kg = jnp.moveaxis(k, 1, 2)                             # (B,Hkv,Skv,K)
    vg = jnp.moveaxis(v, 1, 2)                             # (B,Hkv,Skv,Kv)
    if pq:
        qg = jnp.pad(qg, ((0, 0),) * 3 + ((0, pq), (0, 0)))
    if pk:
        kg = jnp.pad(kg, ((0, 0),) * 2 + ((0, pk), (0, 0)))
        vg = jnp.pad(vg, ((0, 0),) * 2 + ((0, pk), (0, 0)))
    nq, nk = (Sq + pq) // bq, (Skv + pk) // bk

    q_blocks = _blockify(qg, nq, bq)                       # (nq,B,Hkv,G,bq,K)
    k_blocks = _blockify(kg, nk, bk)                       # (nk,B,Hkv,bk,K)
    v_blocks = _blockify(vg, nk, bk)

    def q_step(_, qb_i):
        qb, qi = qb_i
        q0 = qi * bq

        def kv_step(carry, kv_j):
            m_run, l_run, acc = carry
            kb, vb, kj = kv_j
            s = _scores(qb, kb, q0, kj * bk, scale=scale, softcap=softcap,
                        sq=Sq, skv=Skv, causal=causal, window=window)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m_run, m_cur)
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new)
            l_new = alpha * l_run + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhgqs,bhsk->bhgqk", p,
                                           vb.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, Hkv, G, bq, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, bq, 1), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, bq, Kv), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (k_blocks, v_blocks, jnp.arange(nk)))
        l_safe = jnp.maximum(l_f, 1e-30)
        out_b = acc / l_safe
        lse_b = (m_f + jnp.log(l_safe))[..., 0]            # (B,Hkv,G,bq)
        return None, (out_b, lse_b)

    _, (out_blocks, lse_blocks) = jax.lax.scan(
        q_step, None, (q_blocks, jnp.arange(nq)))
    # (nq,B,Hkv,G,bq,Kv) -> (B,Sq,H,Kv)
    out = jnp.moveaxis(out_blocks, 0, 3).reshape(B, Hkv, G, Sq + pq, Kv)
    lse = jnp.moveaxis(lse_blocks, 0, 3).reshape(B, Hkv, G, Sq + pq)
    out = jnp.moveaxis(out, 3, 1)[:, :Sq].reshape(B, Sq, H, Kv)
    return out.astype(q.dtype), lse[..., :Sq]


def _bwd_impl(q, k, v, out, lse, dout, *, causal, window, softcap,
              block_q, block_k):
    B, Sq, H, K = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Kv = v.shape[3]
    G = H // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    pq = (-Sq) % bq
    pk = (-Skv) % bk
    scale = K ** -0.5

    qg = jnp.moveaxis(q.reshape(B, Sq, Hkv, G, K), 1, 3)
    og = jnp.moveaxis(out.reshape(B, Sq, Hkv, G, Kv), 1, 3)
    dg = jnp.moveaxis(dout.reshape(B, Sq, Hkv, G, Kv), 1, 3).astype(jnp.float32)
    kg = jnp.moveaxis(k, 1, 2)
    vg = jnp.moveaxis(v, 1, 2)
    delta = jnp.sum(dg * og.astype(jnp.float32), axis=-1)  # (B,Hkv,G,Sq)
    if pq:
        qg = jnp.pad(qg, ((0, 0),) * 3 + ((0, pq), (0, 0)))
        dg = jnp.pad(dg, ((0, 0),) * 3 + ((0, pq), (0, 0)))
        delta = jnp.pad(delta, ((0, 0),) * 3 + ((0, pq),))
        lse = jnp.pad(lse, ((0, 0),) * 3 + ((0, pq),))
    if pk:
        kg = jnp.pad(kg, ((0, 0),) * 2 + ((0, pk), (0, 0)))
        vg = jnp.pad(vg, ((0, 0),) * 2 + ((0, pk), (0, 0)))
    nq, nk = (Sq + pq) // bq, (Skv + pk) // bk

    q_blocks = _blockify(qg, nq, bq)
    d_blocks = _blockify(dg, nq, bq)
    l_blocks = jnp.moveaxis(lse.reshape(B, Hkv, G, nq, bq), 3, 0)
    e_blocks = jnp.moveaxis(delta.reshape(B, Hkv, G, nq, bq), 3, 0)
    k_blocks = _blockify(kg, nk, bk)
    v_blocks = _blockify(vg, nk, bk)

    def _p_and_dsr(qb, kb, q0, k0, lse_b, dov, delta_b):
        """Recompute tile probabilities + raw-score grads."""
        sr = jnp.einsum("bhgqk,bhsk->bhgqs", qb.astype(jnp.float32),
                        kb.astype(jnp.float32)) * scale
        if softcap and softcap > 0.0:
            t = jnp.tanh(sr / softcap)
            sc = t * softcap
        else:
            sc = sr
        m = _mask(q0, k0, qb.shape[-2], kb.shape[-2],
                  sq=Sq, skv=Skv, causal=causal, window=window)
        sc = jnp.where(m[None, None, None], sc, NEG_INF)
        p = jnp.exp(sc - lse_b[..., None])                 # (B,Hkv,G,bq,bk)
        dsc = p * (dov - delta_b[..., None])
        if softcap and softcap > 0.0:
            dsc = dsc * (1.0 - t * t)
        return p, dsc

    # ---- pass 1: dq (scan q blocks; inner scan kv blocks)
    def q_step(_, xs):
        qb, db, lse_b, delta_b, qi = xs
        q0 = qi * bq

        def kv_step(dq_acc, kv_j):
            kb, vb, kj = kv_j
            dov = jnp.einsum("bhgqk,bhsk->bhgqs", db, vb.astype(jnp.float32))
            p, dsr = _p_and_dsr(qb, kb, q0, kj * bk, lse_b, dov, delta_b)
            dq_acc = dq_acc + jnp.einsum("bhgqs,bhsk->bhgqk", dsr,
                                         kb.astype(jnp.float32)) * scale
            return dq_acc, None

        dq0 = jnp.zeros((B, Hkv, G, bq, K), jnp.float32)
        dq_b, _ = jax.lax.scan(kv_step, dq0,
                               (k_blocks, v_blocks, jnp.arange(nk)))
        return None, dq_b

    _, dq_blocks = jax.lax.scan(
        q_step, None, (q_blocks, d_blocks, l_blocks, e_blocks,
                       jnp.arange(nq)))

    # ---- pass 2: dk, dv (scan kv blocks; inner scan q blocks)
    def kv_step2(_, xs):
        kb, vb, kj = xs
        k0 = kj * bk

        def q_step2(carry, q_j):
            dk_acc, dv_acc = carry
            qb, db, lse_b, delta_b, qi = q_j
            dov = jnp.einsum("bhgqk,bhsk->bhgqs", db, vb.astype(jnp.float32))
            p, dsr = _p_and_dsr(qb, kb, qi * bq, k0, lse_b, dov, delta_b)
            dv_acc = dv_acc + jnp.einsum("bhgqs,bhgqk->bhsk", p, db)
            dk_acc = dk_acc + jnp.einsum(
                "bhgqs,bhgqk->bhsk", dsr, qb.astype(jnp.float32)) * scale
            return (dk_acc, dv_acc), None

        dk0 = jnp.zeros((B, Hkv, bk, K), jnp.float32)
        dv0 = jnp.zeros((B, Hkv, bk, Kv), jnp.float32)
        (dk_b, dv_b), _ = jax.lax.scan(
            q_step2, (dk0, dv0),
            (q_blocks, d_blocks, l_blocks, e_blocks, jnp.arange(nq)))
        return None, (dk_b, dv_b)

    _, (dk_blocks, dv_blocks) = jax.lax.scan(
        kv_step2, None, (k_blocks, v_blocks, jnp.arange(nk)))

    dq = jnp.moveaxis(dq_blocks, 0, 3).reshape(B, Hkv, G, Sq + pq, K)
    dq = jnp.moveaxis(dq, 3, 1)[:, :Sq].reshape(B, Sq, H, K)
    dk = jnp.moveaxis(dk_blocks, 0, 2).reshape(B, Hkv, Skv + pk, K)
    dk = jnp.moveaxis(dk, 2, 1)[:, :Skv]
    dv = jnp.moveaxis(dv_blocks, 0, 2).reshape(B, Hkv, Skv + pk, Kv)
    dv = jnp.moveaxis(dv, 2, 1)[:, :Skv]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, softcap, block_q, block_k):
    out, _ = _fwd_impl(q, k, v, causal=causal, window=window,
                       softcap=softcap, block_q=block_q, block_k=block_k)
    return out


def _flash_fwd(q, k, v, causal, window, softcap, block_q, block_k):
    out, lse = _fwd_impl(q, k, v, causal=causal, window=window,
                         softcap=softcap, block_q=block_q, block_k=block_k)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, softcap, block_q, block_k, res, dout):
    q, k, v, out, lse = res
    return _bwd_impl(q, k, v, out, lse, dout, causal=causal, window=window,
                     softcap=softcap, block_q=block_q, block_k=block_k)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 512,
                    block_k: int = 512):
    """Drop-in for ``ref.flash_attention`` with flash memory behaviour."""
    return _flash(q, k, v, causal, window, softcap, block_q, block_k)
