# Pallas TPU kernels for the inference hot-spots (validated interpret=True
# on CPU against the pure-jnp oracles in ref.py; dispatched via ops.py).
