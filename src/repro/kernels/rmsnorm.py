"""Pallas TPU fused RMSNorm kernel (row-tiled)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)          # (BR, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (..., D) -> same shape; rows tiled into VMEM blocks."""
    orig_shape = x.shape
    D = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, D)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
