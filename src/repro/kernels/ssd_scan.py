"""Pallas TPU kernel for the Mamba2 SSD (state-space duality) chunked scan.

Grid (batch, S/Q): the chunk axis is TPU-sequential, so the inter-chunk
recurrent state (nh, hd, ds) lives in VMEM scratch and is carried across
chunk iterations — the HBM→VMEM traffic per chunk is exactly one tile of
x/dt/B/C and one tile of y, the minimum possible for this op.

Within a chunk the SSD quadratic form is three MXU matmuls per head
(G = C·Bᵀ, masked-decay weighting, y = M·(dt·x)) plus the carried-state
contribution. Heads are vectorised in-kernel (the head axis is folded into
the matmul batch via dot_general batching dims).

All decay math runs in fp32; the recurrence is numerically identical to the
oracle in ref.py (same segsum formulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, A_ref, b_ref, c_ref, d_ref, y_ref, st_ref,
                state_scr, *, chunk: int, nh: int, hd: int, ds: int,
                ng: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    Q = chunk
    x = x_ref[0].astype(jnp.float32)          # (Q, nh, hd)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, nh)
    A = A_ref[...].astype(jnp.float32)        # (nh,)
    B_ = b_ref[0].astype(jnp.float32)         # (Q, ng, ds)
    C_ = c_ref[0].astype(jnp.float32)         # (Q, ng, ds)
    D = d_ref[...].astype(jnp.float32)        # (nh,)

    rep = nh // ng
    Bh = jnp.repeat(B_, rep, axis=1)          # (Q, nh, ds)
    Ch = jnp.repeat(C_, rep, axis=1)

    dA = dt * A[None, :]                      # (Q, nh)
    dA_cum = jnp.cumsum(dA, axis=0)           # inclusive
    # decay matrix L[h, q, j] = exp(cum[q] - cum[j]) for j <= q
    diff = dA_cum.T[:, :, None] - dA_cum.T[:, None, :]       # (nh, Q, Q)
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where((ki <= qi)[None], jnp.exp(diff), 0.0)

    # intra-chunk quadratic term
    G = jax.lax.dot_general(
        jnp.moveaxis(Ch, 1, 0), jnp.moveaxis(Bh, 1, 0),
        (((2,), (2,)), ((0,), (0,))))                         # (nh, Q, Q)
    M = G * L                                                 # (nh, Q, Q)
    dtx = x * dt[:, :, None]                                  # (Q, nh, hd)
    y_diag = jax.lax.dot_general(
        M, jnp.moveaxis(dtx, 1, 0), (((2,), (1,)), ((0,), (0,))))  # (nh, Q, hd)

    # carried-in state contribution: y_off[q] = exp(cum[q]) * C_q · state
    state = state_scr[...]                                    # (nh, hd, ds)
    y_off = jax.lax.dot_general(
        jnp.moveaxis(Ch, 1, 0), state, (((2,), (2,)), ((0,), (0,))))  # (nh, Q, hd)
    y_off = y_off * jnp.exp(dA_cum).T[:, :, None]

    y = y_diag + y_off + jnp.moveaxis(x, 1, 0) * D[:, None, None]
    y_ref[0] = jnp.moveaxis(y, 0, 1).astype(y_ref.dtype)      # (Q, nh, hd)

    # state update: decay full chunk + within-chunk contributions
    decay_to_end = jnp.exp(dA_cum[-1, :][None, :] - dA_cum)   # (Q, nh)
    wx = dtx * decay_to_end[:, :, None]                       # (Q, nh, hd)
    new_contrib = jax.lax.dot_general(
        jnp.moveaxis(wx, 1, 0), jnp.moveaxis(Bh, 1, 0),
        (((1,), (1,)), ((0,), (0,))))                         # (nh, hd, ds)
    chunk_decay = jnp.exp(dA_cum[-1, :])                      # (nh,)
    state_scr[...] = state * chunk_decay[:, None, None] + new_contrib

    @pl.when(c_idx == pl.num_programs(1) - 1)
    def _emit_state():
        st_ref[0] = state_scr[...].astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B_: jax.Array,
             C_: jax.Array, D: jax.Array, *, chunk: int = 64,
             interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Shapes as in ref.ssd_scan. Returns (y, final_state)."""
    Bb, S, nh, hd = x.shape
    ng, ds = B_.shape[2], B_.shape[3]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    grid = (Bb, S // chunk)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, nh=nh, hd=hd,
                               ds=ds, ng=ng)
    y, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, nh, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, nh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((nh,), lambda b, c: (0,)),
            pl.BlockSpec((1, chunk, ng, ds), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, ng, ds), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((nh,), lambda b, c: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, nh, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, nh, hd, ds), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S, nh, hd), x.dtype),
            jax.ShapeDtypeStruct((Bb, nh, hd, ds), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((nh, hd, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B_, C_, D)
    return y, st
