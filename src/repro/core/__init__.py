from repro.core import containers, energy_model, hlo_analysis, roofline, splitter
from repro.core.scheduler import DivideAndSaveScheduler

__all__ = ["containers", "energy_model", "hlo_analysis", "roofline",
           "splitter", "DivideAndSaveScheduler"]
