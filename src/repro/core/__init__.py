"""Core package surface.

Submodules resolve lazily: the process-container child unpickles its
spawn target (``core.testbed._pinned_main``) at bootstrap, which imports
this package BEFORE the cpuset is applied — an eager ``containers`` /
``roofline`` import here would drag jax in pre-affinity and size XLA's
threadpool from the whole host (see serving/child.py and
``repro.analysis.wire``, which gates this property).
"""
from __future__ import annotations

import importlib

__all__ = ["containers", "energy_model", "hlo_analysis", "roofline",
           "splitter", "testbed", "DivideAndSaveScheduler"]

_FROM = {"DivideAndSaveScheduler": "repro.core.scheduler"}


def __getattr__(name: str):
    if name in _FROM:
        return getattr(importlib.import_module(_FROM[name]), name)
    if name in __all__:
        return importlib.import_module(f"repro.core.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(__all__) | set(globals()))
