"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory term     = HLO_bytes   / (chips × HBM_bw)
    collective term = coll_bytes  / (chips × link_bw)

HLO figures come from the while-aware parser in ``hlo_analysis`` (XLA's own
``cost_analysis`` counts scan bodies once; see that module). Parsed HLO
shapes are per-chip, so pod totals are parser × chips and the terms reduce
to per-chip figures over per-chip bandwidths — identical algebra, stated
both ways in the report.

Hardware model (TPU v5e-class, per assignment):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs.base import ArchConfig, InputShape
from repro.core.hlo_analysis import HloCost

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (one effective link per phase)

# energy model constants (per chip, activity-based; cf. DESIGN.md §2)
P_IDLE_W = 80.0
P_PEAK_W = 350.0

# host-side cost of one decode dispatch (executable launch + sync +
# scheduler bookkeeping) — the per-token overhead the fused chunk decode
# amortises; edge-class hosts sit around 10⁻⁴ s
DISPATCH_OVERHEAD_S = 1e-4


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-chip raw terms
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    # seconds
    t_compute: float
    t_memory: float
    t_collective: float
    # derived
    dominant: str
    step_time: float
    model_flops: float          # 6·N_active·D (pod-global)
    hlo_flops_total: float      # parser flops × chips
    useful_ratio: float         # model_flops / hlo_flops_total
    collectives_by_kind: dict
    # energy
    utilization: float
    power_w_per_chip: float
    energy_j: float

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.t_compute*1e3:.2f} | {self.t_memory*1e3:.2f} | "
                f"{self.t_collective*1e3:.2f} | **{self.dominant}** | "
                f"{self.useful_ratio:.2f} | {self.energy_j:.1f} |")


def model_flops(cfg: ArchConfig, shape: InputShape) -> float:
    """6·N·D for training, 2·N·D for inference (fwd only), N = active params.

    D = tokens processed this step: B×S for train/prefill, B for decode.
    Encoder-decoder archs process the encoder's frame tokens with the
    encoder params separately (and not at all during decode).
    """
    n = cfg.active_param_count()
    n_enc = 0
    if cfg.n_encoder_layers:
        n_enc = cfg._encoder_layer_params() * cfg.n_encoder_layers
        n -= n_enc
    factor = 6.0 if shape.kind == "train" else 2.0
    if shape.kind == "decode":
        return factor * n * shape.global_batch       # encoder not rerun
    d_dec = shape.global_batch * shape.seq_len
    d_enc = shape.global_batch * cfg.encoder_seq
    return factor * (n * d_dec + n_enc * d_enc)


def prefill_flops(cfg: ArchConfig, n_tokens: int,
                  hit_tokens: int = 0) -> float:
    """Forward FLOPs of one prefill: ``2·N_active`` per token actually
    executed. ``hit_tokens`` is the prefix-cache hit length — those
    positions are served from cached K/V and never enter the prefill
    dispatch, so they cost nothing here (the benchmark's FLOPs-saved
    accounting; cached pages still charge HBM, see
    ``containers.feasible``'s ``prefix_cached_blocks``)."""
    return 2.0 * cfg.active_param_count() * max(n_tokens - hit_tokens, 0)


def decode_step_seconds(cfg: ArchConfig, batch: int = 1, *,
                        context_tokens: int = 0) -> float:
    """Device seconds of ONE decode iteration (the roofline max of its
    compute and memory terms): a batch-``batch`` step streams the
    weights once and computes ``2·N_active·B`` FLOPs;
    ``context_tokens`` adds the per-step KV-cache read. This is the
    per-token quantum both ``decode_chunk_tokens`` (amortisation) and
    the scheduler's SLO chunk cap (admission-latency bound) price."""
    flops = 2.0 * cfg.active_param_count() * batch
    bytes_ = 2.0 * cfg.param_count()          # bf16 weight stream per step
    if context_tokens:
        from repro.core.containers import kv_cache_bytes_per_token
        bytes_ += batch * context_tokens * kv_cache_bytes_per_token(
            cfg, max_len=context_tokens)
    return max(flops / PEAK_FLOPS, bytes_ / HBM_BW)


def decode_chunk_tokens(cfg: ArchConfig, batch: int = 1, *,
                        overhead_s: float = DISPATCH_OVERHEAD_S,
                        overhead_frac: float = 0.1,
                        max_chunk: int = 32,
                        context_tokens: int = 0) -> int:
    """Decode chunk length from arithmetic intensity: the cost-model hook
    the serving engine (and the adaptive scheduler's wave sizing) use.

    A batch-``batch`` decode step streams the weights once and computes
    ``2·N_active·B`` FLOPs, so its device time is the roofline max of the
    compute and memory terms; decode sits far below the machine balance
    point, so per-step *dispatch* overhead, not the device, dominates
    small models. Pick the smallest chunk that keeps the per-chunk
    dispatch overhead under ``overhead_frac`` of fused device time,
    clamped to ``[1, max_chunk]`` (compile cost and admission latency
    bound the top).

    ``context_tokens > 0`` adds the KV-cache stream to the memory term:
    a paged engine runs dozens of in-flight sequences, so each decode
    step also reads up to ``batch × context × bytes/token`` of cache —
    at high concurrency that, not the weights, is what the chunk has to
    amortise the dispatch against.
    """
    t_tok = decode_step_seconds(cfg, batch, context_tokens=context_tokens)
    amortised = overhead_s * (1.0 - overhead_frac) / overhead_frac
    return max(1, min(max_chunk, math.ceil(amortised / max(t_tok, 1e-12))))


def build_report(arch: str, shape: InputShape, cfg: ArchConfig,
                 mesh_desc: str, chips: int, cost: HloCost) -> RooflineReport:
    t_c = cost.flops_per_chip / PEAK_FLOPS
    t_m = cost.bytes_per_chip / HBM_BW
    t_x = cost.coll_wire_bytes_per_chip / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    step = max(t_c, t_m, t_x)
    mf = model_flops(cfg, shape)
    hlo_total = cost.flops_per_chip * chips
    util = t_c / step if step > 0 else 0.0
    power = P_IDLE_W + (P_PEAK_W - P_IDLE_W) * util
    energy = chips * power * step
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_desc, chips=chips,
        flops_per_chip=cost.flops_per_chip,
        bytes_per_chip=cost.bytes_per_chip,
        coll_bytes_per_chip=cost.coll_wire_bytes_per_chip,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        dominant=dominant, step_time=step,
        model_flops=mf, hlo_flops_total=hlo_total,
        useful_ratio=mf / hlo_total if hlo_total else 0.0,
        collectives_by_kind=cost.collectives,
        utilization=util, power_w_per_chip=power, energy_j=energy)


HEADER = ("| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) "
          "| dominant | useful | energy (J) |\n"
          "|---|---|---|---|---|---|---|---|---|")
