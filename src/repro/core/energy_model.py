"""Convex time/energy/power models of container splitting (paper §VI).

The paper fits, per device, three models in the container count ``x``
(Table II, all normalised to the 1-container benchmark):

  TX2   time   0.026 x² − 0.21 x + 1.17      (convex quadratic)
  TX2   energy 0.015 x² − 0.12 x + 1.10
  TX2   power −0.016 x² + 0.12 x + 0.90      (concave — utilisation rises)
  Orin  time   0.33 + 1.77 e^(−0.98 x)       (saturating exponential)
  Orin  energy 0.59 + 1.14 e^(−1.03 x)
  Orin  power  1.85 − 1.24 e^(−0.38 x)

This module provides those reference models, fitting machinery for both
forms (pure numpy, no scipy), and the TPU activity-based energy model used
by the roofline. The scheduler (scheduler.py) consumes fitted models to pick
the optimal container count online.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# paper's reference values (Table II)
# ---------------------------------------------------------------------------
PAPER_REF = {
    "tx2": {"time_s": 325.0, "energy_j": 942.0, "power_w": 2.9, "cores": 4,
            "max_containers": 6},
    "orin": {"time_s": 54.0, "energy_j": 700.0, "power_w": 13.0, "cores": 12,
             "max_containers": 12},
}

PAPER_MODELS = {
    ("tx2", "time"): ("quad", (0.026, -0.21, 1.17)),
    ("tx2", "energy"): ("quad", (0.015, -0.12, 1.10)),
    ("tx2", "power"): ("quad", (-0.016, 0.12, 0.90)),
    ("orin", "time"): ("exp", (0.33, 1.77, 0.98)),
    ("orin", "energy"): ("exp", (0.59, 1.14, 1.03)),
    ("orin", "power"): ("exp", (1.85, -1.24, 0.38)),
}


def eval_model(kind: str, coef: Sequence[float], x) -> np.ndarray:
    x = np.asarray(x, dtype=float)
    if kind == "quad":
        a, b, c = coef
        return a * x * x + b * x + c
    a, b, lam = coef  # a + b * exp(-lam x)
    return a + b * np.exp(-lam * x)


@dataclasses.dataclass
class FittedModel:
    kind: str                 # "quad" | "exp"
    coef: tuple
    rmse: float

    def __call__(self, x):
        return eval_model(self.kind, self.coef, x)

    def argmin(self, n_max: int) -> int:
        xs = np.arange(1, n_max + 1)
        return int(xs[np.argmin(self(xs))])


def fit_quadratic(x: Sequence[float], y: Sequence[float]) -> FittedModel:
    x, y = np.asarray(x, float), np.asarray(y, float)
    A = np.stack([x * x, x, np.ones_like(x)], axis=1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    rmse = float(np.sqrt(np.mean((A @ coef - y) ** 2)))
    return FittedModel("quad", tuple(coef), rmse)


def fit_exponential(x: Sequence[float], y: Sequence[float],
                    lam_grid: Sequence[float] | None = None) -> FittedModel:
    """Fit y = a + b·exp(−λx): grid over λ, linear lsq for (a, b)."""
    x, y = np.asarray(x, float), np.asarray(y, float)
    if lam_grid is None:
        lam_grid = np.linspace(0.05, 3.0, 120)
    best = None
    for lam in lam_grid:
        e = np.exp(-lam * x)
        A = np.stack([np.ones_like(x), e], axis=1)
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        r = float(np.sqrt(np.mean((A @ coef - y) ** 2)))
        if best is None or r < best.rmse:
            best = FittedModel("exp", (coef[0], coef[1], float(lam)), r)
    return best


def fit_best(x, y) -> FittedModel:
    """Paper fits a quadratic on one device and an exponential on the other;
    pick whichever form fits the observations better."""
    q, e = fit_quadratic(x, y), fit_exponential(x, y)
    return q if q.rmse <= e.rmse else e


# ---------------------------------------------------------------------------
# edge-device simulator (for the paper-reproduction benchmarks)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class EdgeDeviceModel:
    """Analytic model of a multi-core edge device running n containers.

    Mechanism (paper §IV/§VI): a single inference process saturates poorly —
    its effective parallel fraction ``f`` is limited (Amdahl), so a chunk of
    every core-second is stranded. n independent containers with C/n cores
    each raise utilisation: time falls, average power rises (the busy
    core-seconds ``W`` are invariant, so active-cores = W/T grows as T
    shrinks), and energy E = P_idle·T + p_core·W falls with T — exactly the
    paper's "power +84 %, energy −43 %" bookkeeping. Per-container overhead
    ``o`` and past-core-count thrash make both curves convex.
    """

    cores: int
    work_core_s: float            # busy core-seconds of the whole task
    parallel_frac: float          # Amdahl fraction of a single process
    container_overhead_s: float   # per-container startup/runtime overhead
    thrash_penalty: float = 0.05  # per container beyond core count
    p_idle_w: float = 1.5
    p_core_w: float = 0.5

    def single_container_time(self, cpus: float) -> float:
        """Fig. 1: one container with a fractional --cpus allocation."""
        c = max(cpus, 1e-2)
        f = self.parallel_frac
        eff = ((1 - f) + f / c) if c >= 1.0 else 1.0 / c
        return self.work_core_s * eff + self.container_overhead_s

    def time(self, n: int) -> float:
        """Fig. 3a: n containers, cores evenly split, data evenly split."""
        c = self.cores / n
        w = self.work_core_s / n
        f = self.parallel_frac
        t = w * ((1 - f) + f / c) if c >= 1.0 else w / c
        t += self.container_overhead_s
        if n > self.cores:
            t *= 1.0 + self.thrash_penalty * (n - self.cores)
        return t

    def active_cores(self, n: int) -> float:
        # container overhead is wait/IO, not compute: busy core-seconds are
        # the task's work itself, invariant in n
        return min(float(self.cores), self.work_core_s / self.time(n))

    def power(self, n: int) -> float:
        return self.p_idle_w + self.p_core_w * self.active_cores(n)

    def energy(self, n: int) -> float:
        return self.power(n) * self.time(n)


def tx2_model() -> EdgeDeviceModel:
    """Calibrated to Table II refs (325 s, 942 J, 2.9 W, 4 cores)."""
    return EdgeDeviceModel(cores=4, work_core_s=841.0, parallel_frac=0.85,
                           container_overhead_s=20.0, thrash_penalty=0.05,
                           p_idle_w=1.53, p_core_w=0.53)


def orin_model() -> EdgeDeviceModel:
    """Calibrated to Table II refs (54 s, 700 J, 13 W, 12 cores)."""
    return EdgeDeviceModel(cores=12, work_core_s=91.5, parallel_frac=0.55,
                           container_overhead_s=8.6, thrash_penalty=0.04,
                           p_idle_w=8.3, p_core_w=2.77)


# ---------------------------------------------------------------------------
# TPU container-split model (the hardware adaptation; cf. DESIGN.md §2)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TpuSplitPoint:
    n_containers: int
    chips_per_container: int
    t_compute: float
    t_memory: float
    t_collective: float
    bytes_per_chip: float      # HBM footprint (weights replicated/container)
    feasible: bool

    @property
    def step_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def energy(self, chips: int, p_idle: float = 80.0,
               p_peak: float = 350.0) -> float:
        util = self.t_compute / self.step_time if self.step_time else 0.0
        return chips * (p_idle + (p_peak - p_idle) * util) * self.step_time
