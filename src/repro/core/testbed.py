"""CPU container testbed — the paper's mechanism, literally.

``docker run --cpus=C/n`` is reproduced as an OS process pinned to a
disjoint set of C/n cores (``os.sched_setaffinity``, applied BEFORE jax
initialises its threadpool, so XLA's worker threads inherit the cpuset —
the in-process equivalent of the cgroup cpu limit). Two workloads run on
that harness:

  * the YOLOv4-tiny-shaped convolutional detector below, frame-by-frame
    over a synthetic video split into equal segments (§V steps 1-4), and
  * full ``ServingEngine`` containers (serving/process_pool.py), which
    reuse ``assign_core_sets`` + ``spawn_pinned`` from this module.

Core carve-up is centralised in ``assign_core_sets``: per-container core
sets are pairwise disjoint **by construction and by assertion** — asking
for more containers than cores raises instead of silently time-sharing
(the historic modulo wrap corrupted both the isolation claim and
``busy_core_seconds``). Pass ``allow_shared=True`` to opt into round-robin
shared cores explicitly: the analogue of fractional ``--cpus < 1`` shares,
where the kernel time-slices and the isolation claim is knowingly waived.

Energy on the host is modelled (no power sensor in this container):
``P(t) = P_IDLE + P_CORE · busy_cores(t)`` integrated over the run — the
same activity-based bookkeeping the paper measures with the Jetson INA
sensors. Constants below are host-class x86 figures; they cancel in the
normalised (vs 1-container benchmark) plots the paper reports.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import time
from typing import Callable, Sequence

import numpy as np

from repro.core import splitter

P_IDLE_W = 40.0    # host idle draw
P_CORE_W = 3.5     # per busy core

_FRAME_SHAPE = (128, 128, 3)
# YOLOv4-tiny-ish backbone: stride-2 conv stages + 1x1 head (CSP blocks
# collapsed — we need the compute/memory character, not mAP)
_CHANNELS = (16, 32, 64, 128, 256)


# ---------------------------------------------------------------------------
# reusable pinned-worker harness
# ---------------------------------------------------------------------------
def available_cores() -> list[int]:
    """Cores this process may use — ``sched_getaffinity`` where it exists
    (Linux: respects cgroup/container cpusets), ``cpu_count`` elsewhere so
    non-Linux dev hosts still get a sane carve-up (children then run
    unpinned, see ``_pinned_main``)."""
    try:
        return sorted(os.sched_getaffinity(0))
    except AttributeError:
        return list(range(os.cpu_count() or 1))


def assign_core_sets(n_containers: int, total_cores: int | None = None,
                     avail: Sequence[int] | None = None,
                     allow_shared: bool = False) -> list[frozenset[int]]:
    """Carve the host's cores into one set per container — the
    ``docker run --cpus`` allocation as explicit cpusets.

    With ``n_containers <= cores`` the sets are contiguous, equal-size
    (``cores // n``) and pairwise disjoint; disjointness is asserted, not
    assumed, because it IS the isolation claim every measurement rests on.
    With more containers than cores the request is contradictory unless
    ``allow_shared=True``, which degrades to round-robin single-core sets
    (kernel time-slicing — the fractional-share analogue) instead of the
    old silent modulo wrap.
    """
    if n_containers <= 0:
        raise ValueError("n_containers must be positive")
    if avail is None:
        avail = available_cores()
    else:
        avail = sorted(set(avail))
    if total_cores is not None:
        if total_cores <= 0:
            raise ValueError("total_cores must be positive")
        avail = avail[:total_cores]
    total = len(avail)
    if n_containers > total:
        if not allow_shared:
            raise ValueError(
                f"{n_containers} containers over {total} cores cannot have "
                "pairwise-disjoint core sets; reduce n_containers or pass "
                "allow_shared=True to accept round-robin time-shared cores "
                "(the --cpus < 1 fractional-share analogue)")
        return [frozenset({avail[i % total]}) for i in range(n_containers)]
    cpc = total // n_containers
    sets = [frozenset(avail[i * cpc:(i + 1) * cpc])
            for i in range(n_containers)]
    seen: set[int] = set()
    for s in sets:
        assert len(s) == cpc and not (seen & s), \
            "core assignment produced overlapping or ragged sets"
        seen |= s
    return sets


def _pinned_main(cores: Sequence[int], body: Callable, conn, args) -> None:
    """Child entry point: affinity FIRST, then the body (whose jax import
    sizes the XLA threadpool from the already-restricted cpuset)."""
    try:
        os.sched_setaffinity(0, set(cores))
    except (AttributeError, OSError):   # non-Linux dev hosts: run unpinned
        pass
    body(conn, *args)


def spawn_pinned(body: Callable, cores: Sequence[int], args: tuple = (),
                 ctx=None):
    """Spawn ``body(conn, *args)`` in a fresh process pinned to ``cores``
    before jax can initialise. Returns ``(process, parent_conn)``.

    ``body`` must be a module-level (picklable) function and must do its
    jax import inside itself — a spawn context guarantees the child starts
    without the parent's already-initialised jax runtime.
    """
    ctx = ctx or mp.get_context("spawn")
    parent, child = ctx.Pipe()
    proc = ctx.Process(target=_pinned_main,
                       args=(sorted(cores), body, child, args))
    proc.start()
    child.close()
    return proc, parent


# ---------------------------------------------------------------------------
# the paper's video-detection workload on that harness
# ---------------------------------------------------------------------------
def _detector_body(conn, go, frames, batch):
    """Container body. Affinity was set by the harness; jax import here
    (threadpool size follows the cpuset), then warmup, then the timed
    frame loop."""
    import jax
    import jax.numpy as jnp

    def init(key):
        params = []
        cin = _FRAME_SHAPE[-1]
        for cout in _CHANNELS:
            key, k1 = jax.random.split(key)
            params.append(jax.random.normal(k1, (3, 3, cin, cout),
                                            jnp.float32) * 0.1)
            cin = cout
        key, k1 = jax.random.split(key)
        head = jax.random.normal(k1, (1, 1, cin, 18), jnp.float32) * 0.1
        return params, head

    @jax.jit
    def infer(params_head, x):
        params, head = params_head
        for w in params:
            x = jax.lax.conv_general_dilated(
                x, w, window_strides=(2, 2), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jnp.maximum(x, 0.1 * x)          # leaky relu
        x = jax.lax.conv_general_dilated(
            x, head, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jnp.max(x, axis=(1, 2))           # per-frame detection proxy

    ph = init(jax.random.PRNGKey(0))
    warm = infer(ph, jnp.asarray(frames[:batch]))
    warm.block_until_ready()
    conn.send("ready")
    go.wait()

    t0 = time.perf_counter()
    outs = []
    for i in range(0, len(frames), batch):
        fb = frames[i:i + batch]
        if len(fb) < batch:                      # pad the tail batch
            fb = np.concatenate(
                [fb, np.zeros((batch - len(fb), *_FRAME_SHAPE),
                              np.float32)])
        outs.append(np.asarray(infer(ph, jnp.asarray(fb))))
    dt = time.perf_counter() - t0
    out = np.concatenate(outs)[:len(frames)]
    conn.send((dt, out))
    conn.close()


@dataclasses.dataclass
class SplitRunResult:
    n_containers: int
    cores_per_container: int
    wall_s: float                 # max over containers (parallel)
    per_container_s: list
    outputs: np.ndarray           # combined, original frame order
    busy_core_seconds: float
    disjoint: bool = True         # False only under allow_shared overflow

    @property
    def avg_power_w(self) -> float:
        return P_IDLE_W + P_CORE_W * self.busy_core_seconds / self.wall_s

    @property
    def energy_j(self) -> float:
        return self.avg_power_w * self.wall_s


def run_split(frames: np.ndarray, n_containers: int,
              total_cores: int | None = None,
              batch: int = 8, allow_shared: bool = False) -> SplitRunResult:
    """§V: split the video into n segments, spawn n pinned containers,
    run simultaneously, combine in order. Raises for ``n_containers``
    beyond the core budget unless ``allow_shared`` (see
    ``assign_core_sets``)."""
    core_sets = assign_core_sets(n_containers, total_cores=total_cores,
                                 allow_shared=allow_shared)
    cpc = len(core_sets[0])
    disjoint = sum(len(s) for s in core_sets) == len(set().union(*core_sets))
    segs = splitter.split_array(frames, n_containers)

    ctx = mp.get_context("spawn")
    go = ctx.Event()
    procs, conns = [], []
    for cores, seg in zip(core_sets, segs):
        pr, parent = spawn_pinned(_detector_body, cores,
                                  args=(go, seg, batch), ctx=ctx)
        procs.append(pr)
        conns.append(parent)
    for c in conns:                # all children compiled & ready
        assert c.recv() == "ready"
    t0 = time.perf_counter()
    go.set()
    times, outs = [], []
    for c in conns:
        dt, out = c.recv()
        times.append(dt)
        outs.append(out)
    wall = time.perf_counter() - t0
    for pr in procs:
        pr.join()
    combined = splitter.combine_arrays(outs)
    # per-container core-seconds; under allow_shared overflow the sets
    # time-slice, so cap at what the distinct cores could physically have
    # run — otherwise avg_power_w would report more busy cores than exist
    busy = sum(t * cpc for t in times)
    busy = min(busy, len(set().union(*core_sets)) * wall)
    return SplitRunResult(n_containers, cpc, wall, times, combined, busy,
                          disjoint)


def run_single_container(frames: np.ndarray, cores: int,
                         batch: int = 8) -> float:
    """Fig. 1 point: ONE container limited to ``cores`` cores."""
    return run_split(frames, 1, total_cores=cores, batch=batch).wall_s


def make_video(n_frames: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n_frames, *_FRAME_SHAPE)).astype(np.float32)
