"""CPU container testbed — the paper's mechanism, literally.

``docker run --cpus=C/n`` is reproduced as an OS process pinned to a
disjoint set of C/n cores (``os.sched_setaffinity``, applied BEFORE jax
initialises its threadpool, so XLA's worker threads inherit the cpuset —
the in-process equivalent of the cgroup cpu limit). The workload is a
YOLOv4-tiny-shaped convolutional detector in JAX run frame-by-frame over a
synthetic video; the video is split into equal segments (core/splitter.py)
and all containers run simultaneously, results concatenated — §V steps 1-4.

Energy on the host is modelled (no power sensor in this container):
``P(t) = P_IDLE + P_CORE · busy_cores(t)`` integrated over the run — the
same activity-based bookkeeping the paper measures with the Jetson INA
sensors. Constants below are host-class x86 figures; they cancel in the
normalised (vs 1-container benchmark) plots the paper reports.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import time
from typing import Sequence

import numpy as np

from repro.core import splitter

P_IDLE_W = 40.0    # host idle draw
P_CORE_W = 3.5     # per busy core

_FRAME_SHAPE = (128, 128, 3)
# YOLOv4-tiny-ish backbone: stride-2 conv stages + 1x1 head (CSP blocks
# collapsed — we need the compute/memory character, not mAP)
_CHANNELS = (16, 32, 64, 128, 256)


def _child(cores, frames, batch, conn, go):
    """Container body. Affinity FIRST, then jax import (threadpool size
    follows the cpuset), then warmup, then the timed frame loop."""
    os.sched_setaffinity(0, cores)
    import jax
    import jax.numpy as jnp

    def init(key):
        params = []
        cin = _FRAME_SHAPE[-1]
        for i, cout in enumerate(_CHANNELS):
            key, k1 = jax.random.split(key)
            params.append(jax.random.normal(k1, (3, 3, cin, cout),
                                            jnp.float32) * 0.1)
            cin = cout
        key, k1 = jax.random.split(key)
        head = jax.random.normal(k1, (1, 1, cin, 18), jnp.float32) * 0.1
        return params, head

    @jax.jit
    def infer(params_head, x):
        params, head = params_head
        for w in params:
            x = jax.lax.conv_general_dilated(
                x, w, window_strides=(2, 2), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jnp.maximum(x, 0.1 * x)          # leaky relu
        x = jax.lax.conv_general_dilated(
            x, head, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jnp.max(x, axis=(1, 2))           # per-frame detection proxy

    ph = init(jax.random.PRNGKey(0))
    warm = infer(ph, jnp.asarray(frames[:batch]))
    warm.block_until_ready()
    conn.send("ready")
    go.wait()

    t0 = time.perf_counter()
    outs = []
    for i in range(0, len(frames), batch):
        fb = frames[i:i + batch]
        if len(fb) < batch:                      # pad the tail batch
            fb = np.concatenate(
                [fb, np.zeros((batch - len(fb), *_FRAME_SHAPE),
                              np.float32)])
        outs.append(np.asarray(infer(ph, jnp.asarray(fb))))
    dt = time.perf_counter() - t0
    out = np.concatenate(outs)[:len(frames)]
    conn.send((dt, out))
    conn.close()


@dataclasses.dataclass
class SplitRunResult:
    n_containers: int
    cores_per_container: int
    wall_s: float                 # max over containers (parallel)
    per_container_s: list
    outputs: np.ndarray           # combined, original frame order
    busy_core_seconds: float

    @property
    def avg_power_w(self) -> float:
        return P_IDLE_W + P_CORE_W * self.busy_core_seconds / self.wall_s

    @property
    def energy_j(self) -> float:
        return self.avg_power_w * self.wall_s


def run_split(frames: np.ndarray, n_containers: int,
              total_cores: int | None = None,
              batch: int = 8) -> SplitRunResult:
    """§V: split the video into n segments, spawn n pinned containers,
    run simultaneously, combine in order."""
    avail = sorted(os.sched_getaffinity(0))
    total_cores = total_cores or len(avail)
    avail = avail[:total_cores]
    cpc = max(1, total_cores // n_containers)
    segs = splitter.split_array(frames, n_containers)

    ctx = mp.get_context("spawn")
    go = ctx.Event()
    procs, conns = [], []
    for i, seg in enumerate(segs):
        cores = [avail[(i * cpc + j) % len(avail)] for j in range(cpc)]
        parent, child = ctx.Pipe()
        pr = ctx.Process(target=_child,
                         args=(set(cores), seg, batch, child, go))
        pr.start()
        procs.append(pr)
        conns.append(parent)
    for c in conns:                # all children compiled & ready
        assert c.recv() == "ready"
    t0 = time.perf_counter()
    go.set()
    times, outs = [], []
    for c in conns:
        dt, out = c.recv()
        times.append(dt)
        outs.append(out)
    wall = time.perf_counter() - t0
    for pr in procs:
        pr.join()
    combined = splitter.combine_arrays(outs)
    busy = sum(t * cpc for t in times)
    return SplitRunResult(n_containers, cpc, wall, times, combined, busy)


def run_single_container(frames: np.ndarray, cores: int,
                         batch: int = 8) -> float:
    """Fig. 1 point: ONE container limited to ``cores`` cores."""
    return run_split(frames, 1, total_cores=cores, batch=batch).wall_s


def make_video(n_frames: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n_frames, *_FRAME_SHAPE)).astype(np.float32)
