"""Workload splitting (paper §V step 1) — the "divide" in Divide and Save.

A splittable workload is a sequence of independent units (video frames in
the paper; inference requests here). ``split`` cuts it into n contiguous,
maximally-equal segments; ``combine`` restores the original order. The
invariant tested by hypothesis: combine(split(w, n)) == w for every n, and
segment sizes differ by at most 1.
"""
from __future__ import annotations

from typing import Sequence, TypeVar

import numpy as np

T = TypeVar("T")


def segment_sizes(n_items: int, n_segments: int) -> list[int]:
    if n_segments <= 0:
        raise ValueError("n_segments must be positive")
    base, rem = divmod(n_items, n_segments)
    return [base + (1 if i < rem else 0) for i in range(n_segments)]


def split(items: Sequence[T], n_segments: int) -> list[list[T]]:
    sizes = segment_sizes(len(items), n_segments)
    out, i = [], 0
    for s in sizes:
        out.append(list(items[i:i + s]))
        i += s
    return out


def combine(segments: Sequence[Sequence[T]]) -> list[T]:
    out: list[T] = []
    for seg in segments:
        out.extend(seg)
    return out


def split_array(x: np.ndarray, n_segments: int, axis: int = 0) -> list[np.ndarray]:
    """Split an array of independent units (frames / requests) along axis."""
    sizes = segment_sizes(x.shape[axis], n_segments)
    idx = np.cumsum(sizes)[:-1]
    return np.split(x, idx, axis=axis)


def combine_arrays(parts: Sequence[np.ndarray], axis: int = 0) -> np.ndarray:
    return np.concatenate(list(parts), axis=axis)
