"""Container abstraction on a TPU pod: disjoint sub-mesh replica groups.

The paper's "container with C/n CPU cores" maps to "model replica on a
sub-mesh of chips/n chips" (DESIGN.md §2). On a pod mesh
``(data=D, model=M)`` the factorisation is expressed *logically*: choosing
``n`` containers re-factors the pod into ``(data=n, model=chips/n)`` with
parameters replicated over ``data`` (no cross-container collectives) and the
request batch split over ``data`` (core/splitter.py semantics).

``ContainerSpec`` enumerates the feasible factorisations of a pod and their
per-chip weight memory (weights are replicated per container — the analogue
of the paper's per-container memory overhead that capped the TX2 at 6
containers); the scheduler uses this to bound its search.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ContainerSpec:
    n_containers: int
    chips_per_container: int
    total_chips: int

    @property
    def mesh_shape(self) -> tuple[int, int]:
        return (self.n_containers, self.chips_per_container)


def factorizations(total_chips: int, max_containers: int | None = None
                   ) -> list[ContainerSpec]:
    """All 2^k factorisations n × (chips/n) of the pod."""
    out = []
    n = 1
    while n <= total_chips:
        if max_containers is None or n <= max_containers:
            out.append(ContainerSpec(n, total_chips // n, total_chips))
        n *= 2
    return out


def weight_bytes_per_chip(cfg: ArchConfig, spec: ContainerSpec,
                          bytes_per_param: int = 2) -> float:
    """Weights are sharded inside a container, replicated across them."""
    return cfg.param_count() * bytes_per_param / spec.chips_per_container


def feasible(cfg: ArchConfig, spec: ContainerSpec, hbm_bytes: float = 16e9,
             activation_headroom: float = 0.35,
             extra_bytes_per_chip: float = 0.0) -> bool:
    """Does one container's weight shard (+KV/activations) fit per chip?"""
    need = weight_bytes_per_chip(cfg, spec) + extra_bytes_per_chip
    return need <= hbm_bytes * (1.0 - activation_headroom)


def feasible_counts(cfg: ArchConfig, total_chips: int,
                    hbm_bytes: float = 16e9,
                    max_containers: int | None = None,
                    activation_headroom: float = 0.35,
                    extra_bytes_per_chip: float = 0.0) -> list[int]:
    """Container counts the online scheduler may search: the power-of-two
    factorisations of the pod whose per-chip weight shard (+headroom) fits
    — the memory bound that capped the paper's TX2 at 6 containers."""
    return [s.n_containers
            for s in factorizations(total_chips, max_containers)
            if feasible(cfg, s, hbm_bytes, activation_headroom,
                        extra_bytes_per_chip)]


def container_mesh(spec: ContainerSpec,
                   axis_names: tuple[str, str] = ("data", "model")):
    """Build the jax mesh for a factorisation (requires enough devices —
    used under the dry-run's host-device override)."""
    return jax.make_mesh(spec.mesh_shape, axis_names)
