"""Container abstraction on a TPU pod: disjoint sub-mesh replica groups.

The paper's "container with C/n CPU cores" maps to "model replica on a
sub-mesh of chips/n chips" (DESIGN.md §2). The factorisation exists in two
forms:

  * **logical** — ``container_mesh`` builds ONE joint pod mesh
    ``(data=n, model=chips/n)`` where the ``data`` axis is the container
    axis (weights replicated over it, the request batch split over it —
    core/splitter.py semantics). This is the single-program view used by
    the dry-run and the collective roofline.
  * **physical** — ``container_meshes`` carves the pod's device list into
    ``n`` *disjoint* contiguous slices and builds one independent
    ``jax.sharding.Mesh`` per container over its slice. Each container's
    engine commits params/caches onto its own slice (serving/engine.py),
    so n containers genuinely occupy n disjoint device sets and serve in
    parallel with zero cross-container collectives — the paper's
    "C/n cores per container", chip-native.

``ContainerSpec`` enumerates the feasible factorisations of a pod and their
per-chip weight memory (weights are replicated per container — the analogue
of the paper's per-container memory overhead that capped the TX2 at 6
containers); the scheduler uses this to bound its search.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ContainerSpec:
    n_containers: int
    chips_per_container: int
    total_chips: int

    @property
    def mesh_shape(self) -> tuple[int, int]:
        return (self.n_containers, self.chips_per_container)


def factorizations(total_chips: int, max_containers: int | None = None
                   ) -> list[ContainerSpec]:
    """All 2^k factorisations n × (chips/n) of the pod."""
    out = []
    n = 1
    while n <= total_chips:
        if max_containers is None or n <= max_containers:
            out.append(ContainerSpec(n, total_chips // n, total_chips))
        n *= 2
    return out


def weight_bytes_per_chip(cfg: ArchConfig, spec: ContainerSpec,
                          bytes_per_param: int = 2) -> float:
    """Weights are sharded inside a container, replicated across them."""
    return cfg.param_count() * bytes_per_param / spec.chips_per_container


def _pageable_window(window: int, max_len: int) -> bool:
    # mirror of models.cache.pageable without a core -> models import
    return window == 0 or window >= max_len


def kv_cache_bytes_per_token(cfg: ArchConfig, *, max_len: int = 512,
                             dtype_bytes: int = 2) -> float:
    """Bytes of paged KV cache one context token costs across all pageable
    layers (a logical block spans every layer, so a block costs
    ``block_size ×`` this). Counts exactly the groups the paged engine
    pages: full-horizon attention / MLA layers; SSM states, genuinely
    sliding windows and whisper encoder memories are per-SEQUENCE costs,
    not per-token, and are excluded."""
    attn_tok = 2 * cfg.n_kv_heads * cfg.head_dim * dtype_bytes
    if cfg.kv_cache_dtype == "int8":
        # int8 pages + one f32 absmax scale per (token, kv head) for k and v
        attn_tok = 2 * cfg.n_kv_heads * (cfg.head_dim + 4)
    mla_tok = (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * dtype_bytes
    win_ok = _pageable_window(cfg.sliding_window, max_len)
    if cfg.arch_type == "audio":
        return cfg.n_layers * attn_tok          # decoder self-attn, W=max_len
    if cfg.arch_type == "hybrid":
        return (cfg.n_layers // cfg.shared_attn_every) * attn_tok
    if cfg.arch_type == "ssm":
        return 0.0
    if cfg.is_moe:
        return cfg.n_layers * (mla_tok if cfg.mla else
                               (attn_tok if win_ok else 0.0))
    if cfg.local_global_pattern:
        per = cfg.local_global_pattern + 1
        n_global = cfg.n_layers // per
        n_local = cfg.n_layers - n_global
        return (n_global + (n_local if win_ok else 0)) * attn_tok
    return cfg.n_layers * attn_tok if win_ok else 0.0


def kv_block_bytes(cfg: ArchConfig, block_size: int = 16, *,
                   max_len: int = 512, dtype_bytes: int = 2) -> float:
    """HBM cost of ONE logical KV block (summed over all pageable layers)."""
    return block_size * kv_cache_bytes_per_token(cfg, max_len=max_len,
                                                 dtype_bytes=dtype_bytes)


def feasible(cfg: ArchConfig, spec: ContainerSpec, hbm_bytes: float = 16e9,
             activation_headroom: float = 0.35,
             extra_bytes_per_chip: float = 0.0, kv_blocks: int = 0,
             block_size: int = 16, kv_dtype_bytes: int = 2,
             max_len: int = 512, prefix_cached_blocks: int = 0) -> bool:
    """Does one container's weight shard (+KV/activations) fit per chip?
    ``kv_blocks > 0`` adds the block-granular paged-cache pool (shared
    inside a container, so divided over its chips) — the memory model the
    paged engine actually allocates, replacing the n_slots × max_len
    dense worst case. ``prefix_cached_blocks`` budgets a resident
    prefix-cache working set ON TOP of the concurrency pool: those blocks
    stay allocated between requests (refcount-held by the cache index),
    so a deployment sized for ``kv_blocks`` of in-flight state plus R
    cached blocks must fit ``kv_blocks + R``."""
    need = weight_bytes_per_chip(cfg, spec) + extra_bytes_per_chip
    if kv_blocks or prefix_cached_blocks:
        need += ((kv_blocks + prefix_cached_blocks)
                 * kv_block_bytes(cfg, block_size, max_len=max_len,
                                  dtype_bytes=kv_dtype_bytes)
                 / spec.chips_per_container)
    return need <= hbm_bytes * (1.0 - activation_headroom)


def feasible_counts(cfg: ArchConfig, total_chips: int,
                    hbm_bytes: float = 16e9,
                    max_containers: int | None = None,
                    activation_headroom: float = 0.35,
                    extra_bytes_per_chip: float = 0.0, kv_blocks: int = 0,
                    block_size: int = 16, kv_dtype_bytes: int = 2,
                    max_len: int = 512,
                    prefix_cached_blocks: int = 0) -> list[int]:
    """Container counts the online scheduler may search: the power-of-two
    factorisations of the pod whose per-chip weight shard (+headroom) fits
    — the memory bound that capped the paper's TX2 at 6 containers. With
    ``kv_blocks`` set, each container additionally budgets its paged KV
    pool (plus ``prefix_cached_blocks`` of resident prefix-cache working
    set), so DivideAndSaveScheduler sees the block-granular frontier."""
    return [s.n_containers
            for s in factorizations(total_chips, max_containers)
            if feasible(cfg, s, hbm_bytes, activation_headroom,
                        extra_bytes_per_chip, kv_blocks, block_size,
                        kv_dtype_bytes, max_len, prefix_cached_blocks)]


def container_mesh(spec: ContainerSpec,
                   axis_names: tuple[str, str] = ("data", "model")):
    """The joint (logical) mesh for a factorisation: one mesh over the
    whole pod with the container count on the first axis (requires enough
    devices — used under the dry-run's host-device override)."""
    return jax.make_mesh(spec.mesh_shape, axis_names)


def partition_indices(total_chips: int, n_containers: int) -> list[range]:
    """Pure index partition behind ``container_meshes``: ``n`` contiguous,
    equal, disjoint ranges covering ``range(total_chips)`` — the device-set
    invariant the property tests pin down without needing devices."""
    if n_containers <= 0:
        raise ValueError("n_containers must be positive")
    if total_chips % n_containers != 0:
        raise ValueError(
            f"{n_containers} containers do not divide {total_chips} chips")
    per = total_chips // n_containers
    return [range(i * per, (i + 1) * per) for i in range(n_containers)]


def container_meshes(spec: ContainerSpec, devices=None,
                     axis_names: tuple[str, str] = ("data", "model")
                     ) -> list[jax.sharding.Mesh]:
    """The physical factorisation: one ``Mesh`` per container, each over a
    disjoint contiguous slice of the pod's devices, shaped
    ``(data=1, model=chips_per_container)``. Within a container the data
    axis is trivially 1 (the container axis lives ACROSS meshes, carried
    by the pool, not inside any one program); the model axis holds the
    container's chips for intra-container sharding at pod scale."""
    if devices is None:
        devices = jax.devices()
    if len(devices) < spec.total_chips:
        raise ValueError(
            f"spec wants {spec.total_chips} chips, host has {len(devices)}")
    out = []
    for idx in partition_indices(spec.total_chips, spec.n_containers):
        arr = np.empty((1, spec.chips_per_container), dtype=object)
        for j, i in enumerate(idx):
            arr[0, j] = devices[i]
        out.append(jax.sharding.Mesh(arr, axis_names))
    return out
