"""Divide-and-Save scheduler: choose the container count online.

The paper's concluding proposal ("energy-efficient job schedulers that split
input data, obtaining the optimal number of containers in an online
fashion") implemented:

  * observe (n, time, energy) samples of completed jobs,
  * fit the paper's convex model forms (quadratic / saturating-exp,
    whichever fits better) to each metric,
  * pick argmin of the chosen objective over the *feasible* container
    counts (memory-bounded, cf. core/containers.py), with ε-greedy
    exploration so unvisited counts eventually get sampled.

Works identically for the CPU testbed (samples = measured wall times) and
the TPU pod (samples = roofline-derived step time / energy per
factorisation).

**SLO objective** (``energy_under_slo``): the mean-optimal objectives
above ignore the tail, and edge traffic is bursty enough that a
mean-optimal split routinely violates p95 targets (ECORE's framing —
energy minimisation *subject to* per-class latency constraints). Beside
the two mean models the scheduler therefore keeps a **quantile model**:
per-window ttfc-p95 samples fitted over the container count with the
same convex machinery (``fit_best``) and the same RMSE trust check, and
``pick()`` then minimises energy over the counts whose *predicted* p95
meets ``slo_ttfc_p95_s``. ``chunk_for()`` co-optimises the decode chunk
length with the count: the roofline's amortisation optimum
(``decode_chunk_tokens``), capped so one chunk's device time cannot eat
more than a fraction of the ttfc budget — a queued arrival waits up to
a full chunk before admission.
"""
from __future__ import annotations

import dataclasses
import random
from collections import defaultdict
from typing import Literal

from repro.core.energy_model import FittedModel, fit_best

Objective = Literal["energy", "time", "energy_under_deadline",
                    "energy_under_slo"]


@dataclasses.dataclass
class Observation:
    n: int
    time_s: float
    energy_j: float
    ttfc_p95_s: float | None = None   # window tail sample (SLO objective)


class DivideAndSaveScheduler:
    # fraction of the ttfc budget one fused decode chunk may occupy
    # before chunk_for caps it below the amortisation optimum
    CHUNK_SLO_FRAC = 0.25

    def __init__(self, feasible_counts: list[int],
                 objective: Objective = "energy",
                 deadline_s: float | None = None,
                 epsilon: float = 0.1, seed: int = 0,
                 slo_ttfc_p95_s: float | None = None):
        if not feasible_counts:
            raise ValueError("no feasible container counts")
        if objective == "energy_under_slo" and slo_ttfc_p95_s is None:
            raise ValueError("energy_under_slo needs slo_ttfc_p95_s")
        self.feasible = sorted(set(feasible_counts))
        self.objective = objective
        self.deadline = deadline_s
        self.slo_ttfc_p95_s = slo_ttfc_p95_s
        self.epsilon = epsilon
        self._rng = random.Random(seed)
        self._obs: list[Observation] = []
        self.time_model: FittedModel | None = None
        self.energy_model: FittedModel | None = None
        self.ttfc_model: FittedModel | None = None

    # ------------------------------------------------------------------
    def observe(self, n: int, time_s: float, energy_j: float,
                ttfc_p95_s: float | None = None) -> None:
        self._obs.append(Observation(n, time_s, energy_j, ttfc_p95_s))
        self._refit()

    def _refit(self) -> None:
        by_n: dict[int, list[Observation]] = defaultdict(list)
        for o in self._obs:
            by_n[o.n].append(o)
        if len(by_n) < 3:        # need 3 distinct counts to fit 3 params
            return
        xs = sorted(by_n)
        t = [sum(o.time_s for o in by_n[n]) / len(by_n[n]) for n in xs]
        e = [sum(o.energy_j for o in by_n[n]) / len(by_n[n]) for n in xs]
        self.time_model = fit_best(xs, t)
        self.energy_model = fit_best(xs, e)
        # the quantile model fits only counts that HAVE tail samples —
        # mean observations without ttfc (wave callers) leave it alone.
        # Per-count aggregation is a TAIL over the window tails, not a
        # mean: bursty traffic puts its violations in a minority of
        # windows, and averaging window p95s with the calm majority
        # would declare an under-provisioned count SLO-feasible
        qx = [n for n in xs
              if any(o.ttfc_p95_s is not None for o in by_n[n])]
        if len(qx) >= 3:
            q = [self._tail_of([o.ttfc_p95_s for o in by_n[n]
                                if o.ttfc_p95_s is not None])
                 for n in qx]
            self.ttfc_model = fit_best(qx, q)

    # ------------------------------------------------------------------
    def pick(self) -> int:
        unvisited = [n for n in self.feasible
                     if not any(o.n == n for o in self._obs)]
        if self.time_model is None or self.energy_model is None:
            # bootstrap: probe extremes then middle
            if unvisited:
                return unvisited[len(unvisited) // 2 if len(unvisited) > 2
                                 else 0]
            return self.feasible[0]
        if self.epsilon > 0 and self._rng.random() < self.epsilon:
            # explore unvisited counts first, then keep RE-sampling
            # visited ones: a window's time/energy depends on the
            # traffic phase the count happened to serve (a count probed
            # only during a burst looks permanently expensive), and
            # per-count means de-bias only if every count keeps
            # accumulating windows across phases
            return self._rng.choice(unvisited or self.feasible)
        return self._argmin()

    # fits worse than this (normalised rmse) fall back to observed means —
    # the paper's convex forms assume a small n range; a pod sweep over
    # n ∈ [1, 256] can be V-shaped and mislead a quadratic's argmin
    RMSE_TRUST = 0.15

    def _observed_mean(self, n: int, metric: str) -> float | None:
        vals = [getattr(o, metric) for o in self._obs if o.n == n
                and getattr(o, metric) is not None]
        return sum(vals) / len(vals) if vals else None

    # per-count aggregate of window-p95 samples: the 80th percentile of
    # the windows — see _refit for why not mean. Not the max either: a
    # count is "feasible" when ≥80% of its windows met the target, so a
    # rare shed-heavy burst window (loss-censored to the cap) does not
    # brand an otherwise-attaining count infeasible forever
    TAIL_FRAC = 0.8

    @classmethod
    def _tail_of(cls, vals: list) -> float:
        s = sorted(vals)
        return s[int(cls.TAIL_FRAC * (len(s) - 1))]

    def _observed_tail(self, n: int) -> float | None:
        vals = [o.ttfc_p95_s for o in self._obs
                if o.n == n and o.ttfc_p95_s is not None]
        return self._tail_of(vals) if vals else None

    def predict_ttfc_p95(self, n: int) -> float | None:
        """Predicted ttfc p95 at count ``n`` — the fitted quantile model
        when it exists and passes the RMSE trust check, the observed
        per-count tail of the window p95 samples otherwise (the "falls
        back to observations" contract the mean models also follow).
        None before any tail sample exists for ``n`` and no trusted fit
        covers it."""
        fitted = None
        if self.ttfc_model is not None:
            q_mean = self._overall_mean("ttfc_p95_s")
            trusted = (q_mean is not None and q_mean > 0
                       and self.ttfc_model.rmse / max(q_mean, 1e-9)
                       < self.RMSE_TRUST)
            fitted = float(self.ttfc_model(n)) if trusted else None
        if fitted is not None:
            return fitted
        return self._observed_tail(n)

    def _overall_mean(self, metric: str) -> float | None:
        vals = [getattr(o, metric) for o in self._obs
                if getattr(o, metric) is not None]
        return sum(vals) / len(vals) if vals else None

    def _argmin(self) -> int:
        t_mean = sum(o.time_s for o in self._obs) / max(len(self._obs), 1)
        e_mean = sum(o.energy_j for o in self._obs) / max(len(self._obs), 1)
        trust = (self.time_model.rmse / max(t_mean, 1e-9) < self.RMSE_TRUST
                 and self.energy_model.rmse / max(e_mean, 1e-9)
                 < self.RMSE_TRUST)

        def predict(n: int) -> tuple[float, float]:
            """(time, energy) for count n — fitted when the fit passed the
            trust check, observed means otherwise (same source everywhere,
            including the deadline-infeasible fallback below)."""
            t = float(self.time_model(n))
            e = float(self.energy_model(n))
            if not trust:  # poor fit: prefer the measured means
                t_obs = self._observed_mean(n, "time_s")
                e_obs = self._observed_mean(n, "energy_j")
                t = t_obs if t_obs is not None else t
                e = e_obs if e_obs is not None else e
            return t, e

        best_n, best_v = None, None
        for n in self.feasible:
            t, e = predict(n)
            if self.objective == "time":
                v = t
            elif self.objective == "energy":
                v = e
            elif self.objective == "energy_under_slo":
                # energy subject to the predicted tail meeting the SLO.
                # Counts with NO tail prediction yet stay candidates —
                # the bootstrap must not deadlock before quantile
                # samples exist
                q = self.predict_ttfc_p95(n)
                if q is not None and q > self.slo_ttfc_p95_s:
                    continue
                v = e
            else:  # energy under deadline
                if self.deadline is not None and t > self.deadline:
                    continue
                v = e
            if best_v is None or v < best_v:
                best_n, best_v = n, v
        if best_n is None:
            if self.objective == "energy_under_slo":
                # SLO infeasible everywhere: minimise the tail itself —
                # the least-bad violation, by the same trusted source
                best_n = min(self.feasible,
                             key=lambda n: self.predict_ttfc_p95(n))
            else:
                # deadline infeasible everywhere: fall back to the
                # fastest count by the SAME trusted source — consulting
                # the fitted model here when the trust check just
                # rejected it would hand an untrusted argmin straight to
                # the caller
                best_n = min(self.feasible, key=lambda n: predict(n)[0])
        return best_n

    def best(self) -> int:
        """Exploitation-only choice: the fitted argmin when models exist,
        else the best observed mean, else the smallest feasible count.
        Unlike ``pick()`` this never explores — it is what a converged
        deployment runs, and what the adaptive pool reports as its answer."""
        if self.time_model is not None and self.energy_model is not None:
            return self._argmin()
        metric = "time_s" if self.objective == "time" else "energy_j"
        means = {n: self._observed_mean(n, metric) for n in self.feasible}
        means = {n: v for n, v in means.items() if v is not None}
        if means:
            return min(means, key=means.get)
        return self.feasible[0]

    # ------------------------------------------------------------------
    def chunk_for(self, cfg, n: int, *, batch: int = 1,
                  context_tokens: int = 0, max_chunk: int = 32) -> int:
        """Decode chunk length co-optimised with the container count:
        start from the roofline amortisation optimum
        (``core/roofline.decode_chunk_tokens``) and, under an SLO, cap
        it so one fused chunk's device time stays under
        ``CHUNK_SLO_FRAC`` of the ttfc budget — a request admitted
        mid-stream waits up to one whole chunk of the slots ahead of it,
        so an over-long chunk converts straight into first-chunk tail.
        ``n`` scales the per-container batch: splitting the same
        in-flight population over more containers shrinks each
        container's decode batch (and with it the optimal chunk)."""
        from repro.core.roofline import (decode_chunk_tokens,
                                         decode_step_seconds)
        per_container = max(1, -(-batch // max(n, 1)))   # ceil div
        base = decode_chunk_tokens(cfg, per_container,
                                   context_tokens=context_tokens,
                                   max_chunk=max_chunk)
        if self.slo_ttfc_p95_s is None:
            return base
        t_tok = decode_step_seconds(cfg, per_container,
                                    context_tokens=context_tokens)
        budget = self.slo_ttfc_p95_s * self.CHUNK_SLO_FRAC
        cap = max(1, int(budget / max(t_tok, 1e-12)))
        return max(1, min(base, cap))

    @property
    def n_observations(self) -> int:
        return len(self._obs)

    def summary(self) -> dict:
        return {
            "feasible": self.feasible,
            "observations": len(self._obs),
            "time_model": (self.time_model.kind, self.time_model.coef)
            if self.time_model else None,
            "energy_model": (self.energy_model.kind, self.energy_model.coef)
            if self.energy_model else None,
            "ttfc_model": (self.ttfc_model.kind, self.ttfc_model.coef)
            if self.ttfc_model else None,
            "slo_ttfc_p95_s": self.slo_ttfc_p95_s,
            "choice": self.pick(),
        }
