"""Divide-and-Save scheduler: choose the container count online.

The paper's concluding proposal ("energy-efficient job schedulers that split
input data, obtaining the optimal number of containers in an online
fashion") implemented:

  * observe (n, time, energy) samples of completed jobs,
  * fit the paper's convex model forms (quadratic / saturating-exp,
    whichever fits better) to each metric,
  * pick argmin of the chosen objective over the *feasible* container
    counts (memory-bounded, cf. core/containers.py), with ε-greedy
    exploration so unvisited counts eventually get sampled.

Works identically for the CPU testbed (samples = measured wall times) and
the TPU pod (samples = roofline-derived step time / energy per
factorisation).
"""
from __future__ import annotations

import dataclasses
import random
from collections import defaultdict
from typing import Literal

from repro.core.energy_model import FittedModel, fit_best

Objective = Literal["energy", "time", "energy_under_deadline"]


@dataclasses.dataclass
class Observation:
    n: int
    time_s: float
    energy_j: float


class DivideAndSaveScheduler:
    def __init__(self, feasible_counts: list[int],
                 objective: Objective = "energy",
                 deadline_s: float | None = None,
                 epsilon: float = 0.1, seed: int = 0):
        if not feasible_counts:
            raise ValueError("no feasible container counts")
        self.feasible = sorted(set(feasible_counts))
        self.objective = objective
        self.deadline = deadline_s
        self.epsilon = epsilon
        self._rng = random.Random(seed)
        self._obs: list[Observation] = []
        self.time_model: FittedModel | None = None
        self.energy_model: FittedModel | None = None

    # ------------------------------------------------------------------
    def observe(self, n: int, time_s: float, energy_j: float) -> None:
        self._obs.append(Observation(n, time_s, energy_j))
        self._refit()

    def _refit(self) -> None:
        by_n: dict[int, list[Observation]] = defaultdict(list)
        for o in self._obs:
            by_n[o.n].append(o)
        if len(by_n) < 3:        # need 3 distinct counts to fit 3 params
            return
        xs = sorted(by_n)
        t = [sum(o.time_s for o in by_n[n]) / len(by_n[n]) for n in xs]
        e = [sum(o.energy_j for o in by_n[n]) / len(by_n[n]) for n in xs]
        self.time_model = fit_best(xs, t)
        self.energy_model = fit_best(xs, e)

    # ------------------------------------------------------------------
    def pick(self) -> int:
        unvisited = [n for n in self.feasible
                     if not any(o.n == n for o in self._obs)]
        if self.time_model is None or self.energy_model is None:
            # bootstrap: probe extremes then middle
            if unvisited:
                return unvisited[len(unvisited) // 2 if len(unvisited) > 2
                                 else 0]
            return self.feasible[0]
        if unvisited and self._rng.random() < self.epsilon:
            return self._rng.choice(unvisited)
        return self._argmin()

    # fits worse than this (normalised rmse) fall back to observed means —
    # the paper's convex forms assume a small n range; a pod sweep over
    # n ∈ [1, 256] can be V-shaped and mislead a quadratic's argmin
    RMSE_TRUST = 0.15

    def _observed_mean(self, n: int, metric: str) -> float | None:
        vals = [getattr(o, metric) for o in self._obs if o.n == n]
        return sum(vals) / len(vals) if vals else None

    def _argmin(self) -> int:
        t_mean = sum(o.time_s for o in self._obs) / max(len(self._obs), 1)
        e_mean = sum(o.energy_j for o in self._obs) / max(len(self._obs), 1)
        trust = (self.time_model.rmse / max(t_mean, 1e-9) < self.RMSE_TRUST
                 and self.energy_model.rmse / max(e_mean, 1e-9)
                 < self.RMSE_TRUST)

        def predict(n: int) -> tuple[float, float]:
            """(time, energy) for count n — fitted when the fit passed the
            trust check, observed means otherwise (same source everywhere,
            including the deadline-infeasible fallback below)."""
            t = float(self.time_model(n))
            e = float(self.energy_model(n))
            if not trust:  # poor fit: prefer the measured means
                t_obs = self._observed_mean(n, "time_s")
                e_obs = self._observed_mean(n, "energy_j")
                t = t_obs if t_obs is not None else t
                e = e_obs if e_obs is not None else e
            return t, e

        best_n, best_v = None, None
        for n in self.feasible:
            t, e = predict(n)
            if self.objective == "time":
                v = t
            elif self.objective == "energy":
                v = e
            else:  # energy under deadline
                if self.deadline is not None and t > self.deadline:
                    continue
                v = e
            if best_v is None or v < best_v:
                best_n, best_v = n, v
        if best_n is None:       # deadline infeasible everywhere: fall back
            # to the fastest count by the SAME trusted source — consulting
            # the fitted model here when the trust check just rejected it
            # would hand an untrusted argmin straight to the caller
            best_n = min(self.feasible, key=lambda n: predict(n)[0])
        return best_n

    def best(self) -> int:
        """Exploitation-only choice: the fitted argmin when models exist,
        else the best observed mean, else the smallest feasible count.
        Unlike ``pick()`` this never explores — it is what a converged
        deployment runs, and what the adaptive pool reports as its answer."""
        if self.time_model is not None and self.energy_model is not None:
            return self._argmin()
        metric = "time_s" if self.objective == "time" else "energy_j"
        means = {n: self._observed_mean(n, metric) for n in self.feasible}
        means = {n: v for n, v in means.items() if v is not None}
        if means:
            return min(means, key=means.get)
        return self.feasible[0]

    # ------------------------------------------------------------------
    @property
    def n_observations(self) -> int:
        return len(self._obs)

    def summary(self) -> dict:
        return {
            "feasible": self.feasible,
            "observations": len(self._obs),
            "time_model": (self.time_model.kind, self.time_model.coef)
            if self.time_model else None,
            "energy_model": (self.energy_model.kind, self.energy_model.coef)
            if self.energy_model else None,
            "choice": self.pick(),
        }
