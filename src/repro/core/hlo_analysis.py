"""While-loop-aware cost extraction from compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count, which under-reports every scanned layer stack by ~L×. This module
re-derives FLOPs / bytes / collective traffic from ``compiled.as_text()``:

  1. split the module into computations,
  2. find every ``while`` op, read its trip count from the integer constant
     in its *condition* computation (scan lowers to ``i < L`` with a literal
     ``L``),
  3. propagate multiplicities entry→body (nested scans multiply),
  4. per computation, parse ops: ``dot`` FLOPs from result × contracting
     dims, bytes as operands+result of non-trivial ops, and collective wire
     bytes from result shape × participant count (from ``replica_groups``).

Shapes in post-SPMD HLO are *per-device*, so every figure this module
returns is per-chip; multiply by chip count for pod totals.

The mult=1 aggregate is asserted (in tests) to be within a small factor of
XLA's own cost_analysis on unscanned graphs — the parser is the scaled
version of the same accounting.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# donation markers in lowered StableHLO: a donated entry argument that XLA
# can alias to an output carries ``tf.aliasing_output = N : i32``; under
# multi-device lowerings where the pairing is deferred to compile time the
# argument is marked ``jax.buffer_donor = true`` instead. A donated operand
# carrying NEITHER is a silent copy — jax only warns (UserWarning), so the
# donation auditor turns the absence into a hard finding.
_ALIASING_RE = re.compile(r"tf\.aliasing_output\s*=\s*(\d+)\s*:\s*i32")
_BUFFER_DONOR_RE = re.compile(r"jax\.buffer_donor\s*=\s*true")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->\s+.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\），?|while\(.*?\)", re.S)
_WHILE_ATTR_RE = re.compile(r"condition=%([\w\.\-]+), body=%([\w\.\-]+)")
_REPL_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_REPL_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# ops that move no data / cost nothing (while/conditional are control flow —
# their bodies are costed separately with the right multiplicity)
_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "constant",
             "bitcast", "after-all", "iota", "partition-id", "replica-id",
             "opt-barrier", "copy-start", "copy-done", "while", "conditional"}


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes appearing in a type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None, []
    dt, dims = m.group(1), m.group(2)
    dims_l = [int(d) for d in dims.split(",") if d] if dims else []
    return dt, dims_l


def _split_operands(s: str) -> list[str]:
    """Split an operand list on top-level commas only — operands may carry
    inline types (``f32[4,32]{1,0} %x``) whose brackets contain commas."""
    out, cur, depth = [], [], 0
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return [o for o in out if o]


@dataclasses.dataclass(frozen=True)
class DonationInfo:
    """Aliasing facts parsed from one lowered (StableHLO) module."""
    aliased_outputs: tuple    # output indices claimed by aliased args
    buffer_donors: int        # args marked jax.buffer_donor (multi-device)

    @property
    def n_aliased(self) -> int:
        """Arguments that will actually reuse their buffer — either
        aliased to a concrete output now or marked as a donor for the
        compiler to pair up later."""
        return len(self.aliased_outputs) + self.buffer_donors


def parse_donation(stablehlo_text: str) -> DonationInfo:
    """Extract donation/aliasing markers from ``lowered.as_text()``.

    Every donated argument jax could use appears exactly once: as
    ``tf.aliasing_output`` on single-device lowerings, or as
    ``jax.buffer_donor`` when the alias pairing is left to compile time
    (sharded lowerings). Donated arguments that appear as neither were
    dropped — XLA will silently copy them.
    """
    return DonationInfo(
        tuple(int(m) for m in _ALIASING_RE.findall(stablehlo_text)),
        len(_BUFFER_DONOR_RE.findall(stablehlo_text)))


@dataclasses.dataclass
class Collective:
    kind: str
    result_bytes: int
    participants: int
    mult: int = 1

    @property
    def wire_bytes_per_chip(self) -> float:
        """Bytes crossing each chip's links (ring algorithms)."""
        p = max(self.participants, 1)
        r = self.result_bytes
        if self.kind == "all-gather":
            return r * (p - 1) / p
        if self.kind == "all-reduce":
            return 2.0 * r * (p - 1) / p
        if self.kind == "reduce-scatter":
            return r * (p - 1)          # result is the scattered shard
        if self.kind == "all-to-all":
            return r * (p - 1) / p
        return float(r)                  # collective-permute


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool = False
    flops: float = 0.0
    bytes_accessed: float = 0.0
    bytes_slices: float = 0.0   # dynamic-(update-)slice traffic only
    collectives: list = dataclasses.field(default_factory=list)
    whiles: list = dataclasses.field(default_factory=list)  # (cond, body)
    max_s32_const: int = 0
    calls: list = dataclasses.field(default_factory=list)


def _split_computations(text: str) -> list[tuple[str, bool, list[str]]]:
    comps, cur, name, entry = [], None, None, False
    for line in text.splitlines():
        m = _COMP_HDR_RE.match(line)
        if m and cur is None:
            name, entry, cur = m.group(2), bool(m.group(1)), []
            continue
        if cur is not None:
            if line.strip() == "}":
                comps.append((name, entry, cur))
                cur = None
            else:
                cur.append(line)
    return comps


def _parse_computation(name: str, entry: bool, lines: list[str]) -> Computation:
    comp = Computation(name, entry)
    symtab: dict[str, str] = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        res_name, rhs = m.group(1), m.group(2)
        # result type is everything before the opcode
        symtab[res_name] = rhs
        const_m = _CONST_RE.search(line)
        if const_m:
            comp.max_s32_const = max(comp.max_s32_const, int(const_m.group(1)))

        # opcode = first lowercase identifier directly followed by "(" that
        # is not part of the (possibly tuple) result type
        op_m = re.search(r"(?:^|[\s\)\}])([a-z][a-z0-9\-]*)\(", rhs)
        opcode = op_m.group(1) if op_m else ""

        # ---- while (before the free-ops skip: trip counts must register)
        wm = _WHILE_ATTR_RE.search(rhs)
        if opcode == "while" and wm:
            comp.whiles.append((wm.group(1), wm.group(2)))
            continue

        if opcode in _FREE_OPS or not opcode:
            continue

        # ---- fusion / call references
        cm = re.search(r"calls=%([\w\.\-]+)", rhs)
        if cm:
            comp.calls.append(cm.group(1))

        # ---- bytes: WRITE-counting model. Every op's result (one write)
        # plus operand reads only at matmul / custom-call boundaries —
        # elementwise consumers fuse with their producers on TPU, so their
        # reads are the producers' writes, already counted. This mirrors
        # XLA:TPU fusion behaviour; counting operands of every op double-
        # counts each buffer once per consumer.
        type_str = rhs[:rhs.find(opcode)] if opcode in rhs else rhs
        res_bytes = _shape_bytes(type_str)

        def _operand_type(tok: str) -> str:
            """Type string of one operand token: inline if present (newer
            XLA prints ``f32[...]{...} %name``), else the defining line."""
            if _SHAPE_RE.search(tok.split("%")[0]):
                return tok.split("%")[0]
            nm = re.search(r"%?([\w\.\-]+)", tok)
            t = symtab.get(nm.group(1), "") if nm else ""
            return t[:t.find("(")] if "(" in t else t

        oper_m = re.search(re.escape(opcode) + r"\(([^)]*)\)", rhs)
        operands = _split_operands(oper_m.group(1)) if oper_m else []
        operand_sizes = [_shape_bytes(t)
                         for t in map(_operand_type, operands) if t]
        # dynamic-update-slice writes ONE slice into an aliased buffer (XLA
        # updates in place): drop the buffer-sized operand and the full-size
        # result, keep 2× the update slice. dynamic-slice likewise reads a
        # slice, not the whole buffer. Fusion names carry their root op.
        if "dynamic-update-slice" in res_name or \
                opcode == "dynamic-update-slice":
            upd = sum(s for s in operand_sizes if s != res_bytes)
            comp.bytes_accessed += 2 * upd
            comp.bytes_slices += 2 * upd
        elif "dynamic-slice" in res_name or opcode == "dynamic-slice":
            comp.bytes_accessed += 2 * res_bytes
            comp.bytes_slices += 2 * res_bytes
        else:
            reads = (sum(operand_sizes)
                     if opcode in ("dot", "convolution", "custom-call")
                     else 0)
            comp.bytes_accessed += res_bytes + reads

        # ---- collectives
        kind = next((k for k in COLLECTIVE_KINDS
                     if opcode == k or opcode == k + "-start"), None)
        if kind:
            participants = 1
            rg = _REPL_GROUPS_RE.search(rhs)
            if rg:
                participants = int(rg.group(2))
            else:
                rgb = _REPL_GROUPS_BRACE_RE.search(rhs)
                if rgb:
                    participants = len([x for x in rgb.group(1).split(",") if x.strip()])
            comp.collectives.append(
                Collective(kind, res_bytes, participants))
            continue

        # ---- reduce FLOPs (matvecs lower to fused multiply+reduce on CPU;
        # 2×input-elements ≈ the multiply-add count)
        if opcode == "reduce":
            if operands:
                _, in_dims = _first_shape(_operand_type(operands[0]))
                n = 1
                for d in in_dims:
                    n *= d
                comp.flops += 2.0 * n
        # ---- dot FLOPs
        if opcode == "dot":
            dt, res_dims = _first_shape(type_str)
            k = 1
            cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
            if cd and operands:
                _, lhs_dims = _first_shape(_operand_type(operands[0]))
                for di in cd.group(1).split(","):
                    if di and int(di) < len(lhs_dims):
                        k *= lhs_dims[int(di)]
            n = 1
            for d in res_dims:
                n *= d
            comp.flops += 2.0 * n * k
        elif opcode == "convolution":
            # rough: 2 * output elems * kernel elems (per output channel)
            dt, res_dims = _first_shape(type_str)
            n = 1
            for d in res_dims:
                n *= d
            comp.flops += 2.0 * n  # minor term in our models
    return comp


@dataclasses.dataclass
class HloCost:
    flops_per_chip: float
    bytes_per_chip: float
    coll_wire_bytes_per_chip: float
    collectives: dict  # kind -> wire bytes per chip (mult-scaled)
    trip_counts: dict  # body computation -> mult applied


def analyze_hlo(text: str, default_trip: int = 1) -> HloCost:
    comps = {c.name: c
             for (n, e, ls) in _split_computations(text)
             for c in [_parse_computation(n, e, ls)]}
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # propagate multiplicities through while nesting and fusion calls
    mult: dict[str, float] = defaultdict(float)
    mult[entry.name] = 1.0
    order = [entry.name]
    seen = {entry.name}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for cond_name, body_name in comp.whiles:
            cond = comps.get(cond_name)
            trip = (cond.max_s32_const if cond and cond.max_s32_const > 0
                    else default_trip)
            mult[body_name] += m * trip
            if body_name not in seen:
                seen.add(body_name)
                order.append(body_name)
        # fusion bodies (comp.calls): bytes at call site; flops added below
    # computations reachable only via whiles get their mult; others 0 (their
    # cost is attributed at the call site for fusions)
    # innermost while bodies with no collectives model one fused (Pallas)
    # kernel invocation: interior tiles live in VMEM, so HBM traffic is just
    # the dynamic-slice reads of the tile inputs + the DUS tile writes —
    # exactly the BlockSpec traffic of the kernels in src/repro/kernels.
    while_bodies = {b for c in comps.values() for (_, b) in c.whiles}

    flops = bytes_ = wire = 0.0
    coll_by_kind: dict[str, float] = defaultdict(float)
    trip_counts = {}
    for cname, m in mult.items():
        comp = comps.get(cname)
        if comp is None or m <= 0:
            continue
        trip_counts[cname] = m
        # fusion callees: their interior dot/reduce FLOPs are real work at
        # the call site (bytes are not — the interiors are fused)
        call_flops = sum(comps[c2].flops for c2 in comp.calls
                         if c2 in comps)
        flops += (comp.flops + call_flops) * m
        # (bodies with a collective still qualify — the collective cost is
        # carried by the collective term, not the memory term)
        fused_kernel = cname in while_bodies and not comp.whiles
        bytes_ += (comp.bytes_slices if fused_kernel
                   else comp.bytes_accessed) * m
        for col in comp.collectives:
            w = col.wire_bytes_per_chip * m
            wire += w
            coll_by_kind[col.kind] += w
    return HloCost(flops, bytes_, wire, dict(coll_by_kind), trip_counts)
