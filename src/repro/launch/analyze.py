import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# (device-count override before jax import — same as dryrun.py)

"""Perf-iteration profiler: lower one (arch, shape, mesh), print the
roofline terms and the TOP collectives / byte-heavy computations — the
"profile" used by the §Perf hypothesis→change→measure loop.

    PYTHONPATH=src python -m repro.launch.analyze --arch X --shape Y
"""

import argparse

import jax

from repro.compat import set_mesh
from repro.configs.registry import get_config, get_shape
from repro.core.hlo_analysis import (_parse_computation, _split_computations,
                                     analyze_hlo)
from repro.core.roofline import build_report
from repro.launch.dryrun import FSDP_INFERENCE_THRESHOLD, _shardings_for
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import ShardingRules
from repro.launch.specs import PARAM_DTYPE, lowering_args
from repro.models.model import Model
from repro.train.loop import TrainConfig


def lower_text(arch, shape_name, multi_pod=False, microbatches=1,
               remat=True, overrides=None, remat_policy="none"):
    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    step, args = lowering_args(model, shape,
                               TrainConfig(remat=remat,
                                           remat_policy=remat_policy,
                                           microbatches=microbatches))
    weight_bytes = cfg.param_count() * PARAM_DTYPE.dtype.itemsize
    model_axis = dict(mesh.shape)["model"]
    fsdp = (shape.kind == "train"
            or weight_bytes / model_axis > FSDP_INFERENCE_THRESHOLD)
    rules = ShardingRules(mesh, train=(shape.kind == "train"), fsdp=fsdp,
                          decode=(shape.kind == "decode"))
    in_sh = _shardings_for(rules, shape.kind, args)
    with set_mesh(mesh):
        compiled = jax.jit(step, in_shardings=in_sh).lower(*args).compile()
        mem = compiled.memory_analysis()
        txt = compiled.as_text()
    return cfg, shape, mesh, txt, mem


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--top", type=int, default=12)
    ap.add_argument("--remat-policy", default="none")
    ap.add_argument("--dump", default=None, help="write HLO text here")
    ap.add_argument("--set", action="append", default=[],
                    help="config override, e.g. --set moe_dispatch_groups=16")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = type(getattr(get_config(args.arch), k))(
            float(v) if "." in v else int(v)) \
            if not isinstance(getattr(get_config(args.arch), k), str) else v

    cfg, shape, mesh, txt, mem = lower_text(
        args.arch, args.shape, args.multipod, args.microbatches,
        overrides=overrides, remat_policy=args.remat_policy)
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(txt)
    cost = analyze_hlo(txt)
    rep = build_report(args.arch, shape, cfg, "pod", mesh.devices.size, cost)
    print(f"== {args.arch} × {args.shape}  (microbatches="
          f"{args.microbatches})")
    print(f"t_compute {rep.t_compute*1e3:10.2f} ms")
    print(f"t_memory  {rep.t_memory*1e3:10.2f} ms")
    print(f"t_coll    {rep.t_collective*1e3:10.2f} ms   <- dominant: "
          f"{rep.dominant}")
    print(f"useful_ratio {rep.useful_ratio:.3f}   "
          f"HBM temp {getattr(mem, 'temp_size_in_bytes', 0)/1e9:.1f} GB")
    print(f"collectives by kind: "
          f"{ {k: f'{v:.2e}' for k, v in cost.collectives.items()} }")

    comps = {c.name: c for (n, e, ls) in _split_computations(txt)
             for c in [_parse_computation(n, e, ls)]}
    rows = []
    for name, c in comps.items():
        m = cost.trip_counts.get(name, 0)
        for col in c.collectives:
            rows.append((col.wire_bytes_per_chip * m, col.kind,
                         col.result_bytes, col.participants, m, name[:48]))
    rows.sort(reverse=True)
    print(f"\ntop {args.top} collectives (wire bytes/chip × trips):")
    for r in rows[:args.top]:
        print(f"  {r[0]:.3e}  {r[1]:<18s} res={r[2]:.2e} p={r[3]:4d} "
              f"mult={r[4]:6.0f}  {r[5]}")

    brows = []
    wb = {b for c in comps.values() for (_, b) in c.whiles}
    for name, c in comps.items():
        m = cost.trip_counts.get(name, 0)
        if m <= 0:
            continue
        fused = name in wb and not c.whiles
        b = (c.bytes_slices if fused else c.bytes_accessed) * m
        brows.append((b, m, fused, name[:48]))
    brows.sort(reverse=True)
    print(f"\ntop byte-heavy computations:")
    for b, m, f, n in brows[:args.top]:
        print(f"  {b:.3e}  mult={m:6.0f} fused={f}  {n}")


if __name__ == "__main__":
    main()
