"""Production meshes. Functions only — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods,
    (pod=2, data=16, model=16) — the pod axis is pure data parallelism
    across the inter-pod (DCN-ish) boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_container_mesh(total_chips: int, n_containers: int):
    """The paper's factorisation as ONE joint mesh: n containers ×
    (chips/n) model shards. The "data" axis is the container axis (weights
    replicated across it) — the logical view for dry-runs/rooflines."""
    assert total_chips % n_containers == 0
    return make_mesh(
        (n_containers, total_chips // n_containers), ("data", "model"))


def make_container_meshes(total_chips: int, n_containers: int,
                          devices=None):
    """The paper's factorisation as n PHYSICAL meshes: one
    ``(data=1, model=chips/n)`` mesh per container, each over a disjoint
    contiguous slice of the pod's device list. Engines committed to these
    meshes occupy pairwise-disjoint device sets (serving/engine.py), so a
    concurrent pool overlaps real parallel hardware. On CPU CI, export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to fake a pod."""
    from repro.core.containers import ContainerSpec, container_meshes
    # divisibility is enforced by partition_indices inside container_meshes
    spec = ContainerSpec(n_containers, total_chips // n_containers,
                         total_chips)
    return container_meshes(spec, devices)


def mesh_axis_size(mesh, name: str) -> int:
    """Axis size by name (1 if absent). Works for Mesh and AbstractMesh."""
    return dict(mesh.shape).get(name, 1)
