"""Production meshes. Functions only — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods,
    (pod=2, data=16, model=16) — the pod axis is pure data parallelism
    across the inter-pod (DCN-ish) boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_container_mesh(total_chips: int, n_containers: int):
    """The paper's factorisation: n containers × (chips/n) model shards.
    The "data" axis is the container axis (weights replicated across it)."""
    assert total_chips % n_containers == 0
    return make_mesh(
        (n_containers, total_chips // n_containers), ("data", "model"))


def mesh_axis_size(mesh, name: str) -> int:
    """Axis size by name (1 if absent). Works for Mesh and AbstractMesh."""
    return dict(mesh.shape).get(name, 1)
