"""Serving launcher: concurrent container-pool serving of a synthetic
request stream, with the online divide-and-save scheduler.

Fixed count: one concurrent pool. ``--containers 0`` (default) runs the
adaptive loop — waves of traffic, each served at the scheduler's current
pick within the memory-feasible counts, each observation refining the
fitted time/energy models. ``--submesh`` makes the containers physical on
the *device* axis: each engine is committed to a disjoint slice of the
host's jax devices (fake a pod on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
``--isolation process`` makes them physical on the *CPU* axis instead —
one OS process per container pinned to a disjoint core set before jax
initialises (the paper's ``docker run --cpus=C/n``, see
serving/process_pool.py); ``--total-cores`` bounds the carve-up.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --containers 4 --requests 16
    PYTHONPATH=src python -m repro.launch.serve --waves 8 --objective time
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve --containers 2 --submesh
    PYTHONPATH=src python -m repro.launch.serve --containers 2 \
        --isolation process --total-cores 2
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import ARCH_NAMES, get_config
from repro.core.containers import feasible_counts
from repro.core.testbed import available_cores
from repro.launch.mesh import make_container_meshes
from repro.models.model import Model
from repro.serving import (AdaptiveServingPool, ContainerServingPool,
                           ProcessContainerPool, Request)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_NAMES)
    ap.add_argument("--containers", type=int, default=0,
                    help="0 = let the scheduler choose online")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--waves", type=int, default=6,
                    help="traffic waves in adaptive mode")
    ap.add_argument("--objective", default="energy",
                    choices=("energy", "time"))
    ap.add_argument("--sequential", action="store_true",
                    help="disable container concurrency (baseline)")
    ap.add_argument("--units", type=int, default=8,
                    help="resource units to factorise (cores / chips)")
    ap.add_argument("--submesh", action="store_true",
                    help="place each container on a disjoint sub-mesh of "
                         "the host's jax devices (see XLA_FLAGS above)")
    ap.add_argument("--isolation", default="thread",
                    choices=("thread", "process"),
                    help="thread: engines overlap in this process "
                         "(baseline); process: one pinned OS process per "
                         "container — the paper's --cpus shares")
    ap.add_argument("--total-cores", type=int, default=None,
                    help="CPU cores to carve among process containers "
                         "(default: all cores this process may use)")
    args = ap.parse_args()
    if args.isolation == "process" and args.submesh:
        ap.error("--submesh needs one process owning all devices; pick "
                 "either --submesh or --isolation process")

    cfg = get_config(args.arch + "-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    units = args.units
    if args.isolation == "process":
        # factorise cores that actually exist: the process pool carves
        # REAL cpusets, so the unit budget is the core budget
        avail = len(available_cores())
        units = min(units, args.total_cores or avail, avail)
        print(f"process isolation over {units} cores")
    if args.submesh:
        # factorise devices that actually exist: largest power of two the
        # pod (or the CPU device-count override) provides, clamped by an
        # explicit --units so a smaller requested factorisation is honoured
        units = 1 << (min(args.units, jax.device_count()).bit_length() - 1)
        print(f"submesh placement over {units} of {jax.device_count()} "
              f"devices")

    def batch_of_requests(base):
        return [Request(rid=base + i,
                        prompt=rng.integers(0, cfg.vocab_size, (8,),
                                            dtype=np.int32),
                        max_new_tokens=args.max_new)
                for i in range(args.requests)]

    if args.containers:
        meshes = None
        if args.isolation == "process":
            pool = ProcessContainerPool(cfg, args.containers,
                                        n_slots_per_container=args.slots,
                                        total_cores=units, params_seed=0)
        else:
            meshes = (make_container_meshes(units, args.containers)
                      if args.submesh else None)
            pool = ContainerServingPool(model, params, args.containers,
                                        n_slots_per_container=args.slots,
                                        concurrent=not args.sequential,
                                        meshes=meshes)
        done, per, wall, energy = pool.serve_timed(batch_of_requests(0))
        toks = sum(len(c.tokens) for c in done)
        mode = (args.isolation if args.isolation == "process" else
                ("sequential" if args.sequential else "concurrent"))
        print(f"n={args.containers} ({mode}): {len(done)} requests, "
              f"{toks} tokens in {wall:.2f}s ({toks/wall:.1f} tok/s, "
              f"~{energy:.1f}J)")
        for r in per:
            devs = ""
            if meshes is not None:
                ids = sorted(d.id for d in meshes[r.container_id].devices.flat)
                devs = f" devices {ids}"
            if args.isolation == "process":
                cores = pool.reported_core_sets[r.container_id]
                devs = f" cores {sorted(cores)}"
            print(f"  container {r.container_id}: {r.n_requests} reqs "
                  f"wall {r.wall_s:.2f}s busy {r.busy_s:.2f}s "
                  f"{r.tokens_per_s:.1f} tok/s ~{r.energy_j:.1f}J "
                  f"p50 {r.latency_p50_s:.3f}s p95 {r.latency_p95_s:.3f}s"
                  f"{devs}")
        if args.isolation == "process":
            pool.close()
        return

    # online mode: the scheduler probes container counts across waves,
    # bounded by the memory-feasible factorisations of the host
    feasible = feasible_counts(cfg, units) or [1]
    apool = AdaptiveServingPool(model, params, feasible,
                                objective=args.objective, epsilon=0.2,
                                n_slots_per_container=args.slots,
                                concurrent=not args.sequential,
                                submesh_devices=units if args.submesh
                                else None,
                                isolation=args.isolation,
                                total_cores=units if args.isolation ==
                                "process" else None)
    for wave in range(args.waves):
        apool.serve_wave(batch_of_requests(wave * args.requests))
        w = apool.history[-1]
        print(f"wave {w.wave}: n={w.n_containers} wall {w.wall_s:.2f}s "
              f"{w.tokens_per_s:.1f} tok/s energy {w.energy_j:.1f}J "
              f"p50 {w.latency_p50_s:.3f}s p95 {w.latency_p95_s:.3f}s")
    print(f"feasible counts: {feasible}")
    print(f"converged choice: n={apool.choice}")
    print("scheduler summary:", apool.scheduler.summary())
    apool.close()


if __name__ == "__main__":
    main()
