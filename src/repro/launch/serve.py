"""Serving launcher: container-pool serving of a synthetic request stream.

The pod analogue runs one ServingEngine per container sub-mesh; on this CPU
host the pool shares the device but keeps the same splitting semantics.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --containers 4 --requests 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_NAMES, get_config
from repro.core.scheduler import DivideAndSaveScheduler
from repro.models.model import Model
from repro.serving.engine import Request
from repro.serving.pool import ContainerServingPool


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_NAMES)
    ap.add_argument("--containers", type=int, default=0,
                    help="0 = let the scheduler choose online")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config(args.arch + "-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def batch_of_requests(base):
        return [Request(rid=base + i,
                        prompt=rng.integers(0, cfg.vocab_size, (8,),
                                            dtype=np.int32),
                        max_new_tokens=args.max_new)
                for i in range(args.requests)]

    if args.containers:
        pool = ContainerServingPool(model, params, args.containers,
                                    n_slots_per_container=args.slots)
        t0 = time.time()
        done, per = pool.serve(batch_of_requests(0))
        dt = time.time() - t0
        toks = sum(len(c.tokens) for c in done)
        print(f"n={args.containers}: {len(done)} requests, {toks} tokens "
              f"in {dt:.2f}s ({toks/dt:.1f} tok/s)")
        return

    # online mode: the scheduler probes container counts across job batches
    feasible = [1, 2, 4]
    sched = DivideAndSaveScheduler(feasible, objective="energy", epsilon=0.2)
    for job in range(6):
        n = sched.pick()
        pool = ContainerServingPool(model, params, n,
                                    n_slots_per_container=args.slots)
        t0 = time.time()
        done, _ = pool.serve(batch_of_requests(job * args.requests))
        dt = time.time() - t0
        energy = dt * (40.0 + 3.5 * min(8, n * 2))   # activity model
        sched.observe(n, dt, energy)
        print(f"job {job}: n={n} wall {dt:.2f}s energy {energy:.1f}J")
    print("scheduler summary:", sched.summary())


if __name__ == "__main__":
    main()
