"""Serving launcher: request-level streaming Router over containers,
with the online divide-and-save scheduler.

The serving surface is the ``Router`` (serving/router.py): requests are
admitted one at a time (least-loaded + bucket-aware dispatch across the
containers), completions stream back as typed per-chunk events, and —
when the container count is left to the scheduler — the
``DivideAndSaveScheduler`` observes sliding windows of (time, energy,
tokens/s, time-to-first-chunk) stats and resizes the container count
between windows. ``--no-stream`` serves the same traffic through the
legacy wave shim (``serve_wave`` / the pool facades) instead.

Container isolation is picked exactly as before: the default is a
``ThreadBackend`` (engines overlap in this process); ``--submesh``
places each container on a disjoint slice of the host's jax devices
(fake a pod on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``);
``--isolation process`` runs one OS process per container pinned to a
disjoint core set before jax initialises (the paper's
``docker run --cpus=C/n``).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --containers 4 --requests 16 --stream
    PYTHONPATH=src python -m repro.launch.serve --waves 8 --objective time
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve --containers 2 --submesh
    PYTHONPATH=src python -m repro.launch.serve --containers 2 \
        --isolation process --total-cores 2 --stream
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import ARCH_NAMES, get_config
from repro.core.containers import feasible_counts
from repro.core.testbed import available_cores
from repro.launch.mesh import make_container_meshes
from repro.models.model import Model
from repro.serving import ChunkEvent, EngineConfig, Request, Router
from repro.serving.adaptive import AdaptiveServingPool
from repro.serving.backend import (ProcessBackend, SubmeshBackend,
                                   ThreadBackend)
from repro.serving.pool import ContainerServingPool
from repro.serving.process_pool import ProcessContainerPool
from repro.workload.replay import replay
from repro.workload.slo import SLOClass, SLOSpec
from repro.workload.traces import PRESETS, load_or_synthesize


def _engine_config(args) -> EngineConfig:
    """The per-container engine configuration the flags describe — one
    frozen EngineConfig threaded through every backend flavour."""
    return EngineConfig(n_slots=args.slots, cache=args.cache,
                        block_size=args.block_size,
                        max_blocks=args.max_blocks,
                        prefix_cache=args.prefix_cache)


def _make_backend(args, cfg, model, params, n, units):
    """One container backend per isolation flavour — the Router is
    agnostic, so all the flag handling collapses here."""
    engine_cfg = _engine_config(args)
    if args.isolation == "process":
        return ProcessBackend(cfg, n, total_cores=units, params_seed=0,
                              config=engine_cfg,
                              max_respawns=args.max_respawns)
    if args.submesh:
        return SubmeshBackend(model, params, n,
                              meshes=make_container_meshes(units, n),
                              concurrent=not args.sequential,
                              config=engine_cfg,
                              max_respawns=args.max_respawns)
    return ThreadBackend(model, params, n,
                         concurrent=not args.sequential,
                         config=engine_cfg,
                         max_respawns=args.max_respawns)


def _router_fault_kw(args) -> dict:
    """The Router's fault-tolerance knobs from the serving flags."""
    return dict(max_retries=args.max_retries,
                request_deadline_s=args.deadline_s,
                max_queue=args.max_queue,
                shed_p95_s=args.shed_p95_s)


def _stream_requests(router: Router, requests, verbose_chunks: bool):
    """Continuous admission: submit everything, then consume the streams,
    printing chunk arrivals as they land."""
    handles = [router.submit(r) for r in requests]
    for h in handles:
        parts = []
        for ev in h.stream():
            if isinstance(ev, ChunkEvent):
                parts.append(list(ev.tokens))
        if verbose_chunks:
            chunks = " | ".join(" ".join(map(str, p)) for p in parts)
            ttfc = (f"{h.ttfc_s * 1e3:6.1f}ms" if h.ttfc_s is not None
                    else "   n/a")        # zero-budget: DoneEvent only
            print(f"  rid {h.rid} [container {h.container_id}] "
                  f"ttfc {ttfc}  chunks: {chunks}")
    return handles


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_NAMES)
    ap.add_argument("--containers", type=int, default=0,
                    help="0 = let the scheduler choose online")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--cache", default="dense", choices=("dense", "paged"),
                    help="KV cache layout: dense n_slots rows (baseline) "
                         "or the paged block cache (in-flight bounded by "
                         "the block budget, not --slots)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block (paged cache)")
    ap.add_argument("--max-blocks", type=int, default=None,
                    help="physical KV blocks per container (paged; "
                         "default: the dense footprint "
                         "slots*max_len/block_size)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="copy-on-write prefix sharing in the paged "
                         "cache: requests whose leading prompt blocks "
                         "hash-match cached blocks skip that much "
                         "prefill (requires --cache paged; no-op for "
                         "architectures the sharing gate excludes)")
    ap.add_argument("--prefix-cached-blocks", type=int, default=0,
                    help="resident prefix-cache working set budgeted "
                         "on top of the kv pool when sizing feasible "
                         "container counts (online mode)")
    ap.add_argument("--waves", type=int, default=6,
                    help="traffic waves (adaptive: scheduler windows)")
    ap.add_argument("--objective", default="energy",
                    choices=("energy", "time"))
    ap.add_argument("--stream", action="store_true", default=True,
                    help="request-level streaming via the Router "
                         "(default)")
    ap.add_argument("--no-stream", dest="stream", action="store_false",
                    help="serve through the legacy wave shim instead")
    ap.add_argument("--print-chunks", action="store_true",
                    help="print every request's chunk arrivals")
    ap.add_argument("--sequential", action="store_true",
                    help="disable container concurrency (baseline)")
    ap.add_argument("--units", type=int, default=8,
                    help="resource units to factorise (cores / chips)")
    ap.add_argument("--submesh", action="store_true",
                    help="place each container on a disjoint sub-mesh of "
                         "the host's jax devices (see XLA_FLAGS above)")
    ap.add_argument("--isolation", default="thread",
                    choices=("thread", "process"),
                    help="thread: engines overlap in this process "
                         "(baseline); process: one pinned OS process per "
                         "container — the paper's --cpus shares")
    ap.add_argument("--total-cores", type=int, default=None,
                    help="CPU cores to carve among process containers "
                         "(default: all cores this process may use)")
    ap.add_argument("--max-retries", type=int, default=1,
                    help="re-dispatches per request after a container "
                         "failure before it fails typed")
    ap.add_argument("--max-respawns", type=int, default=2,
                    help="automatic container respawns before the "
                         "circuit breaker leaves it dead")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline (seconds, end-to-end "
                         "across retries; default none)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission bound: shed new requests once this "
                         "many are in flight (default unbounded)")
    ap.add_argument("--shed-p95-s", type=float, default=None,
                    help="shed new requests while the recent "
                         "time-to-first-chunk p95 exceeds this "
                         "(seconds; default never)")
    ap.add_argument("--trace", default=None,
                    help="replay a workload trace open-loop instead of "
                         "synthetic waves: a preset name "
                         f"({', '.join(sorted(PRESETS))}) or a trace "
                         "JSONL path")
    ap.add_argument("--trace-seed", type=int, default=0,
                    help="synthesis seed for a preset --trace")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="compress trace time (10 = a 600s trace "
                         "replays in 60s; arrival pattern preserved, "
                         "absolute rates scaled)")
    ap.add_argument("--slo-ttfc-p95", type=float, default=None,
                    help="single-class SLO: time-to-first-chunk p95 "
                         "target in seconds; switches the scheduler to "
                         "the energy_under_slo objective")
    ap.add_argument("--priority-classes", default=None,
                    help="multi-class SLO spec 'interactive:0.5,"
                         "batch:4.0[:queue_frac]' — rank follows the "
                         "listed order; implies energy_under_slo")
    ap.add_argument("--tenant-quota", type=int, default=None,
                    help="max in-flight requests per tenant (SLO mode)")
    args = ap.parse_args()
    if args.isolation == "process" and args.submesh:
        ap.error("--submesh needs one process owning all devices; pick "
                 "either --submesh or --isolation process")

    cfg = get_config(args.arch + "-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    units = args.units
    if args.isolation == "process":
        # factorise cores that actually exist: the process pool carves
        # REAL cpusets, so the unit budget is the core budget
        avail = len(available_cores())
        units = min(units, args.total_cores or avail, avail)
        print(f"process isolation over {units} cores")
    if args.submesh:
        # factorise devices that actually exist: largest power of two the
        # pod (or the CPU device-count override) provides, clamped by an
        # explicit --units so a smaller requested factorisation is honoured
        units = 1 << (min(args.units, jax.device_count()).bit_length() - 1)
        print(f"submesh placement over {units} of {jax.device_count()} "
              f"devices")

    # SLO vocabulary from the flags: a multi-class spec wins; a bare
    # p95 target becomes a single-class spec. Either switches the
    # scheduler objective to energy_under_slo (the Router derives the
    # binding constraint from the spec itself).
    slo = None
    if args.priority_classes:
        slo = SLOSpec.parse(args.priority_classes)
    elif args.slo_ttfc_p95 is not None:
        slo = SLOSpec((SLOClass(ttfc_p95_s=args.slo_ttfc_p95),))

    if args.trace is not None:
        _serve_trace(args, cfg, model, params, units, slo)
        return

    def batch_of_requests(base):
        return [Request(rid=base + i,
                        prompt=rng.integers(0, cfg.vocab_size, (8,),
                                            dtype=np.int32),
                        max_new_tokens=args.max_new)
                for i in range(args.requests)]

    if args.containers:
        n = args.containers
        meshes = None
        if args.stream:
            backend = _make_backend(args, cfg, model, params, n, units)
            meshes = getattr(backend, "meshes", None)
            with Router(backend, **_router_fault_kw(args)) as router:
                handles = _stream_requests(router, batch_of_requests(0),
                                           args.print_chunks)
                # a second pass through the wave shim for the aggregate
                # accounting line (warm engines — no recompiles)
                done, per, wall, energy = router.serve_wave(
                    batch_of_requests(len(handles)))
                ttfc = sorted(h.ttfc_s for h in handles
                              if h.ttfc_s is not None)
                if ttfc:
                    print(f"streamed {len(handles)} requests: ttfc p50 "
                          f"{ttfc[len(ttfc) // 2] * 1e3:.1f}ms  max "
                          f"{ttfc[-1] * 1e3:.1f}ms")
                _print_wave(args, n, done, per, wall, energy, meshes,
                            router.backend)
            return
        backend = _make_backend(args, cfg, model, params, n, units)
        meshes = getattr(backend, "meshes", None)
        if args.isolation == "process":
            pool = ProcessContainerPool(cfg, n, backend=backend)
        else:
            pool = ContainerServingPool(model, params, n, backend=backend)
        done, per, wall, energy = pool.serve_timed(batch_of_requests(0))
        _print_wave(args, n, done, per, wall, energy, meshes,
                    getattr(pool, "backend", None))
        if args.isolation == "process":
            pool.close()
        return

    # online mode: the scheduler probes container counts, bounded by the
    # memory-feasible factorisations of the host; a paged engine budgets
    # its block pool too (the block-granular memory model), so the
    # scheduler searches the frontier the engine actually allocates
    engine_cfg = _engine_config(args)
    kv_kw = ({"kv_blocks": engine_cfg.resolved_max_blocks,
              "block_size": engine_cfg.block_size,
              "prefix_cached_blocks": args.prefix_cached_blocks}
             if args.cache == "paged" else {})
    feasible = feasible_counts(cfg, units, **kv_kw) or [1]
    if args.stream:
        # windowed adaptation: no explicit waves — requests stream in,
        # the scheduler observes each window and resizes between windows
        router = Router(
            backend_factory=lambda n: _make_backend(args, cfg, model,
                                                    params, n, units),
            feasible_counts=feasible, objective=args.objective,
            epsilon=0.2, window=args.requests, **_router_fault_kw(args))
        for wave in range(args.waves):
            _stream_requests(router, batch_of_requests(
                wave * args.requests), args.print_chunks)
        _print_windows(router.history)
        print(f"feasible counts: {feasible}")
        print(f"converged choice: n={router.choice}")
        print("scheduler summary:", router.scheduler.summary())
        router.close()
        return
    apool = AdaptiveServingPool(model, params, feasible,
                                objective=args.objective, epsilon=0.2,
                                n_slots_per_container=args.slots,
                                concurrent=not args.sequential,
                                submesh_devices=units if args.submesh
                                else None,
                                isolation=args.isolation,
                                total_cores=units if args.isolation ==
                                "process" else None)
    for wave in range(args.waves):
        apool.serve_wave(batch_of_requests(wave * args.requests))
        w = apool.history[-1]
        print(f"wave {w.wave}: n={w.n_containers} wall {w.wall_s:.2f}s "
              f"{w.tokens_per_s:.1f} tok/s energy {w.energy_j:.1f}J "
              f"p50 {w.latency_p50_s:.3f}s p95 {w.latency_p95_s:.3f}s")
    print(f"feasible counts: {feasible}")
    print(f"converged choice: n={apool.choice}")
    print("scheduler summary:", apool.scheduler.summary())
    apool.close()


def _print_windows(history) -> None:
    for w in history:
        print(f"window {w.window}: n={w.n_containers} "
              f"wall {w.wall_s:.2f}s {w.tokens_per_s:.1f} tok/s "
              f"energy {w.energy_j:.1f}J "
              f"ttfc p50 {w.ttfc_p50_s:.3f}s p95 {w.ttfc_p95_s:.3f}s "
              f"lat p50 {w.latency_p50_s:.3f}s"
              + (f" retries {w.n_retries} failed {w.n_failed} "
                 f"shed {w.n_shed}"
                 if w.n_retries or w.n_failed or w.n_shed else ""))
        for name, cw in sorted(w.per_class.items()):
            tgt = (f" target {cw.target_ttfc_p95_s:.3f}s "
                   f"{'MET' if cw.attained else 'VIOLATED'}"
                   if cw.attained is not None else "")
            print(f"    [{name}] done {cw.n_done} shed {cw.n_shed} "
                  f"failed {cw.n_failed} "
                  f"ttfc p95 {cw.ttfc_p95_s:.3f}s{tgt}")


def _serve_trace(args, cfg, model, params, units, slo) -> None:
    """Open-loop trace replay through the live Router — the launcher
    face of ``workload.replay``. Online (scheduler-resized) when
    ``--containers 0``, fixed count otherwise."""
    trace = load_or_synthesize(args.trace, seed=args.trace_seed)
    objective = "energy_under_slo" if slo is not None else args.objective
    router_kw = dict(**_router_fault_kw(args), slo=slo,
                     tenant_quota=args.tenant_quota,
                     window=args.requests, window_s=5.0)
    if args.containers:
        backend = _make_backend(args, cfg, model, params,
                                args.containers, units)
        router = Router(backend, **router_kw)
    else:
        engine_cfg = _engine_config(args)
        kv_kw = ({"kv_blocks": engine_cfg.resolved_max_blocks,
                  "block_size": engine_cfg.block_size,
                  "prefix_cached_blocks": args.prefix_cached_blocks}
                 if args.cache == "paged" else {})
        feasible = feasible_counts(cfg, units, **kv_kw) or [1]
        router = Router(
            backend_factory=lambda n: _make_backend(args, cfg, model,
                                                    params, n, units),
            feasible_counts=feasible, objective=objective,
            epsilon=0.1, **router_kw)
    with router:
        report = replay(trace, router, time_scale=args.time_scale,
                        vocab_size=cfg.vocab_size)
        _print_windows(router.history)
    print(f"trace {report.trace} (seed {report.seed}, "
          f"time_scale {report.time_scale:g}): "
          f"{report.n_done}/{report.n_requests} done, "
          f"{report.n_shed} shed, {report.n_failed} failed in "
          f"{report.duration_s:.1f}s")
    print(f"goodput {report.goodput_rps:.2f} rps  "
          f"ttfc p95 {report.ttfc_p95_s:.3f}s  "
          f"energy/done {report.energy_per_done_j:.2f}J  "
          f"counts {list(report.counts_visited)} -> n={report.final_n}")
    for name, cw in sorted(report.per_class.items()):
        tgt = (f" target {cw.target_ttfc_p95_s:.3f}s "
               f"{'MET' if cw.attained else 'VIOLATED'}"
               if cw.attained is not None else "")
        print(f"  [{name}] done {cw.n_done} shed {cw.n_shed} "
              f"failed {cw.n_failed} ttfc p95 {cw.ttfc_p95_s:.3f}s{tgt}")


def _print_wave(args, n, done, per, wall, energy, meshes, backend) -> None:
    toks = sum(len(c.tokens) for c in done)
    mode = (args.isolation if args.isolation == "process" else
            ("sequential" if args.sequential else "concurrent"))
    if args.stream:
        mode += "+stream"
    print(f"n={n} ({mode}): {len(done)} requests, "
          f"{toks} tokens in {wall:.2f}s ({toks/wall:.1f} tok/s, "
          f"~{energy:.1f}J)")
    for r in per:
        devs = ""
        if meshes is not None:
            ids = sorted(d.id for d in meshes[r.container_id].devices.flat)
            devs = f" devices {ids}"
        if args.isolation == "process" and backend is not None:
            cores = backend.reported_core_sets[r.container_id]
            devs = f" cores {sorted(cores)}"
        print(f"  container {r.container_id}: {r.n_requests} reqs "
              f"wall {r.wall_s:.2f}s busy {r.busy_s:.2f}s "
              f"{r.tokens_per_s:.1f} tok/s ~{r.energy_j:.1f}J "
              f"p50 {r.latency_p50_s:.3f}s p95 {r.latency_p95_s:.3f}s"
              f"{devs}")


if __name__ == "__main__":
    main()
