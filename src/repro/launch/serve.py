"""Serving launcher: concurrent container-pool serving of a synthetic
request stream, with the online divide-and-save scheduler.

Fixed count: one concurrent pool. ``--containers 0`` (default) runs the
adaptive loop — waves of traffic, each served at the scheduler's current
pick within the memory-feasible counts, each observation refining the
fitted time/energy models.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --containers 4 --requests 16
    PYTHONPATH=src python -m repro.launch.serve --waves 8 --objective time
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import ARCH_NAMES, get_config
from repro.core.containers import feasible_counts
from repro.models.model import Model
from repro.serving import (AdaptiveServingPool, ContainerServingPool,
                           Request)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_NAMES)
    ap.add_argument("--containers", type=int, default=0,
                    help="0 = let the scheduler choose online")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--waves", type=int, default=6,
                    help="traffic waves in adaptive mode")
    ap.add_argument("--objective", default="energy",
                    choices=("energy", "time"))
    ap.add_argument("--sequential", action="store_true",
                    help="disable container concurrency (baseline)")
    ap.add_argument("--units", type=int, default=8,
                    help="resource units to factorise (cores / chips)")
    args = ap.parse_args()

    cfg = get_config(args.arch + "-reduced")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def batch_of_requests(base):
        return [Request(rid=base + i,
                        prompt=rng.integers(0, cfg.vocab_size, (8,),
                                            dtype=np.int32),
                        max_new_tokens=args.max_new)
                for i in range(args.requests)]

    if args.containers:
        pool = ContainerServingPool(model, params, args.containers,
                                    n_slots_per_container=args.slots,
                                    concurrent=not args.sequential)
        done, per, wall, energy = pool.serve_timed(batch_of_requests(0))
        toks = sum(len(c.tokens) for c in done)
        mode = "sequential" if args.sequential else "concurrent"
        print(f"n={args.containers} ({mode}): {len(done)} requests, "
              f"{toks} tokens in {wall:.2f}s ({toks/wall:.1f} tok/s, "
              f"~{energy:.1f}J)")
        for r in per:
            print(f"  container {r.container_id}: {r.n_requests} reqs "
                  f"wall {r.wall_s:.2f}s busy {r.busy_s:.2f}s "
                  f"{r.tokens_per_s:.1f} tok/s ~{r.energy_j:.1f}J")
        return

    # online mode: the scheduler probes container counts across waves,
    # bounded by the memory-feasible factorisations of the host
    feasible = feasible_counts(cfg, args.units) or [1]
    apool = AdaptiveServingPool(model, params, feasible,
                                objective=args.objective, epsilon=0.2,
                                n_slots_per_container=args.slots,
                                concurrent=not args.sequential)
    for wave in range(args.waves):
        apool.serve_wave(batch_of_requests(wave * args.requests))
        w = apool.history[-1]
        print(f"wave {w.wave}: n={w.n_containers} wall {w.wall_s:.2f}s "
              f"{w.tokens_per_s:.1f} tok/s energy {w.energy_j:.1f}J")
    print(f"feasible counts: {feasible}")
    print(f"converged choice: n={apool.choice}")
    print("scheduler summary:", apool.scheduler.summary())


if __name__ == "__main__":
    main()
