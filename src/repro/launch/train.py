"""Distributed training launcher.

On a pod this builds the production mesh, applies the FSDP sharding rules
and pjit-compiles the train step; on this CPU host the same code path runs
with a 1×1 mesh and a reduced config — one code path, two scales.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh, set_mesh
from repro.configs.registry import ARCH_NAMES, get_config
from repro.data.pipeline import LmTokenStream
from repro.launch.sharding import ShardingRules
from repro.models.model import Model
from repro.train import checkpoint
from repro.train.loop import TrainConfig, make_train_step
from repro.train.optimizer import AdamWConfig, init_opt_state


def make_mesh_from_devices():
    n = jax.device_count()
    data = max(1, n // 2) if n > 1 else 1
    model_ax = n // data
    return make_mesh((data, model_ax), ("data", "model"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale variant of the architecture")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--save", default=None)
    args = ap.parse_args()

    name = args.arch + ("-reduced" if args.reduced else "")
    cfg = get_config(name)
    model = Model(cfg)
    mesh = make_mesh_from_devices()
    rules = ShardingRules(mesh, train=True)
    print(f"arch={cfg.name} params={cfg.param_count():,} "
          f"mesh={dict(mesh.shape)}")

    tcfg = TrainConfig(opt=AdamWConfig(lr=args.lr, warmup_steps=10,
                                       total_steps=args.steps),
                       remat=args.remat, microbatches=args.microbatches)
    step_fn = make_train_step(model, tcfg)
    stream = LmTokenStream(cfg.vocab_size, seq_len=args.seq,
                           batch_size=args.batch)

    with set_mesh(mesh):
        params = jax.jit(
            lambda k: model.init(k),
            out_shardings=rules.params(jax.eval_shape(
                model.init, jax.random.PRNGKey(0))),
        )(jax.random.PRNGKey(0))
        opt_state = init_opt_state(params)
        jitted = jax.jit(step_fn)
        t0 = time.time()
        for step in range(args.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in stream.batch(step).items()}
            params, opt_state, metrics = jitted(params, opt_state, batch)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                      f"({time.time()-t0:.1f}s)", flush=True)
    if args.save:
        checkpoint.save(args.save, params, meta={"steps": args.steps})
        print("checkpoint:", args.save)


if __name__ == "__main__":
    main()
