"""Role-aware sharding rules for params, optimizer state, caches, batches.

Baseline ("paper-faithful container") strategy:
  * inference — Megatron-style tensor parallelism over "model" (q-heads /
    ff / experts on their parallel dims), weights replicated over
    "data"/"pod": the data axis is the *container* axis (independent
    replicas — DESIGN.md §2). Big models additionally FSDP-shard weights
    over "data" (``fsdp=True``) to fit HBM; the extra all-gathers show up
    honestly in the collective roofline term.
  * train — FSDP: weights/optimizer state sharded over "data" on a second
    dim; batch over ("pod","data").

Rules are PATH-BASED (matched on the param-tree key names), not size
heuristics: size heuristics mis-shard attention projections (e.g. sharding
head_dim — a contraction dim — forces a per-tile all-reduce of attention
scores). Every assignment checks divisibility; axes that don't divide are
dropped (GSPMD rejects uneven explicit shardings).

Cache rules (decode): batch over "data" when it divides; kv-heads over
"model" when they divide, otherwise the *sequence* dim goes to "model"
(sequence-parallel flash-decode — each chip owns a slice of the KV cache
and the partial-softmax merge is a small stats collective). When batch
can't use "data" (long_500k has batch 1), the sequence dim is sharded over
"data" instead, so a 500k-token cache spreads over the whole pod.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import mesh_axis_size


def _path_names(path) -> list[str]:
    return [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]


def _ok(shape, i, size) -> bool:
    """Can dim i (negative index from the right) shard over an axis of
    ``size``?"""
    d = shape[i]
    return size > 1 and d % size == 0 and d >= size


def _assemble(shape, rev_assign: dict[int, Any]) -> P:
    """rev_assign keys are negative dim indices."""
    n = len(shape)
    parts = [None] * n
    for i, ax in rev_assign.items():
        if ax is not None:
            parts[n + i] = ax
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


class ShardingRules:
    def __init__(self, mesh, train: bool = False, fsdp: bool | None = None,
                 decode: bool = False):
        """``fsdp=None`` → FSDP iff training. Inference callers pass
        ``fsdp=True`` when model-only weight sharding would overflow HBM.
        ``decode=True`` switches FSDP'd experts to 2D ff-sharding: decode
        activations are tiny, so gathering the token batch beats gathering
        the expert weights every step (§Perf — mixtral decode)."""
        self.mesh = mesh
        self.train = train
        self.fsdp = train if fsdp is None else fsdp
        self.decode = decode
        self.model = mesh_axis_size(mesh, "model")
        self.data = mesh_axis_size(mesh, "data")
        self.pod = mesh_axis_size(mesh, "pod")
        self.batch_axes = (("pod", "data") if self.pod > 1 else ("data",))
        self.data_total = self.data * self.pod

    @property
    def device_set(self) -> frozenset:
        """The devices this rules instance places onto (empty for abstract
        meshes) — a container pool checks these are pairwise disjoint."""
        try:
            devs = self.mesh.devices
        except (AttributeError, ValueError):
            # AbstractMesh has no devices (0.4.x raises ValueError)
            return frozenset()
        return frozenset(devs.flat)

    # ------------------------------------------------------------------
    def _ns(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def _f(self, shape, i) -> str | None:
        """FSDP axis for dim i if enabled and divisible."""
        return "data" if (self.fsdp and _ok(shape, i, self.data)) else None

    def _m(self, shape, i) -> str | None:
        return "model" if _ok(shape, i, self.model) else None

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------
    def _param_spec(self, path: list[str], shape: tuple) -> P:
        name = path[-1] if path else ""
        ctx = set(path)
        M, f = self._m, self._f

        if name == "table":                       # embed (V, d)
            return _assemble(shape, {-2: M(shape, -2), -1: f(shape, -1)})
        if "lm_head" in ctx:                      # (d, V)
            return _assemble(shape, {-2: f(shape, -2), -1: M(shape, -1)})
        if name == "wq":                          # (d, H, hd)
            return _assemble(shape, {-3: f(shape, -3), -2: M(shape, -2)})
        if name in ("wk", "wv"):                  # (d, kv, hd)
            return _assemble(shape, {-3: f(shape, -3), -2: M(shape, -2)})
        if name == "wo":                          # (H, hd, d)
            return _assemble(shape, {-3: M(shape, -3), -1: f(shape, -1)})
        if name in ("w_uk", "w_uv"):              # MLA up (r, H, dk)
            return _assemble(shape, {-3: f(shape, -3), -2: M(shape, -2)})
        if name == "w_dkv":                       # MLA down (d, r+dr)
            return _assemble(shape, {-2: f(shape, -2)})
        if name in ("w_gate", "w_up"):            # (d, ff) / experts (E, d, ff)
            if "experts" in ctx and len(shape) >= 3:
                if _ok(shape, -3, self.model):    # expert-parallel
                    return _assemble(shape, {-3: "model",
                                             -2: f(shape, -2)})
                if self.decode and self.fsdp \
                        and _ok(shape, -1, self.data * self.model):
                    return _assemble(shape, {-1: ("data", "model")})
                return _assemble(shape, {-2: f(shape, -2),
                                         -1: M(shape, -1)})
            return _assemble(shape, {-2: f(shape, -2), -1: M(shape, -1)})
        if name == "w_down":                      # (ff, d) / experts (E, ff, d)
            if "experts" in ctx and len(shape) >= 3:
                if _ok(shape, -3, self.model):
                    return _assemble(shape, {-3: "model",
                                             -1: f(shape, -1)})
                if self.decode and self.fsdp \
                        and _ok(shape, -2, self.data * self.model):
                    return _assemble(shape, {-2: ("data", "model")})
                return _assemble(shape, {-2: M(shape, -2),
                                         -1: f(shape, -1)})
            return _assemble(shape, {-2: M(shape, -2), -1: f(shape, -1)})
        if name == "router":                      # (d, E)
            return _assemble(shape, {-2: f(shape, -2)})
        if name == "in_proj":                     # mamba (d, d_in_proj)
            return _assemble(shape, {-2: f(shape, -2)})
        if name == "out_proj":                    # mamba (di, d) row-parallel
            return _assemble(shape, {-2: M(shape, -2), -1: f(shape, -1)})
        if "vis_proj" in ctx and name == "w":     # (d_vis, d) then (d, d)
            return _assemble(shape, {-2: f(shape, -2), -1: M(shape, -1)})
        # norms / biases / conv / dt / A_log / D / small vectors: replicate
        return P()

    def params(self, params_struct: Any) -> Any:
        flat, tdef = jax.tree_util.tree_flatten_with_path(params_struct)
        specs = [self._ns(self._param_spec(_path_names(p), leaf.shape))
                 for p, leaf in flat]
        return jax.tree_util.tree_unflatten(tdef, specs)

    # ------------------------------------------------------------------
    # optimizer state (mirrors params under m/v; scalars replicated)
    # ------------------------------------------------------------------
    def opt_state(self, opt_struct: Any) -> Any:
        flat, tdef = jax.tree_util.tree_flatten_with_path(opt_struct)
        specs = []
        for p, leaf in flat:
            names = _path_names(p)
            if leaf.ndim == 0:
                specs.append(self._ns(P()))
                continue
            # strip the leading "m"/"v" key and apply the param rule
            inner = names[1:] if names and names[0] in ("m", "v") else names
            specs.append(self._ns(self._param_spec(inner, leaf.shape)))
        return jax.tree_util.tree_unflatten(tdef, specs)

    # ------------------------------------------------------------------
    # KV / SSM caches
    # ------------------------------------------------------------------
    def _cache_spec(self, path: list[str], shape: tuple, batch: int) -> P:
        name = path[-1] if path else ""
        asg: dict[int, Any] = {}
        if name in ("k", "v", "mem_k", "mem_v"):
            # trailing (B, W, kv, hd)
            if len(shape) < 4:
                return P()
            b_ok = _ok(shape, -4, self.data) and shape[-4] == batch
            if b_ok:
                asg[-4] = "data"
            if _ok(shape, -2, self.model):
                asg[-2] = "model"                  # kv heads
            elif _ok(shape, -3, self.model):
                asg[-3] = "model"                  # seq-parallel decode
            if not b_ok and _ok(shape, -3, self.data) and -3 not in asg:
                asg[-3] = "data"                   # long ctx, idle batch axis
            elif not b_ok and -3 in asg and asg[-3] == "model" \
                    and _ok(shape, -3, self.data * self.model):
                asg[-3] = ("data", "model")
            return _assemble(shape, asg)
        if name in ("k_scale", "v_scale"):
            # trailing (B, W, kv) — mirror the k/v rules minus head_dim
            if len(shape) < 3:
                return P()
            b_ok = _ok(shape, -3, self.data) and shape[-3] == batch
            if b_ok:
                asg[-3] = "data"
            if _ok(shape, -1, self.model):
                asg[-1] = "model"
            elif _ok(shape, -2, self.model):
                asg[-2] = "model"
            if not b_ok and _ok(shape, -2, self.data) and -2 not in asg:
                asg[-2] = "data"
            return _assemble(shape, asg)
        if name in ("ckv", "k_rope"):
            # trailing (B, S, r). Shard the SEQUENCE over "model" (and over
            # "data" too when batch is idle): the decode score einsum then
            # stays shard-local with a distributed softmax, instead of
            # GSPMD all-gathering the whole latent cache per layer (537 MB
            # ×L — the r-sharded layout's failure mode).
            if len(shape) < 3:
                return P()
            b_ok = _ok(shape, -3, self.data) and shape[-3] == batch
            if b_ok:
                asg[-3] = "data"
                if _ok(shape, -2, self.model):
                    asg[-2] = "model"
            elif _ok(shape, -2, self.data * self.model):
                asg[-2] = ("data", "model")        # long ctx, idle batch
            elif _ok(shape, -2, self.model):
                asg[-2] = "model"
            return _assemble(shape, asg)
        if name == "conv":
            # trailing (B, K-1, conv_dim)
            if len(shape) >= 3 and _ok(shape, -3, self.data) \
                    and shape[-3] == batch:
                asg[-3] = "data"
            if len(shape) >= 1 and _ok(shape, -1, self.model):
                asg[-1] = "model"
            return _assemble(shape, asg)
        if name == "state":
            # trailing (B, nh, hd, ds)
            if len(shape) >= 4 and _ok(shape, -4, self.data) \
                    and shape[-4] == batch:
                asg[-4] = "data"
            if len(shape) >= 3 and _ok(shape, -3, self.model):
                asg[-3] = "model"                  # SSD heads
            return _assemble(shape, asg)
        return P()

    def cache(self, cache_struct: Any, batch: int) -> Any:
        flat, tdef = jax.tree_util.tree_flatten_with_path(cache_struct)
        specs = [self._ns(self._cache_spec(_path_names(p), leaf.shape, batch))
                 for p, leaf in flat]
        return jax.tree_util.tree_unflatten(tdef, specs)

    # ------------------------------------------------------------------
    # batches
    # ------------------------------------------------------------------
    def _batch_spec(self, shape: tuple) -> P:
        if not shape or shape[0] % self.data_total != 0 \
                or shape[0] < self.data_total:
            return P()
        ax = self.batch_axes if len(self.batch_axes) > 1 else \
            self.batch_axes[0]
        return _assemble(shape, {-len(shape): ax})

    def batch(self, batch_struct: Any) -> Any:
        return jax.tree.map(
            lambda leaf: self._ns(self._batch_spec(leaf.shape)),
            batch_struct)

    def replicated(self, struct: Any) -> Any:
        return jax.tree.map(lambda _: self._ns(P()), struct)

    # ------------------------------------------------------------------
    # container placement (sub-mesh serving)
    # ------------------------------------------------------------------
    def container_placement(self, struct: Any) -> Any:
        """Placement for one container's params/caches on its sub-mesh:
        replicated across the slice. The container axis carries the
        parallelism (containers are full replicas — the paper's model);
        intra-container tensor parallelism (``params()``/``cache()`` on
        the same sub-mesh) is the pod-scale extension, but it changes
        matmul reduction order, so the bit-parity contract between n and
        the single-device baseline holds only for replicas."""
        return self.replicated(struct)


def tree_device_set(tree: Any) -> frozenset:
    """Union of the device sets of every committed leaf in ``tree`` —
    what the sub-mesh placement tests assert disjointness over."""
    out: set = set()
    for leaf in jax.tree.leaves(tree):
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None:
            out |= set(sharding.device_set)
    return frozenset(out)
