"""Abstract step-function + input-spec builders for the dry-run.

For every (arch, input-shape) pair this module produces:
  * ``input_specs(cfg, shape)`` — ShapeDtypeStruct stand-ins for every model
    input (no device allocation),
  * ``abstract_state(...)``      — params / optimizer state / KV-cache
    ShapeDtypeStructs via ``jax.eval_shape``,
  * ``build_step(...)``          — the pure step function to lower:
    train_step for ``train`` shapes, ``prefill`` for prefill shapes and
    ``decode_step`` (ONE new token against a seq_len KV cache) for decode
    shapes.

Everything here is abstract: the dry-run lowers with these structs and never
materialises a single parameter.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models.model import Model
from repro.train.loop import TrainConfig, make_train_step
from repro.train.optimizer import init_opt_state

# Production dtypes: bf16 params/activations, f32 optimizer state (the
# optimizer keeps f32 moments internally regardless of param dtype).
PARAM_DTYPE = jnp.bfloat16
CACHE_DTYPE = jnp.bfloat16


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """ShapeDtypeStructs for the *batch* inputs of the step function."""
    B = shape.global_batch
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": sds((B, shape.seq_len), jnp.int32)}
        if cfg.n_vision_tokens:
            specs["vision_embeds"] = sds(
                (B, cfg.n_vision_tokens, cfg.vision_embed_dim), PARAM_DTYPE)
        if cfg.n_encoder_layers:
            specs["audio_frames"] = sds(
                (B, cfg.encoder_seq, cfg.d_model), PARAM_DTYPE)
        return specs
    # decode: ONE new token per sequence + per-sequence positions
    return {"tokens": sds((B, 1), jnp.int32),
            "pos": sds((B,), jnp.int32)}


def abstract_params(model: Model) -> Any:
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: model.init(k, dtype=PARAM_DTYPE), key)


def abstract_opt_state(params_struct: Any) -> Any:
    return jax.eval_shape(init_opt_state, params_struct)


def abstract_cache(model: Model, batch: int, max_len: int) -> Any:
    return jax.eval_shape(
        functools.partial(model.init_cache, batch, max_len,
                          dtype=CACHE_DTYPE))


def build_step(model: Model, shape: InputShape,
               tcfg: TrainConfig | None = None) -> Callable:
    """The pure function the dry-run lowers (signature depends on kind)."""
    cfg = model.cfg
    if shape.kind == "train":
        tcfg = tcfg or TrainConfig(remat=True)
        return make_train_step(model, tcfg)
    if shape.kind == "prefill":
        def prefill_step(params, batch):
            cache = model.init_cache(shape.global_batch, shape.seq_len,
                                     dtype=CACHE_DTYPE)
            return model.prefill(params, batch, cache, logits_at=-1)
        return prefill_step

    def decode_step(params, cache, batch):
        return model.decode_step(params, batch["tokens"], cache,
                                 batch["pos"])
    return decode_step


def lowering_args(model: Model, shape: InputShape,
                  tcfg: TrainConfig | None = None):
    """(step_fn, abstract positional args) ready for jit(...).lower(*args)."""
    cfg = model.cfg
    step = build_step(model, shape, tcfg)
    batch = input_specs(cfg, shape)
    params = abstract_params(model)
    if shape.kind == "train":
        return step, (params, abstract_opt_state(params), batch)
    if shape.kind == "prefill":
        return step, (params, batch)
    cache = abstract_cache(model, shape.global_batch, shape.seq_len)
    return step, (params, cache, batch)
