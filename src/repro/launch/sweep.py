import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# (the dry-run device-count override must precede every jax import)

import argparse
import json
import subprocess
import sys

from repro.configs.registry import assigned_pairs
from repro.launch.dryrun import RESULTS_DIR, result_path


def run_sweep(meshes: list[str], force: bool = False,
              jobs: int = 4) -> list[tuple[str, str, str, bool]]:
    """Run every assigned (arch, shape) × mesh dry-run in subprocesses
    (isolation: one failure doesn't kill the sweep; JSON results cache)."""
    todo = []
    for mesh in meshes:
        for arch, shape in assigned_pairs():
            if force or not os.path.exists(result_path(arch, shape, mesh)):
                todo.append((arch, shape, mesh))
    print(f"{len(todo)} dry-runs to execute")
    procs: list[tuple[tuple, subprocess.Popen]] = []
    results = []

    def drain(block_all=False):
        while procs and (block_all or len(procs) >= jobs):
            (key, pr) = procs[0]
            pr.wait()
            procs.pop(0)
            ok = os.path.exists(result_path(*key))
            results.append((*key, ok))
            print(("[ok]  " if ok else "[FAIL]"), *key, flush=True)

    for arch, shape, mesh in todo:
        drain()
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh]
        if force:
            cmd.append("--force")
        procs.append(((arch, shape, mesh),
                      subprocess.Popen(cmd, stdout=subprocess.DEVNULL,
                                       stderr=subprocess.DEVNULL)))
    drain(block_all=True)
    return results


def collect() -> list[dict]:
    rows = []
    if not os.path.isdir(RESULTS_DIR):
        return rows
    for fn in sorted(os.listdir(RESULTS_DIR)):
        if fn.endswith(".json"):
            with open(os.path.join(RESULTS_DIR, fn)) as f:
                rows.append(json.load(f))
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | fsdp | t_comp (ms) | t_mem (ms) | "
           "t_coll (ms) | dominant | step (ms) | useful | HBM/chip (GB) | "
           "energy (J) |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|---|")
    out = [hdr]
    for d in rows:
        r = d["roofline"]
        mem = d.get("memory_analysis", {})
        hbm = (mem.get("temp_size_in_bytes", 0)
               + mem.get("argument_size_in_bytes", 0)) / 1e9
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
            f"{'Y' if d.get('fsdp') else 'n'} | "
            f"{r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} | "
            f"{r['t_collective_s']*1e3:.2f} | {r['dominant']} | "
            f"{r['step_time_s']*1e3:.2f} | {r['useful_ratio']:.3f} | "
            f"{hbm:.2f} | {r['energy_j']:.1f} |")
    return "\n".join(out)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--table-only", action="store_true")
    args = ap.parse_args()
    meshes = (["pod", "multipod"] if args.mesh == "both" else [args.mesh])
    if not args.table_only:
        results = run_sweep(meshes, force=args.force, jobs=args.jobs)
        fails = [r for r in results if not r[3]]
        print(f"\n{len(results)} run, {len(fails)} failed")
        for f in fails:
            print("FAILED:", f[:3])
    print(markdown_table(collect()))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
