import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- the two lines above MUST precede every other import (jax locks the ---
# --- device count on first init; the dry-run needs 512 placeholders).  ---

import argparse
import json
import time
import traceback

import jax

from repro.compat import set_mesh
from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import assigned_pairs, get_config, get_shape
from repro.core.hlo_analysis import analyze_hlo
from repro.core.roofline import build_report
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import ShardingRules
from repro.launch.specs import PARAM_DTYPE, lowering_args
from repro.models.model import Model
from repro.train.loop import TrainConfig

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

# Inference weights: shard over "model" only (container semantics) unless
# the per-chip shard would overflow HBM — then ZeRO-style ("data" too).
FSDP_INFERENCE_THRESHOLD = 12e9  # bytes per chip


def result_path(arch: str, shape: str, mesh_name: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_name}.json")


def _shardings_for(rules: ShardingRules, shape_kind: str, args):
    if shape_kind == "train":
        params, opt_state, batch = args
        return (rules.params(params), rules.opt_state(opt_state),
                rules.batch(batch))
    if shape_kind == "prefill":
        params, batch = args
        return (rules.params(params), rules.batch(batch))
    params, cache, batch = args
    return (rules.params(params), rules.cache(cache, batch["tokens"].shape[0]),
            rules.batch(batch))


def run_one(arch: str, shape_name: str, mesh_name: str,
            microbatches: int = 1, remat: bool = True,
            save: bool = True) -> dict:
    """Lower + compile one (arch, shape, mesh) and extract the roofline."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    multi_pod = mesh_name == "multipod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    model = Model(cfg)

    tcfg = TrainConfig(remat=remat, microbatches=microbatches)
    step, args = lowering_args(model, shape, tcfg)

    weight_bytes = cfg.param_count() * PARAM_DTYPE.dtype.itemsize
    model_axis = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    fsdp_inference = weight_bytes / model_axis > FSDP_INFERENCE_THRESHOLD
    rules = ShardingRules(mesh, train=(shape.kind == "train"),
                          fsdp=(True if shape.kind == "train"
                                else fsdp_inference),
                          decode=(shape.kind == "decode"))
    in_shardings = _shardings_for(rules, shape.kind, args)

    # decode: pin the output cache to the input cache layout — otherwise
    # XLA may pick a different output sharding and re-layout the whole
    # cache (a 34 MB collective-permute per layer per token, measured on
    # the multipod mesh)
    out_shardings = None
    if shape.kind == "decode":
        out_shardings = (None, in_shardings[1])

    t0 = time.time()
    with set_mesh(mesh):
        jitted = (jax.jit(step, in_shardings=in_shardings,
                          out_shardings=out_shardings)
                  if out_shardings is not None
                  else jax.jit(step, in_shardings=in_shardings))
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = {}
        try:
            ma = compiled.memory_analysis()
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(ma, k, None)
                if v is not None:
                    mem[k] = int(v)
        except Exception as e:  # backend without memory analysis
            mem["error"] = str(e)

        xla_cost = {}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            for k in ("flops", "bytes accessed"):
                if k in ca:
                    xla_cost[k] = float(ca[k])
        except Exception as e:
            xla_cost["error"] = str(e)

        hlo_text = compiled.as_text()

    cost = analyze_hlo(hlo_text)
    report = build_report(arch, shape, cfg, mesh_name, chips, cost)

    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "kind": shape.kind,
        "fsdp": rules.fsdp,
        "microbatches": microbatches,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "xla_cost_analysis": xla_cost,
        "parser": {
            "flops_per_chip": cost.flops_per_chip,
            "bytes_per_chip": cost.bytes_per_chip,
            "coll_wire_bytes_per_chip": cost.coll_wire_bytes_per_chip,
            "collectives_by_kind": cost.collectives,
        },
        "roofline": {
            "t_compute_s": report.t_compute,
            "t_memory_s": report.t_memory,
            "t_collective_s": report.t_collective,
            "dominant": report.dominant,
            "step_time_s": report.step_time,
            "model_flops": report.model_flops,
            "hlo_flops_total": report.hlo_flops_total,
            "useful_ratio": report.useful_ratio,
            "utilization": report.utilization,
            "power_w_per_chip": report.power_w_per_chip,
            "energy_j": report.energy_j,
        },
    }
    if save:
        with open(result_path(arch, shape_name, mesh_name), "w") as f:
            json.dump(out, f, indent=2)
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description="Multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=[None, *INPUT_SHAPES])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    pairs = assigned_pairs()
    if args.arch:
        pairs = [(a, s) for a, s in pairs if a == args.arch]
    if args.shape:
        pairs = [(a, s) for a, s in pairs if s == args.shape]
    if not pairs and args.arch and args.shape:
        # explicit pair outside the assigned pool (extra architectures)
        pairs = [(args.arch, args.shape)]
    if not pairs:
        print("nothing to run")
        return 1

    failures = 0
    for arch, shape in pairs:
        path = result_path(arch, shape, args.mesh)
        if os.path.exists(path) and not args.force:
            print(f"[skip] {arch} × {shape} × {args.mesh} (cached)")
            continue
        try:
            out = run_one(arch, shape, args.mesh,
                          microbatches=args.microbatches)
            r = out["roofline"]
            print(f"[ok]   {arch} × {shape} × {args.mesh}: "
                  f"compile {out['compile_s']}s, dominant={r['dominant']}, "
                  f"step={r['step_time_s']*1e3:.2f}ms, "
                  f"useful={r['useful_ratio']:.2f}")
        except Exception:
            failures += 1
            print(f"[FAIL] {arch} × {shape} × {args.mesh}")
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
