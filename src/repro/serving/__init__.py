"""Public serving surface.

The request-level streaming API is the supported one: a ``Router`` over a
``ContainerBackend``, ``Request`` in, typed ``ChunkEvent``/``DoneEvent``
out, engines configured with a frozen ``EngineConfig`` (dense or paged
KV cache behind the ``CacheBackend`` protocol — serving/cache.py).

Everything else (wave pools, concrete backends, params handoff helpers)
is still importable from here for compatibility, but lazily and behind a
DeprecationWarning — import those names from their home modules
(``repro.serving.pool``, ``repro.serving.backend``, ...) instead.
"""
from __future__ import annotations

import importlib
import warnings

# only the import-light wire modules load eagerly: the process child
# unpickles ``serving.child._serving_child`` pre-affinity, which runs
# this __init__ — an eager engine/backend import here would pull jax
# into the child before its cpuset exists (repro.analysis.wire gates
# this). The heavy names resolve on first attribute access instead.
from repro.serving.events import (ChunkEvent, ContainerFailure, DoneEvent,
                                  FailedEvent, RejectedEvent, RetryEvent)
from repro.serving.faults import Fault, FaultPlan

__all__ = ["Router", "Request", "Completion", "ChunkEvent", "DoneEvent",
           "RetryEvent", "FailedEvent", "RejectedEvent", "ContainerFailure",
           "RequestFailed", "RequestRejected", "Fault", "FaultPlan",
           "ContainerBackend", "EngineConfig", "CacheBackend"]

# curated-but-heavy surface: resolved lazily, no DeprecationWarning
_CANONICAL = {
    "ContainerBackend": "repro.serving.backend",
    "CacheBackend": "repro.serving.cache",
    "Completion": "repro.serving.engine",
    "EngineConfig": "repro.serving.engine",
    "Request": "repro.serving.engine",
    "RequestFailed": "repro.serving.router",
    "RequestRejected": "repro.serving.router",
    "Router": "repro.serving.router",
}

# legacy surface: name -> home module. Resolved on attribute access with
# a DeprecationWarning naming the canonical import.
_LEGACY = {
    "ServingEngine": "repro.serving.engine",
    "Event": "repro.serving.events",
    "ContainerResult": "repro.serving.pool",
    "ContainerServingPool": "repro.serving.pool",
    "EnergyProxy": "repro.serving.pool",
    "AdaptiveServingPool": "repro.serving.adaptive",
    "SyntheticContainerPool": "repro.serving.adaptive",
    "WaveResult": "repro.serving.adaptive",
    "synthetic_pool_factory": "repro.serving.adaptive",
    "ProcessContainerPool": "repro.serving.process_pool",
    "ThreadBackend": "repro.serving.backend",
    "ProcessBackend": "repro.serving.backend",
    "SubmeshBackend": "repro.serving.backend",
    "save_params": "repro.serving.backend",
    "share_params": "repro.serving.backend",
    "ParamsShare": "repro.serving.backend",
    "SharedParams": "repro.serving.backend",
    "CompletionHandle": "repro.serving.router",
    "WindowStats": "repro.serving.router",
}


def __getattr__(name: str):
    mod = _CANONICAL.get(name)
    if mod is not None:
        return getattr(importlib.import_module(mod), name)
    mod = _LEGACY.get(name)
    if mod is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    warnings.warn(
        f"importing {name} from repro.serving is deprecated; import it "
        f"from {mod} instead (the curated repro.serving surface is "
        f"{__all__})", DeprecationWarning, stacklevel=2)
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return sorted(set(__all__) | set(_LEGACY) | set(globals()))
