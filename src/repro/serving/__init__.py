from repro.serving.adaptive import (AdaptiveServingPool,
                                    SyntheticContainerPool, WaveResult,
                                    synthetic_pool_factory)
from repro.serving.backend import (ContainerBackend, ParamsShare,
                                   ProcessBackend, SharedParams,
                                   SubmeshBackend, ThreadBackend,
                                   save_params, share_params)
from repro.serving.engine import Completion, Request, ServingEngine
from repro.serving.events import ChunkEvent, DoneEvent, Event
from repro.serving.pool import (ContainerResult, ContainerServingPool,
                                EnergyProxy)
from repro.serving.process_pool import ProcessContainerPool
from repro.serving.router import CompletionHandle, Router, WindowStats

__all__ = ["Completion", "Request", "ServingEngine", "ContainerResult",
           "ContainerServingPool", "EnergyProxy", "AdaptiveServingPool",
           "SyntheticContainerPool", "WaveResult", "synthetic_pool_factory",
           "ProcessContainerPool", "save_params", "share_params",
           "ParamsShare", "SharedParams", "ContainerBackend",
           "ThreadBackend", "ProcessBackend", "SubmeshBackend",
           "ChunkEvent", "DoneEvent", "Event", "Router",
           "CompletionHandle", "WindowStats"]
