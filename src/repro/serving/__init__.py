from repro.serving.engine import Completion, Request, ServingEngine
from repro.serving.pool import ContainerResult, ContainerServingPool

__all__ = ["Completion", "Request", "ServingEngine", "ContainerResult",
           "ContainerServingPool"]
