from repro.serving.adaptive import (AdaptiveServingPool,
                                    SyntheticContainerPool, WaveResult,
                                    synthetic_pool_factory)
from repro.serving.engine import Completion, Request, ServingEngine
from repro.serving.pool import (ContainerResult, ContainerServingPool,
                                EnergyProxy)
from repro.serving.process_pool import ProcessContainerPool, save_params

__all__ = ["Completion", "Request", "ServingEngine", "ContainerResult",
           "ContainerServingPool", "EnergyProxy", "AdaptiveServingPool",
           "SyntheticContainerPool", "WaveResult", "synthetic_pool_factory",
           "ProcessContainerPool", "save_params"]
