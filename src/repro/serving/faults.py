"""Fault-injection harness for the serving stack (test-only).

The divide-and-save premise only pays off if splitting work across
containers doesn't multiply failure modes — so failures must be
*rehearsable*. A ``FaultPlan`` is a picklable script of faults that the
backends execute against themselves under test-only flags:

    plan = FaultPlan((Fault("kill", container_id=0, after_steps=2),))
    backend = ProcessBackend(cfg, 2, fault_plan=plan, ...)

Fault kinds (per container):

* ``"kill"`` — the container dies abruptly after ``after_steps`` engine
  macro-steps. Process containers ``os._exit`` with
  ``EXIT_FAULT_KILL`` (no cleanup — a real crash); thread containers
  raise ``InjectedFault`` out of ``engine.step()``.
* ``"error"`` — the engine raises ``InjectedFault`` from ``step()``
  (process children report it over the pipe and exit
  ``EXIT_STEP_ERROR`` — the ordinary-exception failure class).
* ``"drop_replies"`` — process children silently discard their next
  ``count`` event flushes (simulated message loss on the reply pipe;
  the request looks in-flight forever, which is exactly what
  per-request deadlines exist to catch).
* ``"delay_replies"`` — process children sleep ``delay_s`` before each
  of their next ``count`` event flushes (a slow/contended pipe).
* ``"refuse_blocks"`` — the engine's paged-cache admission sees
  ``count`` refused block allocations (simulated pool exhaustion:
  requests stall in the queue until a deadline or the fault drains).

Faults are scoped to a container *incarnation* (0 = the original child,
1 = its first respawn, ...; ``incarnation=None`` applies to every one),
so a chaos test can kill incarnation 0 and assert the respawned child
serves cleanly — or kill every incarnation and assert the circuit
breaker trips.

This module must stay import-light: process children unpickle plans
BEFORE their pinned jax import, so nothing here may pull in jax or the
engine.
"""
from __future__ import annotations

import dataclasses

# Child exit codes, one per failure class, so a dead child's exitcode
# says *why* it died (surfaced in the ContainerFailure message). 0 stays
# the clean ("close",) shutdown; negative exitcodes are signals.
EXIT_STARTUP = 3        # failed before serving (import/params/engine init)
EXIT_PIPE_LOST = 4      # reply pipe broke mid-serve (parent gone?)
EXIT_STEP_ERROR = 5     # engine.step() raised; state unrecoverable
EXIT_FAULT_KILL = 6     # injected FaultPlan kill

EXIT_CLASSES = {
    EXIT_STARTUP: "startup failure",
    EXIT_PIPE_LOST: "reply pipe lost",
    EXIT_STEP_ERROR: "engine step error",
    EXIT_FAULT_KILL: "injected fault kill",
}


def describe_exitcode(code: int | None) -> str:
    """Human string for a child exitcode (``ContainerFailure`` messages)."""
    if code is None:
        return "exit code unknown"
    if code < 0:
        return f"killed by signal {-code}"
    return f"exit {code} ({EXIT_CLASSES.get(code, 'unclassified')})"


class InjectedFault(RuntimeError):
    """Raised out of ``engine.step()`` by an armed injector — thread
    containers surface it like any engine error; process children map
    ``kind='kill'`` to a hard ``os._exit`` instead."""

    def __init__(self, fault: "Fault"):
        super().__init__(f"injected fault: {fault.kind} on container "
                         f"{fault.container_id} after "
                         f"{fault.after_steps} steps")
        self.fault = fault


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scripted fault. ``after_steps`` counts the target container's
    engine macro-steps within the incarnation; ``count`` bounds how many
    times a repeating fault (drop/delay/refuse) fires (None = forever)."""
    kind: str
    container_id: int
    after_steps: int = 0
    count: int | None = None
    delay_s: float = 0.0
    incarnation: int | None = 0

    _KINDS = ("kill", "error", "drop_replies", "delay_replies",
              "refuse_blocks")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {self._KINDS}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A picklable script of ``Fault``s, shipped to backends (and across
    the spawn boundary into process children) under test-only flags."""
    faults: tuple = ()

    def for_container(self, container_id: int,
                      incarnation: int = 0) -> tuple:
        return tuple(f for f in self.faults
                     if f.container_id == container_id
                     and (f.incarnation is None
                          or f.incarnation == incarnation))


class FaultInjector:
    """Per-container, per-incarnation executor of a plan's faults.

    The engine calls ``on_step(step_no)`` at the top of every macro-step
    (raises ``InjectedFault`` for kill/error faults) and
    ``refuse_alloc()`` at each paged block allocation; process children
    additionally consult ``drop_reply()`` / ``reply_delay()`` around
    their event flushes. Stateless engines pass ``None`` instead of an
    injector — every hook is a no-op in that case.
    """

    def __init__(self, plan: FaultPlan | None, container_id: int,
                 incarnation: int = 0):
        faults = (plan.for_container(container_id, incarnation)
                  if plan is not None else ())
        self._step_faults = [f for f in faults
                             if f.kind in ("kill", "error")]
        self._drop = [f.count if f.count is not None else -1
                      for f in faults if f.kind == "drop_replies"]
        self._delay = [[f.count if f.count is not None else -1, f.delay_s]
                       for f in faults if f.kind == "delay_replies"]
        self._refuse = [f.count if f.count is not None else -1
                        for f in faults if f.kind == "refuse_blocks"]
        self._steps = 0

    @property
    def armed(self) -> bool:
        return bool(self._step_faults or self._drop or self._delay
                    or self._refuse)

    def on_step(self, step_no: int | None = None) -> None:
        """Called at the top of every engine macro-step; raises
        ``InjectedFault`` once a kill/error fault's step threshold is
        crossed."""
        self._steps = self._steps + 1 if step_no is None else step_no
        for f in self._step_faults:
            if self._steps > f.after_steps:
                raise InjectedFault(f)

    def refuse_alloc(self) -> bool:
        """True while a refuse_blocks fault still has budget — admission
        must treat the pool as exhausted."""
        for i, left in enumerate(self._refuse):
            if left != 0:
                if left > 0:
                    self._refuse[i] = left - 1
                return True
        return False

    def drop_reply(self) -> bool:
        """True when the next reply flush should be silently discarded."""
        for i, left in enumerate(self._drop):
            if left != 0:
                if left > 0:
                    self._drop[i] = left - 1
                return True
        return False

    def reply_delay(self) -> float:
        """Seconds to sleep before the next reply flush (0.0 = none)."""
        for entry in self._delay:
            if entry[0] != 0:
                if entry[0] > 0:
                    entry[0] -= 1
                return entry[1]
        return 0.0
