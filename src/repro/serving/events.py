"""Typed per-request serving events — the streaming serving surface.

The wave API (serve a batch, block until the slowest container drains)
hides everything that happens mid-wave; the paper's workload, by
contrast, is *continuous* (video frames arriving over time), and online
routing/scheduling needs observations at finer grain than a wave. These
events are that grain: ``ServingEngine`` emits them from the points where
token data is already on the host — admission (the prefill sample) and
each fused decode chunk's single host transfer — so streaming adds **no
new device syncs**.

Per request the stream is: one or more ``ChunkEvent``s (each carrying the
tokens that landed in that macro-step; the first one marks
time-to-first-chunk) followed by exactly one ``DoneEvent`` carrying the
finished ``Completion``. Events are plain picklable dataclasses so the
process backend can ship them over a pipe unchanged.

``time_s`` is a ``time.perf_counter`` stamp taken at emission, in the
emitting process. Consumers that compare stamps across processes (the
Router's latency windows) measure arrival-side instead, which keeps one
clock domain.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Union


@dataclasses.dataclass(frozen=True)
class ChunkEvent:
    """Tokens for one request that materialised in one engine macro-step
    (admission prefill sample, or a fused decode chunk's share)."""
    rid: int
    container_id: int
    tokens: tuple
    time_s: float


@dataclasses.dataclass(frozen=True)
class DoneEvent:
    """Terminal event: the request's completion (a
    ``serving.engine.Completion``), emitted exactly once, after every one
    of its ChunkEvents."""
    rid: int
    container_id: int
    completion: Any
    time_s: float


Event = Union[ChunkEvent, DoneEvent]
