"""Typed per-request serving events — the streaming serving surface.

The wave API (serve a batch, block until the slowest container drains)
hides everything that happens mid-wave; the paper's workload, by
contrast, is *continuous* (video frames arriving over time), and online
routing/scheduling needs observations at finer grain than a wave. These
events are that grain: ``ServingEngine`` emits them from the points where
token data is already on the host — admission (the prefill sample) and
each fused decode chunk's single host transfer — so streaming adds **no
new device syncs**.

Per request the stream is: one or more ``ChunkEvent``s (each carrying the
tokens that landed in that macro-step; the first one marks
time-to-first-chunk) followed by exactly one terminal event — a
``DoneEvent`` carrying the finished ``Completion``, a ``FailedEvent``
(deadline expiry, retries exhausted, cancellation), or a
``RejectedEvent`` (load-shedding refused admission, with a retry-after
hint). A ``RetryEvent`` may appear mid-stream when the Router
re-dispatches a request lost to a container failure: everything streamed
before it came from the dead container's aborted attempt and must be
discarded by the consumer — the retried prefill restarts from the
prompt, so the chunks AFTER the last RetryEvent are the request's actual
output. Events are plain picklable dataclasses so the process backend
can ship them over a pipe unchanged.

``ContainerFailure`` is the container-scoped (not request-scoped) typed
failure that supervising backends *return* from ``poll()`` instead of
raising — a dead/hung/erroring container must not take the Router (and
every healthy container's in-flight requests) down with it.

``time_s`` is a ``time.perf_counter`` stamp taken at emission, in the
emitting process. Consumers that compare stamps across processes (the
Router's latency windows) measure arrival-side instead, which keeps one
clock domain.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Union


@dataclasses.dataclass(frozen=True)
class ChunkEvent:
    """Tokens for one request that materialised in one engine macro-step
    (admission prefill sample, or a fused decode chunk's share)."""
    rid: int
    container_id: int
    tokens: tuple
    time_s: float


@dataclasses.dataclass(frozen=True)
class DoneEvent:
    """Terminal event: the request's completion (a
    ``serving.engine.Completion``), emitted exactly once, after every one
    of its ChunkEvents."""
    rid: int
    container_id: int
    completion: Any
    time_s: float


@dataclasses.dataclass(frozen=True)
class RetryEvent:
    """The request was lost to a container failure and re-dispatched to
    ``container_id`` (its new home) as attempt ``attempt`` (1 = first
    retry). Chunks streamed before this event belong to the aborted
    attempt: the retried prefill restarts from the prompt, so consumers
    reset their accumulation here instead of seeing silently replayed
    tokens."""
    rid: int
    container_id: int
    attempt: int
    reason: str
    time_s: float


@dataclasses.dataclass(frozen=True)
class FailedEvent:
    """Terminal event: the request ended without a completion.
    ``kind`` ∈ {"deadline", "container", "cancelled"} — deadline expiry,
    container failure with retries exhausted (or no healthy container
    left), or explicit cancellation."""
    rid: int
    container_id: int
    kind: str
    reason: str
    time_s: float


@dataclasses.dataclass(frozen=True)
class RejectedEvent:
    """Terminal event: admission control shed this request instead of
    queueing it (bounded queue full, the ttfc tail over the shed
    threshold, or a tenant over its quota). ``retry_after_s`` is the
    Router's backpressure hint; ``kind`` ∈ {"queue", "slo", "tenant"}
    names which threshold tripped and ``priority`` the SLO class it was
    evaluated under — per-class shed accounting keys on these."""
    rid: int
    reason: str
    retry_after_s: float
    time_s: float
    container_id: int = -1        # never dispatched
    kind: str = "queue"
    priority: str = "default"


@dataclasses.dataclass(frozen=True)
class ContainerFailure:
    """Container-scoped typed failure, surfaced IN a backend's ``poll()``
    result (never raised from it): the container died (``kind="dead"``,
    with the child's ``exitcode`` decoded into the message), raised from
    ``engine.step()`` (``kind="error"``), went silent past the heartbeat
    timeout (``kind="hung"``), or failed to (re)start (``kind="start"``).
    ``lost_rids`` are the requests that were in flight there — the Router
    re-dispatches them to healthy containers."""
    container_id: int
    kind: str
    message: str
    time_s: float
    exitcode: int | None = None
    lost_rids: tuple = ()


Event = Union[ChunkEvent, DoneEvent, RetryEvent, FailedEvent,
              RejectedEvent, ContainerFailure]
