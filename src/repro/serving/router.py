"""Request-level streaming Router — continuous admission over containers.

The paper's workload is continuous (video frames arriving over time), but
the wave API serves it in batch: hand over a complete wave, block until
the slowest container drains. The ``Router`` replaces that surface with
per-request admission and typed per-chunk events:

    router = Router(ThreadBackend(model, params, n))
    handle = router.submit(Request(...))          # returns immediately
    for ev in handle.stream():                    # ChunkEvent... DoneEvent
        ...

Dispatch is **least-loaded + bucket-aware**: a request goes to the
container with the fewest queued+active requests, ties broken toward a
container already holding requests in the same prompt-length admission
bucket (those prefill together in one compiled call — see the engine's
batched bucket admission). Works identically over every
``ContainerBackend`` (thread, process, submesh).

With a scheduler attached, the Router closes the paper's online loop at
**window** granularity instead of wave granularity: completions
accumulate into a sliding window of observed (wall, energy, tokens/s,
time-to-first-chunk, latency) stats; at each window boundary the
``DivideAndSaveScheduler`` observes the window and re-picks the container
count, and the Router swaps to the (cached, warm) backend for that count
as soon as the stream drains — no explicit waves anywhere.

The wave API survives as a thin shim: ``serve_wave`` = submit-all +
drain, reconstructing ``ContainerResult`` accounting via the existing
``pool.assemble_wave``, so wave callers and benchmarks keep working.

All latency/ttfc stamps are taken router-side (one clock domain even for
process backends): time-to-first-chunk is measured from ``submit()`` to
the arrival of the request's first ``ChunkEvent`` at the router.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter, deque
from typing import Any, Callable, Iterator, Sequence

from repro.core.scheduler import DivideAndSaveScheduler
from repro.serving.engine import Completion, Request, _bucket
from repro.serving.events import ChunkEvent, DoneEvent, Event
from repro.serving.pool import (ContainerResult, EnergyProxy, _warn_wave_shim,
                                assemble_wave, latency_percentiles,
                                percentiles)

_IDLE_SLEEP_S = 0.002


@dataclasses.dataclass
class WindowStats:
    """One scheduler observation window of streamed serving — the
    request-level analogue of ``adaptive.WaveResult``."""
    window: int
    n_containers: int
    wall_s: float
    energy_j: float
    n_requests: int
    n_tokens: int = 0
    tokens_per_s: float = 0.0
    ttfc_p50_s: float = 0.0       # time-to-first-chunk, median
    ttfc_p95_s: float = 0.0       # time-to-first-chunk, tail
    latency_p50_s: float = 0.0
    latency_p95_s: float = 0.0


class CompletionHandle:
    """Live view of one submitted request. ``stream()`` yields the
    request's typed events as they arrive (pumping the router while it
    waits); ``result()`` drains the stream and returns the Completion."""

    def __init__(self, rid: int, router: "Router"):
        self.rid = rid
        self._router = router
        self._pending: deque[Event] = deque()
        self.completion: Completion | None = None
        self.ttfc_s: float | None = None    # submit → first ChunkEvent
        self.container_id: int | None = None  # where dispatch placed it
        self.done_at: float | None = None   # DoneEvent arrival stamp

    @property
    def done(self) -> bool:
        """The terminal event arrived at the router (it may still be
        waiting in this handle's queue for ``stream()`` to consume)."""
        return self.completion is not None

    def stream(self) -> Iterator[Event]:
        """Yield this request's ChunkEvents, then its DoneEvent, then
        stop. Raises RuntimeError if the router is closed mid-stream
        instead of blocking forever; a second stream() over an
        already-consumed handle yields nothing (the completion is kept on
        the handle)."""
        while True:
            while self._pending:
                ev = self._pending.popleft()
                yield ev
                if isinstance(ev, DoneEvent):
                    return
            if self.completion is not None:
                return                 # already fully consumed
            if self._router._closed:
                raise RuntimeError(
                    f"router closed while request {self.rid} was "
                    "mid-stream")
            self._router._pump(block=True)

    def result(self) -> Completion:
        """Block (pumping the router) until done; the Completion."""
        for _ in self.stream():
            pass
        assert self.completion is not None
        return self.completion

    def tokens(self) -> list[int]:
        """Convenience: the completion's tokens (drains the stream)."""
        return list(self.result().tokens)


class Router:
    """Continuous-admission facade over a ``ContainerBackend``.

    Fixed mode: pass ``backend``. Adaptive mode: pass ``backend_factory``
    (count -> backend) plus ``feasible_counts`` (and optionally a
    ``scheduler``/``objective``); the Router starts at the scheduler's
    pick and resizes between windows. Backends built by the factory are
    cached per count and stay warm across resizes; ``close()`` releases
    all of them.
    """

    def __init__(self, backend=None, *,
                 backend_factory: Callable[[int], Any] | None = None,
                 feasible_counts: Sequence[int] | None = None,
                 scheduler: DivideAndSaveScheduler | None = None,
                 objective: str = "energy",
                 epsilon: float = 0.0, seed: int = 0,
                 deadline_s: float | None = None,
                 window: int = 16,
                 energy: EnergyProxy | None = None):
        if backend is None and backend_factory is None:
            raise ValueError("need a backend or a backend_factory")
        self.energy = energy or EnergyProxy()
        self.window = window
        self.scheduler = scheduler
        self._factory = backend_factory
        self._backends: dict[int, Any] = {}
        if backend_factory is not None:
            if scheduler is None:
                if not feasible_counts:
                    raise ValueError(
                        "adaptive mode needs feasible_counts (or an "
                        "explicit scheduler)")
                self.scheduler = DivideAndSaveScheduler(
                    list(feasible_counts), objective=objective,
                    deadline_s=deadline_s, epsilon=epsilon, seed=seed)
            n0 = self.scheduler.pick()
            backend = self._backend_for(n0)
        self.backend = backend
        self._closed = False
        self._handles: dict[int, CompletionHandle] = {}
        self._rid_cid: dict[int, int] = {}
        self._submit_t: dict[int, float] = {}
        # per-container multiset of in-flight admission buckets (the
        # bucket-aware half of dispatch)
        self._cid_buckets: list[Counter] = [Counter()
                                            for _ in range(backend.capacity)]
        self.history: list[WindowStats] = []
        self._target_n: int | None = None    # resize awaiting a drain
        self._new_window()

    # -- plumbing -------------------------------------------------------
    def _backend_for(self, n: int):
        if n not in self._backends:
            assert self._factory is not None
            self._backends[n] = self._factory(n)
        return self._backends[n]

    def _new_window(self) -> None:
        self._window_t0 = time.perf_counter()
        self._window_stats0 = [self.backend.stats(cid)
                               for cid in range(self.backend.capacity)]
        self._window_done: list[Completion] = []
        self._window_ttfc: list[float] = []

    @property
    def in_flight(self) -> int:
        return len(self._handles)

    @property
    def n_containers(self) -> int:
        return self.backend.capacity

    # -- admission ------------------------------------------------------
    def _dispatch(self, req: Request) -> int:
        bucket = _bucket(len(req.prompt))
        load = self.backend.load

        def key(cid: int):
            return (load(cid),
                    0 if self._cid_buckets[cid][bucket] else 1,
                    cid)
        cid = min(range(self.backend.capacity), key=key)
        self._cid_buckets[cid][bucket] += 1
        return cid

    def submit(self, req: Request) -> CompletionHandle:
        """Admit one request now; returns immediately with a handle whose
        ``stream()`` yields the request's events."""
        if self._closed:
            raise RuntimeError("router is closed")
        if req.rid in self._handles:
            raise ValueError(f"request id {req.rid} is already in flight")
        cid = self._dispatch(req)
        handle = CompletionHandle(req.rid, self)
        handle.container_id = cid
        self._handles[req.rid] = handle
        self._rid_cid[req.rid] = cid
        self._submit_t[req.rid] = time.perf_counter()
        self.backend.submit(cid, req)
        return handle

    # -- event pump -----------------------------------------------------
    def _pump(self, block: bool = False) -> list[Event]:
        """Advance the backend and route its events to handles. With
        ``block`` and nothing to route, naps briefly so process-backend
        waits don't spin."""
        events = self.backend.poll()
        now = time.perf_counter()
        for ev in events:
            handle = self._handles.get(ev.rid)
            if handle is None:          # stale event for a dropped handle
                continue
            handle._pending.append(ev)
            if isinstance(ev, ChunkEvent) and handle.ttfc_s is None:
                handle.ttfc_s = now - self._submit_t[ev.rid]
            elif isinstance(ev, DoneEvent):
                self._on_done(handle, ev)
        if self.scheduler is not None:
            self._maybe_rotate_window()
        if block and not events:
            time.sleep(_IDLE_SLEEP_S)
        return events

    def poll(self) -> list[Event]:
        """Public pump: advance containers, route events, return the
        routed batch (a tap — the events still reach their handles)."""
        return self._pump(block=False)

    def _on_done(self, handle: CompletionHandle, ev: DoneEvent) -> None:
        comp = ev.completion
        handle.completion = comp
        handle.done_at = time.perf_counter()
        rid = handle.rid
        cid = self._rid_cid.pop(rid)
        self._cid_buckets[cid][_bucket(comp.prompt_len)] -= 1
        del self._handles[rid]
        self._submit_t.pop(rid, None)
        if self.scheduler is not None:
            # window accumulators only exist to feed the scheduler; a
            # fixed-capacity router must not retain one Completion per
            # request forever (the lists are only reset at rotation)
            self._window_done.append(comp)
            if handle.ttfc_s is not None:
                self._window_ttfc.append(handle.ttfc_s)

    def drain(self) -> None:
        """Pump until every in-flight request has completed (their
        handles still hold any unconsumed events)."""
        while self._handles:
            self._pump(block=True)

    # -- windowed adaptation -------------------------------------------
    def _maybe_rotate_window(self) -> None:
        """Sliding-window adaptation, split in two so continuous traffic
        still adapts: the *stats window* closes on completion count
        (observe + re-pick every ``window`` completions, even with
        requests in flight), while the *backend swap* waits for the
        stream to drain — resizing under a live request would strand its
        slot."""
        if len(self._window_done) >= self.window:
            self._observe_window()
        if self._target_n is None or self._handles:
            return
        if self._target_n != self.backend.capacity \
                and self._factory is not None:
            if self._window_done:
                # the partial window ran entirely on the outgoing
                # backend; record it before its stats0 go stale
                self._observe_window(repick=False)
            self.backend = self._backend_for(self._target_n)
            self._cid_buckets = [Counter()
                                 for _ in range(self.backend.capacity)]
            self._new_window()
        self._target_n = None

    def _observe_window(self, repick: bool = True) -> None:
        n = self.backend.capacity
        wall = time.perf_counter() - self._window_t0
        busy = [self.backend.stats(cid)[0] - self._window_stats0[cid][0]
                for cid in range(n)]
        toks = sum(self.backend.stats(cid)[1] - self._window_stats0[cid][1]
                   for cid in range(n))
        energy_j = sum(self.energy.container_energy(wall, b, n)
                       for b in busy)
        ttfc50, ttfc95 = percentiles(self._window_ttfc)
        lat50, lat95 = latency_percentiles(self._window_done)
        self.history.append(WindowStats(
            len(self.history), n, wall, energy_j, len(self._window_done),
            toks, toks / wall if wall > 0 else 0.0, ttfc50, ttfc95,
            lat50, lat95))
        assert self.scheduler is not None
        self.scheduler.observe(n, wall, energy_j)
        if repick:
            self._target_n = self.scheduler.pick()
        self._new_window()

    @property
    def choice(self) -> int:
        """Exploitation-only container count (what a converged deployment
        runs); only meaningful in adaptive mode."""
        assert self.scheduler is not None
        return self.scheduler.best()

    # -- wave shim ------------------------------------------------------
    def serve_wave(self, requests: list[Request]
                   ) -> tuple[list[Completion], list[ContainerResult],
                              float, float]:
        """The legacy wave API on top of streaming: submit-all + drain,
        per-container accounting reconstructed with the existing
        ``assemble_wave``. Completions come back in submission order."""
        _warn_wave_shim("Router.serve_wave")
        # pin the backend for the whole wave: an adaptive window boundary
        # inside drain() may swap self.backend, and this wave's stats
        # deltas must come from the backend that served it
        backend = self.backend
        stats0 = [backend.stats(cid) for cid in range(backend.capacity)]
        t0 = time.perf_counter()
        handles = [self.submit(r) for r in requests]
        self.drain()
        wall = time.perf_counter() - t0
        capacity = backend.capacity
        segments: list[list[Request]] = [[] for _ in range(capacity)]
        comps: list[list[Completion]] = [[] for _ in range(capacity)]
        # _rid_cid entries are popped on completion; reconstruct the
        # dispatch segments from the handles' completions instead
        by_rid = {h.rid: h.completion for h in handles}
        # per-container wall: submit → last DoneEvent arrival for that
        # container (matching the pool contract, where a fast container
        # reports its own wall, not the slowest sibling's)
        last = [0.0] * capacity
        for r, h in zip(requests, handles):
            cid = h.container_id
            segments[cid].append(r)
            comps[cid].append(by_rid[r.rid])
            if h.done_at is not None:
                last[cid] = max(last[cid], h.done_at - t0)
        out = [(comps[cid], last[cid],
                backend.stats(cid)[0] - stats0[cid][0],
                backend.stats(cid)[1] - stats0[cid][1])
               for cid in range(capacity)]
        _, results, energy = assemble_wave(out, segments, wall, self.energy)
        ordered = [by_rid[r.rid] for r in requests]
        return ordered, results, wall, energy

    def serve(self, requests: list[Request]
              ) -> tuple[list[Completion], list[ContainerResult]]:
        ordered, results, _, _ = self.serve_wave(requests)
        return ordered, results

    # -- lifecycle ------------------------------------------------------
    def close(self) -> None:
        """Close the backend (and every cached adaptive backend). Handles
        still mid-stream raise rather than hang."""
        if self._closed:
            return
        self._closed = True
        backends = set(self._backends.values()) | {self.backend}
        for b in backends:
            b.close()
        self._backends = {}

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
