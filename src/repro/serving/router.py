"""Request-level streaming Router — continuous admission over containers.

The paper's workload is continuous (video frames arriving over time), but
the wave API serves it in batch: hand over a complete wave, block until
the slowest container drains. The ``Router`` replaces that surface with
per-request admission and typed per-chunk events:

    router = Router(ThreadBackend(model, params, n))
    handle = router.submit(Request(...))          # returns immediately
    for ev in handle.stream():                    # ChunkEvent... DoneEvent
        ...

Dispatch is **least-loaded + bucket-aware**: a request goes to the
container with the fewest queued+active requests, ties broken toward a
container already holding requests in the same prompt-length admission
bucket (those prefill together in one compiled call — see the engine's
batched bucket admission). Works identically over every
``ContainerBackend`` (thread, process, submesh).

With a scheduler attached, the Router closes the paper's online loop at
**window** granularity instead of wave granularity: completions
accumulate into a sliding window of observed (wall, energy, tokens/s,
time-to-first-chunk, latency) stats; at each window boundary the
``DivideAndSaveScheduler`` observes the window and re-picks the container
count, and the Router swaps to the (cached, warm) backend for that count
as soon as the stream drains — no explicit waves anywhere.

The wave API survives as a thin shim: ``serve_wave`` = submit-all +
drain, reconstructing ``ContainerResult`` accounting via the existing
``pool.assemble_wave``, so wave callers and benchmarks keep working.

All latency/ttfc stamps are taken router-side (one clock domain even for
process backends): time-to-first-chunk is measured from ``submit()`` to
the arrival of the request's first ``ChunkEvent`` at the router.

Fault tolerance (see serving/events.py for the event taxonomy):

* **Retry** — a ``ContainerFailure`` surfaced by a supervising backend
  carries the rids lost with the container; the Router re-dispatches
  each to a healthy container (``max_retries`` bound), streaming a
  ``RetryEvent`` so consumers discard the aborted attempt's chunks.
* **Deadlines** — ``Request.deadline_s`` (or the Router-wide
  ``request_deadline_s`` default) rides into the engine, which expires
  it exactly where resources are freed; the Router keeps an authoritative
  backstop clock so a dead/silent container cannot outlive a deadline.
* **Load-shedding** — admission rejects (typed ``RejectedEvent`` with a
  retry-after hint) when ``max_queue`` in-flight requests exist or the
  recent ttfc p95 crosses ``shed_p95_s``, so overload degrades into
  fast rejections instead of an unbounded latency tail.

``stream()`` yields a request's terminal event and then *raises*
(``RequestFailed`` / ``RequestRejected``, both RuntimeError) so code
that only calls ``result()`` cannot mistake a failed request for a
hung one.

**SLO mode** (pass an ``workload.slo.SLOSpec``): requests carry a
priority class and tenant; admission enters a rank-ordered router-side
backlog instead of a container FIFO (``dispatch_depth`` bounds how deep
each container's own queue may get, so ordering happens where ranks
exist), shed thresholds and queue shares derive from each class
(``queue_limit`` / ``shed_ttfc_threshold``), per-tenant in-flight
quotas reject hogs with ``RejectedEvent(kind="tenant")``, and each
window's ``WindowStats.per_class`` carries per-class tails + SLO
attainment. The scheduler observation then includes the constraint
class's ttfc p95 so ``energy_under_slo`` can pick the cheapest count
whose predicted tail meets target. Without an SLOSpec every code path
above is byte-identical to the pre-SLO router.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from collections import Counter, defaultdict, deque
from typing import Any, Callable, Iterator, Sequence

from repro.core.scheduler import DivideAndSaveScheduler
from repro.serving.engine import Completion, Request, _bucket
from repro.serving.events import (ChunkEvent, ContainerFailure, DoneEvent,
                                  Event, FailedEvent, RejectedEvent,
                                  RetryEvent)
from repro.serving.pool import (ContainerResult, EnergyProxy, _warn_wave_shim,
                                assemble_wave, latency_percentiles,
                                percentiles)
from repro.workload.slo import (SLOSpec, censored_ttfc_p95, class_window,
                                queue_limit, shed_ttfc_threshold)

_IDLE_SLEEP_S = 0.002


class RequestFailed(RuntimeError):
    """Raised by ``stream()``/``result()`` after a terminal
    ``FailedEvent`` — deadline expiry, retries exhausted, cancellation.
    The event rides on ``.event``; the message embeds its reason (which
    for container failures includes the original traceback)."""

    def __init__(self, event):
        super().__init__(
            f"request {event.rid} failed ({event.kind}): {event.reason}")
        self.event = event


class RequestRejected(RequestFailed):
    """Raised after a terminal ``RejectedEvent`` (admission shed the
    request). ``event.retry_after_s`` is the backpressure hint."""

    def __init__(self, event):
        RuntimeError.__init__(
            self,
            f"request {event.rid} rejected: {event.reason} "
            f"(retry after {event.retry_after_s:.2f}s)")
        self.event = event


@dataclasses.dataclass
class WindowStats:
    """One scheduler observation window of streamed serving — the
    request-level analogue of ``adaptive.WaveResult``."""
    window: int
    n_containers: int
    wall_s: float
    energy_j: float
    n_requests: int
    n_tokens: int = 0
    tokens_per_s: float = 0.0
    ttfc_p50_s: float = 0.0       # time-to-first-chunk, median
    ttfc_p95_s: float = 0.0       # time-to-first-chunk, tail
    latency_p50_s: float = 0.0
    latency_p95_s: float = 0.0
    n_retries: int = 0            # re-dispatches after container failures
    n_failed: int = 0             # terminal FailedEvents in the window
    n_shed: int = 0               # admission rejections in the window
    prefix_hit_tokens: int = 0    # prompt tokens served from the prefix
                                  # cache instead of prefill (paged only)
    # per-SLO-class slice of the window (name -> workload.slo.ClassWindow
    # with tails + attainment); empty unless the Router runs with an
    # SLOSpec
    per_class: dict = dataclasses.field(default_factory=dict)


class CompletionHandle:
    """Live view of one submitted request. ``stream()`` yields the
    request's typed events as they arrive (pumping the router while it
    waits); ``result()`` drains the stream and returns the Completion —
    or raises ``RequestFailed``/``RequestRejected`` if the request ended
    without one."""

    def __init__(self, rid: int, router: "Router"):
        self.rid = rid
        self._router = router
        self._pending: deque[Event] = deque()
        self.completion: Completion | None = None
        self.failure: Any = None            # terminal Failed/RejectedEvent
        self.attempts: int = 0              # retries so far (0 = first try)
        self.ttfc_s: float | None = None    # submit → first ChunkEvent
        self.container_id: int | None = None  # where dispatch placed it
        self.done_at: float | None = None   # DoneEvent arrival stamp
        self.priority: str = "default"      # resolved SLO class name
        self.tenant: str = ""

    @property
    def done(self) -> bool:
        """The terminal event arrived at the router (it may still be
        waiting in this handle's queue for ``stream()`` to consume)."""
        return self.completion is not None or self.failure is not None

    def stream(self) -> Iterator[Event]:
        """Yield this request's events: ChunkEvents (and RetryEvents —
        discard accumulated chunks at each one), then exactly one
        terminal event. After yielding a DoneEvent it stops; after a
        FailedEvent/RejectedEvent it raises ``RequestFailed`` /
        ``RequestRejected`` — the terminal event is always *yielded
        first*, so event-driven consumers see it even if they stop
        iterating there. Raises RuntimeError if the router is closed
        mid-stream instead of blocking forever; a second stream() over a
        consumed handle yields nothing more (and re-raises for a failed
        request — the terminal state is kept on the handle)."""
        while True:
            while self._pending:
                ev = self._pending.popleft()
                yield ev
                if isinstance(ev, DoneEvent):
                    return
                if isinstance(ev, RejectedEvent):
                    raise RequestRejected(ev)
                if isinstance(ev, FailedEvent):
                    raise RequestFailed(ev)
            if self.completion is not None:
                return                 # already fully consumed
            if self.failure is not None:
                if isinstance(self.failure, RejectedEvent):
                    raise RequestRejected(self.failure)
                raise RequestFailed(self.failure)
            if self._router._closed:
                raise RuntimeError(
                    f"router closed while request {self.rid} was "
                    "mid-stream")
            self._router._pump(block=True)

    def result(self) -> Completion:
        """Block (pumping the router) until done; the Completion. Raises
        ``RequestFailed``/``RequestRejected`` on a failed request."""
        for _ in self.stream():
            pass
        assert self.completion is not None
        return self.completion

    def tokens(self) -> list[int]:
        """Convenience: the completion's tokens (drains the stream)."""
        return list(self.result().tokens)


class Router:
    """Continuous-admission facade over a ``ContainerBackend``.

    Fixed mode: pass ``backend``. Adaptive mode: pass ``backend_factory``
    (count -> backend) plus ``feasible_counts`` (and optionally a
    ``scheduler``/``objective``); the Router starts at the scheduler's
    pick and resizes between windows. Backends built by the factory are
    cached per count and stay warm across resizes; ``close()`` releases
    all of them.
    """

    def __init__(self, backend=None, *,
                 backend_factory: Callable[[int], Any] | None = None,
                 feasible_counts: Sequence[int] | None = None,
                 scheduler: DivideAndSaveScheduler | None = None,
                 objective: str = "energy",
                 epsilon: float = 0.0, seed: int = 0,
                 deadline_s: float | None = None,
                 window: int = 16,
                 window_s: float | None = None,
                 energy: EnergyProxy | None = None,
                 max_retries: int = 1,
                 request_deadline_s: float | None = None,
                 deadline_grace_s: float = 0.5,
                 max_queue: int | None = None,
                 shed_p95_s: float | None = None,
                 shed_window_s: float = 30.0,
                 slo: SLOSpec | None = None,
                 tenant_quota: int | None = None,
                 dispatch_depth: int = 4):
        if backend is None and backend_factory is None:
            raise ValueError("need a backend or a backend_factory")
        self.energy = energy or EnergyProxy()
        self.window = window
        # time-based window close (None = completion count only): sparse
        # traffic then still produces scheduler observations instead of
        # stalling adaptation below the count threshold forever
        self.window_s = window_s
        self.scheduler = scheduler
        # fault-tolerance knobs: bounded re-dispatch after container
        # failures, a default per-request deadline (``deadline_s`` above
        # is the *scheduler objective* constraint, a different thing),
        # the router-side backstop grace over engine-side expiry, and
        # the two admission-control thresholds
        self.max_retries = max_retries
        self.request_deadline_s = request_deadline_s
        self.deadline_grace_s = deadline_grace_s
        self.max_queue = max_queue
        self.shed_p95_s = shed_p95_s
        self.shed_window_s = shed_window_s
        # SLO mode (workload/slo.py): priority-ordered dispatch through a
        # router-side backlog (``dispatch_depth`` bounds backend-side
        # queueing so ordering happens HERE, where ranks exist), shed
        # thresholds derived per class, per-tenant in-flight quotas, and
        # per-class window stats
        self.slo = slo
        self.tenant_quota = tenant_quota
        self.dispatch_depth = dispatch_depth
        self._factory = backend_factory
        self._backends: dict[int, Any] = {}
        if backend_factory is not None:
            if scheduler is None:
                if not feasible_counts:
                    raise ValueError(
                        "adaptive mode needs feasible_counts (or an "
                        "explicit scheduler)")
                slo_kw = ({"objective": "energy_under_slo",
                           "slo_ttfc_p95_s": slo.constraint.ttfc_p95_s}
                          if slo is not None
                          and objective == "energy_under_slo"
                          else {"objective": objective})
                self.scheduler = DivideAndSaveScheduler(
                    list(feasible_counts),
                    deadline_s=deadline_s, epsilon=epsilon, seed=seed,
                    **slo_kw)
            n0 = self.scheduler.pick()
            backend = self._backend_for(n0)
        self.backend = backend
        self._closed = False
        self._handles: dict[int, CompletionHandle] = {}
        self._rid_cid: dict[int, int] = {}
        self._requests: dict[int, Request] = {}   # for re-dispatch
        self._submit_t: dict[int, float] = {}
        self._deadline_abs: dict[int, float] = {}  # router backstop clock
        # priority backlog (SLO mode): (rank, submit seq, rid) heap of
        # registered-but-undispatched requests; entries whose rid left
        # ``_handles`` (terminal) or entered ``_rid_cid`` (placed) are
        # skipped lazily
        self._backlog: list[tuple[int, int, int]] = []
        self._subseq = 0
        self._tenants: Counter = Counter()      # in-flight per tenant
        # per-container multiset of in-flight admission buckets (the
        # bucket-aware half of dispatch)
        self._cid_buckets: list[Counter] = [Counter()
                                            for _ in range(backend.capacity)]
        self.history: list[WindowStats] = []
        self.container_failures: list[ContainerFailure] = []
        self.retry_total = 0
        self.failed_total = 0
        self.shed_total = 0
        # always-on ttfc tail sample for the shed threshold (the window
        # accumulators only run under a scheduler). Entries are
        # (stamp, seconds) so the shed check can age out samples older
        # than shed_window_s — a p95 frozen on a past spike would keep
        # shedding forever after the overload drains
        self._recent_ttfc: deque[tuple[float, float]] = deque(maxlen=64)
        # per-class tail samples (SLO mode): each class sheds against its
        # OWN recent p95, so one class's blown tail cannot shed another's
        self._recent_ttfc_cls: dict[str, deque] = defaultdict(
            lambda: deque(maxlen=64))
        self._target_n: int | None = None    # resize awaiting a drain
        # SLO mode: the first window after a resize is a warm-up — its
        # (loss-censored) tail describes the transition, not the new
        # count — recorded in history but withheld from the scheduler
        self._warmup_window = False
        self._new_window()

    # -- plumbing -------------------------------------------------------
    def _backend_for(self, n: int):
        if n not in self._backends:
            assert self._factory is not None
            self._backends[n] = self._factory(n)
        return self._backends[n]

    def _new_window(self) -> None:
        self._window_t0 = time.perf_counter()
        self._window_stats0 = [self.backend.stats(cid)
                               for cid in range(self.backend.capacity)]
        self._window_done: list[Completion] = []
        self._window_ttfc: list[float] = []
        self._window_retries = 0
        self._window_failed = 0
        self._window_shed = 0
        # per-SLO-class accumulators (only filled in scheduler mode, like
        # _window_done — a fixed router must stay O(1) per request)
        self._window_cls: dict[str, dict] = defaultdict(
            lambda: {"ttfc": [], "lat": [], "shed": 0, "failed": 0})

    @property
    def in_flight(self) -> int:
        return len(self._handles)

    @property
    def n_containers(self) -> int:
        return self.backend.capacity

    # -- admission ------------------------------------------------------
    def _alive_cids(self) -> list[int]:
        """Containers the backend reports ``alive`` (discovered with
        getattr — structural test backends without a supervision surface
        count as all-alive)."""
        alive = getattr(self.backend, "alive", None)
        return [cid for cid in range(self.backend.capacity)
                if alive is None or alive(cid)]

    def _dispatch(self, req: Request,
                  max_load: int | None = None) -> int | None:
        """Pick a container: least-loaded, ties toward a bucket hit.
        None if every container is dead/respawning — or, with
        ``max_load`` (the SLO backlog's bounded-depth dispatch), if
        every live container already holds that many requests: the
        request then stays in the priority backlog instead of burying
        rank order inside a container's FIFO."""
        cids = self._alive_cids()
        if not cids:
            return None
        load = self.backend.load
        if max_load is not None:
            cids = [cid for cid in cids if load(cid) < max_load]
            if not cids:
                return None
        bucket = _bucket(len(req.prompt))

        def key(cid: int):
            return (load(cid),
                    0 if self._cid_buckets[cid][bucket] else 1,
                    cid)
        cid = min(cids, key=key)
        self._cid_buckets[cid][bucket] += 1
        return cid

    def note_ttfc(self, seconds: float, at: float | None = None,
                  priority: str = "default") -> None:
        """Record one time-to-first-chunk sample for the shed-threshold
        p95 (stamped now unless ``at`` is given — tests inject history
        through here rather than poking the deque's tuple layout)."""
        stamp = time.perf_counter() if at is None else at
        self._recent_ttfc.append((stamp, seconds))
        if self.slo is not None:
            self._recent_ttfc_cls[priority].append((stamp, seconds))

    @staticmethod
    def _aged_p95(samples: deque, horizon: float) -> float | None:
        """p95 over a (stamp, value) deque after aging out entries older
        than ``horizon``: a ttfc spike must stop tripping the threshold
        once it leaves the window, or one past burst sheds traffic
        forever after recovery. None below 8 samples (too noisy)."""
        while samples and samples[0][0] < horizon:
            samples.popleft()
        if len(samples) < 8:
            return None
        _, p95 = percentiles([v for _, v in samples])
        return p95

    def _shed_reason(self, req: Request,
                     cls=None) -> tuple[str, str] | None:
        """(kind, reason) when admission should shed ``req``, else None.
        kind ∈ {"tenant", "queue", "slo"} — with an SLO class the queue
        bound and the ttfc threshold are the *class's* (lower-rank
        classes get a smaller queue slice and their own tail sample), so
        batch overload cannot starve interactive admission."""
        if (self.tenant_quota is not None and req.tenant
                and self._tenants[req.tenant] >= self.tenant_quota):
            return ("tenant",
                    f"tenant {req.tenant!r} at quota: "
                    f"{self._tenants[req.tenant]} in flight >= "
                    f"tenant_quota={self.tenant_quota}")
        if self.max_queue is not None:
            limit = (queue_limit(cls, self.max_queue)
                     if cls is not None else self.max_queue)
            if len(self._handles) >= limit:
                extra = (f" (class {cls.name!r} share of "
                         f"max_queue={self.max_queue})" if cls is not None
                         and limit != self.max_queue else "")
                return ("queue",
                        f"queue full: {len(self._handles)} in flight >= "
                        f"{limit}{extra}")
        threshold = (shed_ttfc_threshold(cls, self.shed_p95_s)
                     if cls is not None else self.shed_p95_s)
        if threshold is not None:
            horizon = time.perf_counter() - self.shed_window_s
            samples = (self._recent_ttfc_cls[cls.name]
                       if cls is not None else self._recent_ttfc)
            p95 = self._aged_p95(samples, horizon)
            if p95 is not None and p95 > threshold:
                scope = (f"class {cls.name!r} " if cls is not None else "")
                return ("slo",
                        f"{scope}ttfc p95 {p95:.3f}s over shed threshold "
                        f"{threshold:g}s")
        return None

    def _retry_after_hint(self) -> float:
        """Backpressure hint for shed requests: roughly one median
        request latency (the shortest wait after which the picture can
        have changed), floored so clients cannot hot-loop."""
        if self.history and self.history[-1].latency_p50_s > 0:
            return max(0.05, self.history[-1].latency_p50_s)
        return 0.25

    def _terminal_handle(self, req: Request, ev: Any) -> CompletionHandle:
        """A handle born terminal (shed, or nowhere to dispatch): never
        registered in ``_handles``, its single event already pending."""
        handle = CompletionHandle(req.rid, self)
        handle.failure = ev
        handle._pending.append(ev)
        return handle

    def submit(self, req: Request) -> CompletionHandle:
        """Admit one request now; returns immediately with a handle whose
        ``stream()`` yields the request's events. Under overload the
        handle may come back already shed (its stream yields one
        ``RejectedEvent`` and raises ``RequestRejected``). With an
        ``SLOSpec`` the request enters a rank-ordered backlog instead of
        going straight to a container queue: dispatch happens in
        priority order as containers free up below ``dispatch_depth``."""
        if self._closed:
            raise RuntimeError("router is closed")
        if req.rid in self._handles:
            raise ValueError(f"request id {req.rid} is already in flight")
        now = time.perf_counter()
        cls = self.slo.cls(req.priority) if self.slo is not None else None
        shed = self._shed_reason(req, cls)
        if shed is not None:
            kind, reason = shed
            self.shed_total += 1
            self._window_shed += 1
            pri = cls.name if cls is not None else "default"
            if self.scheduler is not None and self.slo is not None:
                self._window_cls[pri]["shed"] += 1
            return self._terminal_handle(req, RejectedEvent(
                req.rid, reason, self._retry_after_hint(), now,
                kind=kind, priority=pri))
        if req.deadline_s is None and self.request_deadline_s is not None:
            req = dataclasses.replace(
                req, deadline_s=self.request_deadline_s)
        handle = CompletionHandle(req.rid, self)
        if cls is not None:
            handle.priority = cls.name
            handle.tenant = req.tenant
        if self.slo is None:
            # non-SLO path: dispatch immediately (unchanged behaviour)
            cid = self._dispatch(req)
            if cid is None:
                self.failed_total += 1
                self._window_failed += 1
                return self._terminal_handle(req, FailedEvent(
                    req.rid, -1, "container",
                    "no healthy container to dispatch to "
                    "(all circuit-broken or respawning)", now))
            handle.container_id = cid
            self._rid_cid[req.rid] = cid
        self._handles[req.rid] = handle
        self._requests[req.rid] = req
        self._submit_t[req.rid] = now
        if req.deadline_s is not None:
            self._deadline_abs[req.rid] = now + req.deadline_s
        if self.slo is None:
            self.backend.submit(handle.container_id, req)
        else:
            if req.tenant:
                self._tenants[req.tenant] += 1
            heapq.heappush(self._backlog,
                           (cls.rank, self._subseq, req.rid))
            self._subseq += 1
            self._drain_backlog()
        return handle

    def _drain_backlog(self) -> None:
        """Dispatch backlog entries in (rank, arrival) order while a
        live container has load below ``dispatch_depth``. Entries whose
        rid already left ``_handles`` (terminal: deadline backstop,
        cancel) or entered ``_rid_cid`` (already placed) are lazy-
        deleted. Stops at the first undispatchable entry — skipping
        past it would invert priority order."""
        while self._backlog:
            rank, seq, rid = self._backlog[0]
            handle = self._handles.get(rid)
            if handle is None or rid in self._rid_cid:
                heapq.heappop(self._backlog)
                continue
            req = self._requests[rid]
            cid = self._dispatch(req, max_load=self.dispatch_depth)
            if cid is None:
                if not self._alive_cids():
                    # nothing to ever dispatch to: fail rather than
                    # strand the backlog behind dead containers
                    heapq.heappop(self._backlog)
                    self._fail_request(
                        rid, "container",
                        "no healthy container to dispatch to "
                        "(all circuit-broken or respawning)")
                    continue
                break                  # all live containers at depth
            heapq.heappop(self._backlog)
            handle.container_id = cid
            self._rid_cid[rid] = cid
            self.backend.submit(cid, req)

    # -- event pump -----------------------------------------------------
    def _pump(self, block: bool = False) -> list[Event]:
        """Advance the backend and route its events to handles —
        including ``ContainerFailure`` records (retry/fail the lost
        requests) and the router-side deadline backstop. With ``block``
        and nothing to route, naps briefly so process-backend waits
        don't spin."""
        events = self.backend.poll()
        now = time.perf_counter()
        for ev in events:
            if isinstance(ev, ContainerFailure):
                self._on_container_failure(ev)
                continue
            handle = self._handles.get(ev.rid)
            if handle is None:          # stale event for a dropped handle
                continue
            cid = getattr(ev, "container_id", None)
            if cid is not None and cid != self._rid_cid.get(ev.rid):
                # stale event from an abandoned incarnation: the request
                # was re-dispatched elsewhere after a container failure,
                # and the old container's late chunks/terminals must not
                # leak into the retried stream (a stale DoneEvent would
                # even pop the router backstop while the live incarnation
                # is still running)
                continue
            handle._pending.append(ev)
            if isinstance(ev, ChunkEvent) and handle.ttfc_s is None:
                handle.ttfc_s = now - self._submit_t[ev.rid]
                self.note_ttfc(handle.ttfc_s, at=now,
                               priority=handle.priority)
            elif isinstance(ev, DoneEvent):
                self._on_done(handle, ev)
            elif isinstance(ev, FailedEvent):
                # engine-side terminal (deadline expired inside the
                # container, resources already freed there): the event is
                # in the handle's queue, just release the router's state
                self._forget(ev.rid)
                handle.failure = ev
                self.failed_total += 1
                self._window_failed += 1
                if self.scheduler is not None and self.slo is not None:
                    self._window_cls[handle.priority]["failed"] += 1
        self._expire_deadlines(now)
        if self.slo is not None:
            # completions freed container slots; pull the backlog forward
            self._drain_backlog()
        if self.scheduler is not None:
            self._maybe_rotate_window()
        if block and not events:
            time.sleep(_IDLE_SLEEP_S)
        return events

    def poll(self) -> list[Event]:
        """Public pump: advance containers, route events, return the
        routed batch (a tap — the events still reach their handles)."""
        return self._pump(block=False)

    def _forget(self, rid: int) -> None:
        """Release every router-side record of ``rid`` (the handle's
        terminal state is the caller's to set). Backlog entries are
        lazy-deleted (``_drain_backlog`` skips rids no longer in
        ``_handles``)."""
        cid = self._rid_cid.pop(rid, None)
        req = self._requests.pop(rid, None)
        if cid is not None and req is not None:
            self._cid_buckets[cid][_bucket(len(req.prompt))] -= 1
        handle = self._handles.pop(rid, None)
        if handle is not None and handle.tenant:
            self._tenants[handle.tenant] -= 1
            if self._tenants[handle.tenant] <= 0:
                del self._tenants[handle.tenant]
        self._submit_t.pop(rid, None)
        self._deadline_abs.pop(rid, None)

    def _fail_request(self, rid: int, kind: str, reason: str) -> None:
        """Terminal FailedEvent for an in-flight request (router-side
        origin: retries exhausted, backstop deadline, cancel)."""
        handle = self._handles.get(rid)
        cid = self._rid_cid.get(rid, -1)
        self._forget(rid)
        if handle is None:
            return
        ev = FailedEvent(rid, cid if cid is not None else -1, kind,
                         reason, time.perf_counter())
        handle.failure = ev
        handle._pending.append(ev)
        self.failed_total += 1
        self._window_failed += 1
        if self.scheduler is not None and self.slo is not None:
            self._window_cls[handle.priority]["failed"] += 1

    def _expire_deadlines(self, now: float) -> None:
        """Authoritative deadline backstop: the engine expires deadlines
        itself (that frees slots/blocks exactly where they live), but a
        dead, hung or reply-dropping container can't — so past the grace
        the router cancels backend-side and fails the request here."""
        if not self._deadline_abs:
            return
        expired = [rid for rid, t in self._deadline_abs.items()
                   if now > t + self.deadline_grace_s]
        for rid in expired:
            cid = self._rid_cid.get(rid)
            cancel = getattr(self.backend, "cancel", None)
            if cancel is not None and cid is not None:
                cancel(cid, rid)
            self._fail_request(
                rid, "deadline",
                "deadline exceeded (router backstop, "
                f"{self.deadline_grace_s:g}s past the engine's own expiry)")

    def _on_container_failure(self, fail: ContainerFailure) -> None:
        """Re-dispatch (bounded) or fail every request lost with a
        container. The dead container's bucket counters for these rids
        are released; requests that still fit their deadline go to the
        least-loaded healthy container with a RetryEvent in the stream
        and their *remaining* deadline budget."""
        self.container_failures.append(fail)
        reason = fail.message.splitlines()[0]
        for rid in fail.lost_rids:
            handle = self._handles.get(rid)
            if handle is None:
                continue
            req = self._requests.get(rid)
            old_cid = self._rid_cid.pop(rid, None)
            if old_cid is not None and req is not None:
                self._cid_buckets[old_cid][_bucket(len(req.prompt))] -= 1
            now = time.perf_counter()
            deadline_abs = self._deadline_abs.get(rid)
            handle.attempts += 1
            if req is None:
                self._fail_request(rid, "container",
                                   f"lost to {reason}; request body "
                                   "unknown (cannot re-dispatch)")
                continue
            if deadline_abs is not None and now >= deadline_abs:
                self._fail_request(rid, "deadline",
                                   f"deadline expired while lost to "
                                   f"{reason}")
                continue
            if handle.attempts > self.max_retries:
                self._fail_request(
                    rid, "container",
                    f"retries exhausted after {handle.attempts} attempts; "
                    f"last failure: {fail.message}")
                continue
            cid = self._dispatch(req)
            if cid is None:
                self._fail_request(
                    rid, "container",
                    f"no healthy container left to retry on; "
                    f"last failure: {fail.message}")
                continue
            self._rid_cid[rid] = cid
            handle.container_id = cid
            if deadline_abs is not None:
                # re-arm the router backstop for the new incarnation: the
                # first incarnation's terminal may already have popped
                # _deadline_abs, and a retry onto a reply-dropping
                # container would otherwise hang with only the engine's
                # (unreachable) expiry guarding it
                self._deadline_abs[rid] = deadline_abs
            self.retry_total += 1
            self._window_retries += 1
            handle._pending.append(RetryEvent(
                rid, cid, handle.attempts, reason, now))
            resubmit = req
            if deadline_abs is not None:
                # the retry inherits the REMAINING budget, not a fresh
                # deadline — end-to-end means across attempts
                resubmit = dataclasses.replace(
                    req, deadline_s=deadline_abs - now)
            try:
                self.backend.submit(cid, resubmit)
            except RuntimeError as e:
                self._fail_request(rid, "container",
                                   f"re-dispatch to container {cid} "
                                   f"failed: {e}")

    def _on_done(self, handle: CompletionHandle, ev: DoneEvent) -> None:
        comp = ev.completion
        handle.completion = comp
        handle.done_at = time.perf_counter()
        submit_t = self._submit_t.get(handle.rid)
        self._forget(handle.rid)
        if self.scheduler is not None:
            # window accumulators only exist to feed the scheduler; a
            # fixed-capacity router must not retain one Completion per
            # request forever (the lists are only reset at rotation)
            self._window_done.append(comp)
            if handle.ttfc_s is not None:
                self._window_ttfc.append(handle.ttfc_s)
            if self.slo is not None:
                acc = self._window_cls[handle.priority]
                if handle.ttfc_s is not None:
                    acc["ttfc"].append(handle.ttfc_s)
                if submit_t is not None:
                    acc["lat"].append(handle.done_at - submit_t)

    def cancel(self, rid: int, reason: str = "cancelled by caller") -> bool:
        """Cancel an in-flight request: backend-side removal (slot and
        paged blocks freed via the engine's cancel path) plus a terminal
        ``FailedEvent(kind="cancelled")`` on the handle. Returns whether
        the request was still in flight."""
        if rid not in self._handles:
            return False
        cid = self._rid_cid.get(rid)
        cancel = getattr(self.backend, "cancel", None)
        if cancel is not None and cid is not None:
            cancel(cid, rid)
        self._fail_request(rid, "cancelled", reason)
        return True

    def drain(self) -> None:
        """Pump until every in-flight request reached a terminal event
        (their handles still hold any unconsumed events). Failed
        requests leave ``_handles`` too, so a drain over failures
        terminates instead of hanging."""
        while self._handles:
            self._pump(block=True)

    # -- windowed adaptation -------------------------------------------
    def _maybe_rotate_window(self) -> None:
        """Sliding-window adaptation, split in two so continuous traffic
        still adapts: the *stats window* closes on completion count — or,
        with ``window_s``, on elapsed wall time, so sparse traffic still
        produces scheduler observations instead of stalling adaptation
        below the count threshold forever — while the *backend swap*
        waits for the stream to drain (resizing under a live request
        would strand its slot). A time-expired window with zero
        completions just restarts its clock: observing it would feed the
        scheduler an all-idle sample with no latency content."""
        time_up = (self.window_s is not None
                   and time.perf_counter() - self._window_t0
                   >= self.window_s)
        if len(self._window_done) >= self.window:
            self._observe_window()
        elif time_up:
            if self._window_done:
                self._observe_window()
            else:
                self._new_window()       # idle window: restart the clock
        if self._target_n is None or self._handles:
            return
        if self._target_n != self.backend.capacity \
                and self._factory is not None:
            if self._window_done:
                # the partial window ran entirely on the outgoing
                # backend; record it before its stats0 go stale
                self._observe_window(repick=False)
            self.backend = self._backend_for(self._target_n)
            self._cid_buckets = [Counter()
                                 for _ in range(self.backend.capacity)]
            # shed-threshold tails described the OUTGOING backend; kept
            # across the resize they would shed (and loss-censor) the new
            # count's first windows and brand it infeasible forever
            self._recent_ttfc.clear()
            self._recent_ttfc_cls.clear()
            # SLO mode only: mean observations average a transition
            # away, but one loss-censored tail sample from the swap
            # window can brand the incoming count infeasible
            self._warmup_window = self.slo is not None
            self._new_window()
        self._target_n = None

    def _observe_window(self, repick: bool = True) -> None:
        n = self.backend.capacity
        wall = time.perf_counter() - self._window_t0
        busy = [self.backend.stats(cid)[0] - self._window_stats0[cid][0]
                for cid in range(n)]
        toks = sum(self.backend.stats(cid)[1] - self._window_stats0[cid][1]
                   for cid in range(n))
        energy_j = sum(self.energy.container_energy(wall, b, n)
                       for b in busy)
        ttfc50, ttfc95 = percentiles(self._window_ttfc)
        lat50, lat95 = latency_percentiles(self._window_done)
        per_class: dict = {}
        if self.slo is not None:
            per_class = {
                name: class_window(self.slo.cls(name), name,
                                   acc["ttfc"], acc["lat"],
                                   acc["shed"], acc["failed"])
                for name, acc in sorted(self._window_cls.items())}
        self.history.append(WindowStats(
            len(self.history), n, wall, energy_j, len(self._window_done),
            toks, toks / wall if wall > 0 else 0.0, ttfc50, ttfc95,
            lat50, lat95, n_retries=self._window_retries,
            n_failed=self._window_failed, n_shed=self._window_shed,
            prefix_hit_tokens=sum(getattr(c, "prefix_hit_tokens", 0)
                                  for c in self._window_done),
            per_class=per_class))
        assert self.scheduler is not None
        if self._warmup_window:
            # transition window (see __init__): keep the stats, withhold
            # the scheduler observation and keep the current pick
            self._warmup_window = False
            self._new_window()
            return
        done = len(self._window_done)
        scale = 1.0
        if self.window_s is not None and 0 < done < self.window:
            # time-closed short window: normalise wall/energy to the
            # canonical window size so observations stay comparable
            # across sparse and busy windows (per-request cost is the
            # quantity the convex fit models)
            scale = self.window / done
        # the scheduler's tail sample is the CONSTRAINT class's p95 (the
        # tightest target — that is what energy_under_slo guards),
        # shed-censored: admission pins the admitted p95 at the shed
        # threshold, so shed arrivals must count as violations or every
        # count looks feasible. Overall window p95 when no SLO is set
        q95: float | None = ttfc95 if self._window_ttfc else None
        if self.slo is not None:
            cname = self.slo.constraint.name
            acc = self._window_cls.get(cname)
            if acc is not None:
                q95 = censored_ttfc_p95(
                    acc["ttfc"], acc["shed"] + acc["failed"],
                    2.0 * self.slo.constraint.ttfc_p95_s)
        self.scheduler.observe(n, wall * scale, energy_j * scale,
                               ttfc_p95_s=q95)
        if repick:
            self._target_n = self.scheduler.pick()
        self._new_window()

    @property
    def choice(self) -> int:
        """Exploitation-only container count (what a converged deployment
        runs); only meaningful in adaptive mode."""
        assert self.scheduler is not None
        return self.scheduler.best()

    # -- wave shim ------------------------------------------------------
    def serve_wave(self, requests: list[Request]
                   ) -> tuple[list[Completion], list[ContainerResult],
                              float, float]:
        """The legacy wave API on top of streaming: submit-all + drain,
        per-container accounting reconstructed with the existing
        ``assemble_wave``. Completions come back in submission order."""
        _warn_wave_shim("Router.serve_wave")
        # pin the backend for the whole wave: an adaptive window boundary
        # inside drain() may swap self.backend, and this wave's stats
        # deltas must come from the backend that served it
        backend = self.backend
        stats0 = [backend.stats(cid) for cid in range(backend.capacity)]
        t0 = time.perf_counter()
        handles = [self.submit(r) for r in requests]
        self.drain()
        wall = time.perf_counter() - t0
        failed = [h.rid for h in handles if h.completion is None]
        if failed:
            # waves have no per-request failure surface: a request that
            # ended in a FailedEvent (even after retries) fails the wave
            raise RuntimeError(
                f"wave failed: requests {failed} ended without a "
                "completion (see router.container_failures)")
        capacity = backend.capacity
        segments: list[list[Request]] = [[] for _ in range(capacity)]
        comps: list[list[Completion]] = [[] for _ in range(capacity)]
        # _rid_cid entries are popped on completion; reconstruct the
        # dispatch segments from the handles' completions instead
        by_rid = {h.rid: h.completion for h in handles}
        # per-container wall: submit → last DoneEvent arrival for that
        # container (matching the pool contract, where a fast container
        # reports its own wall, not the slowest sibling's)
        last = [0.0] * capacity
        for r, h in zip(requests, handles):
            cid = h.container_id
            segments[cid].append(r)
            comps[cid].append(by_rid[r.rid])
            if h.done_at is not None:
                last[cid] = max(last[cid], h.done_at - t0)
        out = [(comps[cid], last[cid],
                backend.stats(cid)[0] - stats0[cid][0],
                backend.stats(cid)[1] - stats0[cid][1])
               for cid in range(capacity)]
        _, results, energy = assemble_wave(out, segments, wall, self.energy)
        ordered = [by_rid[r.rid] for r in requests]
        return ordered, results, wall, energy

    def serve(self, requests: list[Request]
              ) -> tuple[list[Completion], list[ContainerResult]]:
        ordered, results, _, _ = self.serve_wave(requests)
        return ordered, results

    # -- lifecycle ------------------------------------------------------
    def close(self) -> None:
        """Close the backend (and every cached adaptive backend). Handles
        still mid-stream raise rather than hang."""
        if self._closed:
            return
        self._closed = True
        backends = set(self._backends.values()) | {self.backend}
        for b in backends:
            b.close()
        self._backends = {}

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
