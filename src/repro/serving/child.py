"""The process container's child body — kept import-light on purpose.

``spawn_pinned`` (core/testbed.py) promises the child applies its cpuset
BEFORE jax can initialise, so XLA's threadpool is sized from the
container's cores rather than the whole host. That promise is only as
good as the spawn payload: multiprocessing's spawn start method pickles
the child target *by reference* (module + qualname), and unpickling it
at child bootstrap imports that module — before ``_pinned_main`` runs
``sched_setaffinity``. The child body therefore cannot live in
``serving/backend.py`` (whose module scope imports the engine, hence
jax); it lives here, in a module whose import closure is stdlib + numpy
+ the wire dataclasses (events/faults/configs). ``repro.analysis.wire``
enforces this transitively — a module-scope jax import added anywhere
under this module's closure fails the static-analysis gate.

Everything heavy (jax, the model, the engine) is imported inside
``_serving_child`` itself, after affinity.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time

import numpy as np

_IDLE_POLL_S = 0.05


def _load_params(model, path: str):
    import jax
    struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    treedef = jax.tree_util.tree_structure(struct)
    with np.load(path) as z:
        leaves = [z[f"leaf{i}"] for i in range(len(z.files))]
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclasses.dataclass(frozen=True)
class SharedParams:
    """Picklable descriptor of a ``multiprocessing.shared_memory`` params
    block: children attach by name and view each leaf at its offset —
    one parent-side copy total, no filesystem round-trip (the ROADMAP's
    leftover from the ``.npz`` handoff, which writes and re-reads every
    byte per child)."""
    shm_name: str
    specs: tuple                  # ((shape, dtype_str, offset), ...)
    nbytes: int


def _load_params_shm(model, handle: SharedParams):
    """Child-side loader: attach, view each leaf, copy onto the device
    (``jnp.asarray``), detach. The segment outlives the view copies only
    in the parent, which owns the unlink."""
    import jax
    import jax.numpy as jnp
    from multiprocessing import shared_memory
    # NOTE on lifetime: spawn children inherit the parent's resource
    # tracker, so this attach registers a duplicate no-op and the parent
    # keeps sole ownership of the unlink (ParamsShare.close).
    shm = shared_memory.SharedMemory(name=handle.shm_name)
    try:
        leaves = []
        for shape, dtype, off in handle.specs:
            view = np.ndarray(shape, dtype=dtype, buffer=shm.buf, offset=off)
            # jnp.array(copy=True): jax on CPU may alias a numpy buffer
            # zero-copy, and an alias into the segment would dangle the
            # moment it is unmapped below
            leaves.append(jnp.array(view, copy=True))
        for leaf in leaves:
            leaf.block_until_ready()
    finally:
        shm.close()
    struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    treedef = jax.tree_util.tree_structure(struct)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _serving_child(conn, cid: int, cfg, params_seed: int,
                   params_path: str | None, params_shm,
                   engine_kw: dict, incarnation: int = 0,
                   fault_plan=None, heartbeat_s: float = 0.0) -> None:
    """Container body (module-level: spawn pickles it by reference).
    Affinity was already applied by ``spawn_pinned``; the jax import below
    therefore sizes XLA's threadpool from the container's cpuset.
    ``engine_kw`` is ``_engine_config_wire`` output — one EngineConfig,
    primitives only.

    Streaming protocol: ``("submit", [Request...])`` enqueues,
    ``("cancel", rid)`` removes one request (queued or mid-decode);
    after every engine macro-step (and after zero-budget submissions,
    which complete instantly) the child flushes ``("events", [Event...],
    busy_s, tokens_generated)``. With ``heartbeat_s`` a daemon thread
    also sends ``("hb",)`` on that period, so the parent can tell a slow
    child (heartbeats flowing, no events) from a hung one (silence). The
    pipe is checked between steps, so a ``("close",)`` lands promptly
    even mid-stream.

    Exits are classified (EXIT_* in serving/faults.py) so the parent's
    ``ContainerFailure`` message can say *why* from the exitcode alone:
    startup failures, a lost reply pipe and engine-step errors each get
    a distinct nonzero code instead of the silent exit-0 they used to
    share with clean shutdown."""
    import sys
    import traceback

    from repro.serving.faults import (EXIT_FAULT_KILL, EXIT_PIPE_LOST,
                                      EXIT_STARTUP, EXIT_STEP_ERROR,
                                      FaultInjector, InjectedFault)
    send_lock = threading.Lock()

    def send(msg) -> None:
        # the heartbeat thread and the serve loop share the pipe; Linux
        # pipe writes interleave at message granularity only under a lock
        with send_lock:
            conn.send(msg)

    try:
        import jax

        from repro.models.model import Model
        from repro.serving.engine import EngineConfig, ServingEngine

        model = Model(cfg)
        if params_shm is not None:
            params = _load_params_shm(model, params_shm)
        elif params_path:
            params = _load_params(model, params_path)
        else:
            params = model.init(jax.random.PRNGKey(params_seed))
        engine = ServingEngine(model, params, EngineConfig(**engine_kw))
        # events cross the pipe as-is: the child must stamp the parent's
        # container id or every child would claim container 0
        engine.container_id = cid
        inj = FaultInjector(fault_plan, cid, incarnation)
        engine.fault = inj if inj.armed else None
        buf: list = []
        engine.on_event = buf.append
        try:
            cores = sorted(os.sched_getaffinity(0))
        except AttributeError:              # non-Linux dev host
            cores = []
        send(("ready", cores))
    except BaseException:
        try:
            send(("error", traceback.format_exc()))
        except Exception:
            pass
        sys.exit(EXIT_STARTUP)
    if heartbeat_s > 0:
        hb_stop = threading.Event()

        def _heartbeat() -> None:
            while not hb_stop.wait(heartbeat_s):
                try:
                    send(("hb",))
                except Exception:
                    return              # pipe gone: main loop exits too

        threading.Thread(target=_heartbeat, daemon=True,
                         name=f"hb-{cid}").start()
    while True:
        try:
            if buf:
                if inj.armed and inj.drop_reply():
                    buf.clear()         # injected reply loss
                    engine.done.clear()
                else:
                    delay = inj.reply_delay() if inj.armed else 0.0
                    if delay > 0:
                        time.sleep(delay)
                    send(("events", list(buf), engine.busy_s,
                          engine.tokens_generated))
                    buf.clear()
                    # DoneEvents carry the completions; nobody calls
                    # run() here, so drain the engine's done list or it
                    # grows without bound across a long-lived stream
                    engine.done.clear()
            timeout = 0 if engine.has_work else _IDLE_POLL_S
            if conn.poll(timeout):
                msg = conn.recv()
                if msg[0] == "close":
                    conn.close()
                    return
                if msg[0] == "submit":
                    engine.submit_many(msg[1])
                    continue               # flush instant completions
                if msg[0] == "cancel":
                    engine.cancel(msg[1])
                    continue
            if engine.has_work:
                engine.step()
        except InjectedFault as e:
            if e.fault.kind == "kill":
                os._exit(EXIT_FAULT_KILL)  # a real crash: no cleanup
            try:
                send(("error", traceback.format_exc()))
            except Exception:
                pass
            sys.exit(EXIT_STEP_ERROR)
        except (EOFError, BrokenPipeError):  # parent died / closed
            sys.exit(EXIT_PIPE_LOST)
        except SystemExit:
            raise
        except BaseException:
            # engine state after an arbitrary step error is not
            # trustworthy — report and exit so the parent respawns a
            # clean incarnation (the old loop kept serving on it)
            try:
                send(("error", traceback.format_exc()))
            except (BrokenPipeError, OSError):
                sys.exit(EXIT_PIPE_LOST)
            sys.exit(EXIT_STEP_ERROR)
