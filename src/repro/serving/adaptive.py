"""Adaptive serving: the paper's online scheduler closed over the pool.

The paper's conclusion calls for "energy-efficient job schedulers that
split input data, obtaining the optimal number of containers in an online
fashion". ``AdaptiveServingPool`` is that loop: traffic arrives in waves;
each wave is served by a ``ContainerServingPool`` factored to the count the
``DivideAndSaveScheduler`` picked, the wave's measured ``(n, wall, energy)``
lands back in the scheduler, and the next wave is re-factored to the new
``pick()`` — restricted to the feasible counts from ``core/containers.py``
(memory bounds the factorisation search, as it capped the paper's TX2 at 6
containers).

Pools are cached per count, so converging traffic stops paying refactor
cost: once the scheduler settles, every wave reuses the same engines and
their compiled executables. With ``isolation="process"`` the cached pools
are ``ProcessContainerPool``s: each count keeps its pinned child processes
warm (spawn + compile paid once per count, at first probe), which is what
makes real OS-level CPU shares affordable inside an online loop. With ``submesh_devices`` set, each count's pool
places its engines on disjoint device sub-meshes
(``launch/mesh.make_container_meshes``) — re-placing engines when the
scheduler changes n is then just a pool-cache lookup: the params were
device_put onto each count's slices once, at that pool's construction.
Every cached pool keeps its placed replicas resident, though, and
``core/containers.feasible_counts`` budgets a SINGLE placement — for
models near the HBM limit bound the cache with ``max_cached_pools`` (LRU
eviction drops the stalest pool's placements; re-probing that count later
pays one fresh placement, which exploration does rarely by design).

``SyntheticContainerPool`` is the simulator counterpart (paper §VI): a
pool whose time/energy come from closed-form profiles instead of a device,
used to exercise the scheduler loop deterministically in tests and in
``benchmarks/pool_scaling.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

from repro.core.scheduler import DivideAndSaveScheduler, Objective
from repro.models.model import Model
from repro.serving.engine import Completion, Request
from repro.serving.pool import (ContainerResult, ContainerServingPool,
                                latency_percentiles)


@dataclasses.dataclass
class WaveResult:
    wave: int
    n_containers: int
    wall_s: float
    energy_j: float
    n_requests: int
    n_tokens: int = 0             # tokens emitted across the wave
    tokens_per_s: float = 0.0     # wave decode throughput
    latency_p50_s: float = 0.0    # median completion latency in the wave
    latency_p95_s: float = 0.0    # tail completion latency in the wave


class AdaptiveServingPool:
    """Serve waves of requests, learning the optimal container count."""

    def __init__(self, model: Model | None, params: Any,
                 feasible_counts: Sequence[int],
                 objective: Objective = "energy",
                 deadline_s: float | None = None,
                 epsilon: float = 0.0, seed: int = 0,
                 n_slots_per_container: int = 4, max_len: int = 512,
                 concurrent: bool = True,
                 scheduler: DivideAndSaveScheduler | None = None,
                 pool_factory: Callable[[int], Any] | None = None,
                 submesh_devices: int | None = None,
                 max_cached_pools: int | None = None,
                 isolation: str = "thread",
                 total_cores: int | None = None,
                 params_seed: int = 0,
                 allow_shared_cores: bool = False):
        """``submesh_devices``: factorise this many devices into disjoint
        per-container sub-meshes for every count the scheduler may pick
        (each count must divide it — use power-of-two feasible counts).
        ``max_cached_pools``: LRU-bound the per-count pool cache (each
        cached pool pins a full set of placed param replicas — or, for
        process isolation, a full set of warm child processes; evicted
        pools are ``close()``d so children never leak).
        ``isolation``: ``"thread"`` (engines overlap as threads in this
        process — the shared-runtime baseline, and the only mode that
        composes with ``submesh_devices``) or ``"process"`` (one pinned OS
        process per container, the paper's ``--cpus`` shares —
        serving/process_pool.py; ``total_cores`` bounds the carve-up and
        each count's pool keeps its children warm, so the scheduler's
        converged count stops paying spawn+compile cost).
        ``params_seed``: process children rebuild params as
        ``model.init(PRNGKey(params_seed))`` — pass the seed that built
        ``params`` so both isolation modes serve identical weights."""
        self.scheduler = scheduler or DivideAndSaveScheduler(
            list(feasible_counts), objective=objective,
            deadline_s=deadline_s, epsilon=epsilon, seed=seed)
        counts = getattr(self.scheduler, "feasible", list(feasible_counts))
        if isolation not in ("thread", "process"):
            raise ValueError(f"unknown isolation {isolation!r}")
        if submesh_devices is not None:
            if isolation == "process":
                raise ValueError(
                    "submesh placement needs one process owning the whole "
                    "device pool — use isolation='thread' with "
                    "submesh_devices, or isolation='process' without")
            # fail fast: a non-divisor count would otherwise crash mid-
            # serving, the first time the scheduler probes it
            bad = [n for n in counts if submesh_devices % n != 0]
            if bad:
                raise ValueError(
                    f"feasible counts {bad} do not divide "
                    f"{submesh_devices} submesh devices")
        if isolation == "process" and not allow_shared_cores:
            # same fail-fast courtesy for the core carve-up: a count past
            # the core budget cannot be pairwise disjoint
            from repro.core.testbed import available_cores
            budget = total_cores or len(available_cores())
            bad = [n for n in counts if n > budget]
            if bad:
                raise ValueError(
                    f"feasible counts {bad} exceed the {budget}-core "
                    "budget; drop them, raise total_cores, or pass "
                    "allow_shared_cores=True")
        if pool_factory is None:
            if model is None:
                raise ValueError("need a model or a pool_factory")

            def pool_factory(n: int):
                if isolation == "process":
                    from repro.serving.process_pool import \
                        ProcessContainerPool
                    return ProcessContainerPool(
                        model.cfg, n,
                        n_slots_per_container=n_slots_per_container,
                        max_len=max_len, total_cores=total_cores,
                        params_seed=params_seed,
                        allow_shared_cores=allow_shared_cores)
                meshes = None
                if submesh_devices is not None:
                    from repro.launch.mesh import make_container_meshes
                    meshes = make_container_meshes(submesh_devices, n)
                return ContainerServingPool(
                    model, params, n,
                    n_slots_per_container=n_slots_per_container,
                    max_len=max_len, concurrent=concurrent, meshes=meshes)
        self._pool_factory = pool_factory
        self._pools: dict[int, Any] = {}       # insertion order == LRU order
        self._max_cached = max_cached_pools
        self.history: list[WaveResult] = []

    def _pool(self, n: int):
        if n in self._pools:
            self._pools[n] = self._pools.pop(n)    # refresh LRU position
        else:
            self._pools[n] = self._pool_factory(n)
            if self._max_cached is not None:
                while len(self._pools) > max(self._max_cached, 1):
                    # evict the stalest count; dropping the pool releases
                    # its engines' placed params/caches — and shuts down
                    # warm child processes for process-isolation pools
                    evicted = self._pools.pop(next(iter(self._pools)))
                    close = getattr(evicted, "close", None)
                    if close is not None:
                        close()
        return self._pools[n]

    def close(self) -> None:
        """Release every cached pool (shutting down any warm process
        containers). The adaptive pool is reusable after this — the next
        wave simply rebuilds its pool."""
        pools, self._pools = list(self._pools.values()), {}
        for pool in pools:
            close = getattr(pool, "close", None)
            if close is not None:
                close()

    def serve_wave(self, requests: list[Request]) -> list[Completion]:
        n = self.scheduler.pick()
        ordered, _, wall, energy = self._pool(n).serve_timed(requests)
        self.scheduler.observe(n, wall, energy)
        n_tokens = sum(len(c.tokens) for c in ordered)
        p50, p95 = latency_percentiles(ordered)
        self.history.append(WaveResult(len(self.history), n, wall, energy,
                                       len(requests), n_tokens,
                                       n_tokens / wall if wall > 0 else 0.0,
                                       p50, p95))
        return ordered

    def serve(self, waves) -> list[list[Completion]]:
        return [self.serve_wave(w) for w in waves]

    @property
    def choice(self) -> int:
        """Current exploitation-only choice (what a converged deployment
        would run)."""
        return self.scheduler.best()


class SyntheticContainerPool:
    """Pool stand-in with closed-form time/energy profiles (§VI-style
    simulation). ``serve_timed`` echoes the requests as empty completions
    and reports ``time_fn(n)`` / ``energy_fn(n)`` — deterministic input for
    scheduler-loop experiments."""

    def __init__(self, n_containers: int,
                 time_fn: Callable[[int], float],
                 energy_fn: Callable[[int], float] | None = None):
        self.n_containers = n_containers
        self._time_fn = time_fn
        self._energy_fn = energy_fn or (lambda n: time_fn(n) * 40.0)

    def serve_timed(self, requests: list[Request]
                    ) -> tuple[list[Completion], list[ContainerResult],
                               float, float]:
        n = self.n_containers
        wall = float(self._time_fn(n))
        energy = float(self._energy_fn(n))
        ordered = [Completion(r.rid, [], len(r.prompt)) for r in requests]
        per = [ContainerResult(cid, [], wall, 0, wall, energy / n)
               for cid in range(n)]
        return ordered, per, wall, energy

    def serve(self, requests):
        ordered, per, _, _ = self.serve_timed(requests)
        return ordered, per


def synthetic_pool_factory(time_fn: Callable[[int], float],
                           energy_fn: Callable[[int], float] | None = None
                           ) -> Callable[[int], SyntheticContainerPool]:
    return lambda n: SyntheticContainerPool(n, time_fn, energy_fn)
