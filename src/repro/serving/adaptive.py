"""Adaptive serving: the paper's online scheduler closed over the pool.

The paper's conclusion calls for "energy-efficient job schedulers that
split input data, obtaining the optimal number of containers in an online
fashion". ``AdaptiveServingPool`` is that loop: traffic arrives in waves;
each wave is served by a ``ContainerServingPool`` factored to the count the
``DivideAndSaveScheduler`` picked, the wave's measured ``(n, wall, energy)``
lands back in the scheduler, and the next wave is re-factored to the new
``pick()`` — restricted to the feasible counts from ``core/containers.py``
(memory bounds the factorisation search, as it capped the paper's TX2 at 6
containers).

Pools are cached per count, so converging traffic stops paying refactor
cost: once the scheduler settles, every wave reuses the same engines and
their compiled executables.

``SyntheticContainerPool`` is the simulator counterpart (paper §VI): a
pool whose time/energy come from closed-form profiles instead of a device,
used to exercise the scheduler loop deterministically in tests and in
``benchmarks/pool_scaling.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

from repro.core.scheduler import DivideAndSaveScheduler, Objective
from repro.models.model import Model
from repro.serving.engine import Completion, Request
from repro.serving.pool import ContainerResult, ContainerServingPool


@dataclasses.dataclass
class WaveResult:
    wave: int
    n_containers: int
    wall_s: float
    energy_j: float
    n_requests: int
    n_tokens: int = 0             # tokens emitted across the wave
    tokens_per_s: float = 0.0     # wave decode throughput


class AdaptiveServingPool:
    """Serve waves of requests, learning the optimal container count."""

    def __init__(self, model: Model | None, params: Any,
                 feasible_counts: Sequence[int],
                 objective: Objective = "energy",
                 deadline_s: float | None = None,
                 epsilon: float = 0.0, seed: int = 0,
                 n_slots_per_container: int = 4, max_len: int = 512,
                 concurrent: bool = True,
                 scheduler: DivideAndSaveScheduler | None = None,
                 pool_factory: Callable[[int], Any] | None = None):
        self.scheduler = scheduler or DivideAndSaveScheduler(
            list(feasible_counts), objective=objective,
            deadline_s=deadline_s, epsilon=epsilon, seed=seed)
        if pool_factory is None:
            if model is None:
                raise ValueError("need a model or a pool_factory")

            def pool_factory(n: int) -> ContainerServingPool:
                return ContainerServingPool(
                    model, params, n,
                    n_slots_per_container=n_slots_per_container,
                    max_len=max_len, concurrent=concurrent)
        self._pool_factory = pool_factory
        self._pools: dict[int, Any] = {}
        self.history: list[WaveResult] = []

    def _pool(self, n: int):
        if n not in self._pools:
            self._pools[n] = self._pool_factory(n)
        return self._pools[n]

    def serve_wave(self, requests: list[Request]) -> list[Completion]:
        n = self.scheduler.pick()
        ordered, _, wall, energy = self._pool(n).serve_timed(requests)
        self.scheduler.observe(n, wall, energy)
        n_tokens = sum(len(c.tokens) for c in ordered)
        self.history.append(WaveResult(len(self.history), n, wall, energy,
                                       len(requests), n_tokens,
                                       n_tokens / wall if wall > 0 else 0.0))
        return ordered

    def serve(self, waves) -> list[list[Completion]]:
        return [self.serve_wave(w) for w in waves]

    @property
    def choice(self) -> int:
        """Current exploitation-only choice (what a converged deployment
        would run)."""
        return self.scheduler.best()


class SyntheticContainerPool:
    """Pool stand-in with closed-form time/energy profiles (§VI-style
    simulation). ``serve_timed`` echoes the requests as empty completions
    and reports ``time_fn(n)`` / ``energy_fn(n)`` — deterministic input for
    scheduler-loop experiments."""

    def __init__(self, n_containers: int,
                 time_fn: Callable[[int], float],
                 energy_fn: Callable[[int], float] | None = None):
        self.n_containers = n_containers
        self._time_fn = time_fn
        self._energy_fn = energy_fn or (lambda n: time_fn(n) * 40.0)

    def serve_timed(self, requests: list[Request]
                    ) -> tuple[list[Completion], list[ContainerResult],
                               float, float]:
        n = self.n_containers
        wall = float(self._time_fn(n))
        energy = float(self._energy_fn(n))
        ordered = [Completion(r.rid, [], len(r.prompt)) for r in requests]
        per = [ContainerResult(cid, [], wall, 0, wall, energy / n)
               for cid in range(n)]
        return ordered, per, wall, energy

    def serve(self, requests):
        ordered, per, _, _ = self.serve_timed(requests)
        return ordered, per


def synthetic_pool_factory(time_fn: Callable[[int], float],
                           energy_fn: Callable[[int], float] | None = None
                           ) -> Callable[[int], SyntheticContainerPool]:
    return lambda n: SyntheticContainerPool(n, time_fn, energy_fn)
