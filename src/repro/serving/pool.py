"""Container pool: the paper's method applied to serving.

Splits a batch of independent requests into n segments (core/splitter.py),
runs one ServingEngine replica per "container", and combines completions in
request order. On the real pod each replica owns a disjoint sub-mesh
(core/containers.py); on this CPU host the replicas share the device and
the pool records per-container wall time so the benchmarks can account
resource shares explicitly (the multi-process testbed in
examples/serve_video_detection.py pins real disjoint core sets instead).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro.core import splitter
from repro.models.model import Model
from repro.serving.engine import Completion, Request, ServingEngine


@dataclasses.dataclass
class ContainerResult:
    container_id: int
    completions: list
    wall_s: float
    n_requests: int


class ContainerServingPool:
    def __init__(self, model: Model, params: Any, n_containers: int,
                 n_slots_per_container: int = 4, max_len: int = 512,
                 engine_factory: Callable[..., ServingEngine] | None = None):
        self.n_containers = n_containers
        factory = engine_factory or ServingEngine
        self.engines = [
            factory(model, params, n_slots=n_slots_per_container,
                    max_len=max_len)
            for _ in range(n_containers)
        ]

    def serve(self, requests: list[Request]) -> tuple[list[Completion],
                                                      list[ContainerResult]]:
        segments = splitter.split(requests, self.n_containers)
        results = []
        for cid, (engine, seg) in enumerate(zip(self.engines, segments)):
            t0 = time.time()
            for r in seg:
                engine.submit(r)
            comps = engine.run()
            results.append(ContainerResult(cid, comps, time.time() - t0,
                                           len(seg)))
        by_rid = {c.rid: c for r in results for c in r.completions}
        ordered = [by_rid[r.rid] for r in requests if r.rid in by_rid]
        return ordered, results
