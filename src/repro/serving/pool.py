"""Container pool: the paper's method applied to serving.

Splits a batch of independent requests into n segments (core/splitter.py),
runs one ServingEngine replica per "container", and combines completions in
request order. The containers run **concurrently** — one worker thread per
engine; jax releases the GIL while XLA executes, so n engines genuinely
overlap device work (this is the "save" half of divide-and-save: same
total work, less wall time). Pass ``meshes`` (one disjoint sub-mesh per
container — ``launch/mesh.make_container_meshes``) and each engine commits
its params/caches onto its own device slice, so the threads overlap *real
parallel hardware*, not one shared device; the pool validates the slices
are pairwise disjoint at construction. Without ``meshes`` every engine
shares the default device (the thread-overlap baseline). For OS-level
CPU shares — one pinned process per container, the paper's actual
``docker run --cpus`` mechanism — use
``serving/process_pool.ProcessContainerPool``, which shares this module's
per-wave accounting via ``assemble_wave``.

Per-container accounting: each ContainerResult carries the container's wall
time, its busy time (wall the engine spent inside ``step()``), its emitted
token count and tokens/s (per-chunk granularity — the engine counts tokens
as each fused decode chunk lands), p50/p95 completion-latency percentiles,
and an energy estimate from ``EnergyProxy`` — the paper's fixed+dynamic
power decomposition (a baseline draw shared by the containers plus an
activity draw proportional to busy time). The proxy is what the online
scheduler optimises on hosts with no power sensor; the calibrated device
simulators in core/energy_model.py play that role for TX2/Orin figures.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Sequence

import numpy as np

from repro.core import splitter
from repro.models.model import Model
from repro.serving.engine import Completion, Request, ServingEngine


@dataclasses.dataclass(frozen=True)
class EnergyProxy:
    """E = wall·idle_w + Σ_containers busy·active_w  (paper's two-term
    power model: a package baseline plus per-container activity)."""
    idle_w: float = 40.0
    active_w: float = 7.0

    def container_energy(self, wave_wall_s: float, busy_s: float,
                         n_containers: int) -> float:
        """One container's share: its activity draw plus an equal share of
        the baseline draw over the wave."""
        return (self.active_w * busy_s
                + self.idle_w * wave_wall_s / max(n_containers, 1))


def latency_percentiles(completions: Sequence[Completion]
                        ) -> tuple[float, float]:
    """(p50, p95) of completion latencies, (0, 0) when empty — the
    scheduler-facing tail-latency summary (ROADMAP: latency percentiles)."""
    lats = [c.latency_s for c in completions]
    if not lats:
        return 0.0, 0.0
    return (float(np.percentile(lats, 50)), float(np.percentile(lats, 95)))


@dataclasses.dataclass
class ContainerResult:
    container_id: int
    completions: list
    wall_s: float
    n_requests: int
    busy_s: float = 0.0
    energy_j: float = 0.0
    n_tokens: int = 0             # tokens emitted by this container
    tokens_per_s: float = 0.0     # n_tokens / wall_s (decode throughput)
    latency_p50_s: float = 0.0    # median completion latency
    latency_p95_s: float = 0.0    # tail completion latency


def assemble_wave(out: Sequence[tuple], segments: Sequence[Sequence[Request]],
                  wall: float, energy: EnergyProxy
                  ) -> tuple[list[Completion], list[ContainerResult], float]:
    """Shared per-wave accounting for every pool flavour (thread, process,
    sub-mesh): turn raw per-container ``(completions, wall, busy, tokens)``
    tuples into ContainerResults with energy/percentiles, and combine the
    completions back into request order (split/combine round-trip ==
    original order). Returns ``(ordered, results, wave_energy_j)``."""
    n_containers = len(segments)
    results, total_e = [], 0.0
    for cid, ((comps, c_wall, c_busy, c_toks), seg) in enumerate(
            zip(out, segments)):
        e = energy.container_energy(wall, c_busy, n_containers)
        total_e += e
        p50, p95 = latency_percentiles(comps)
        results.append(ContainerResult(
            cid, comps, c_wall, len(seg), c_busy, e, c_toks,
            c_toks / c_wall if c_wall > 0 else 0.0, p50, p95))
    # request-order combination: within a segment order completions by
    # the segment's submission order, then splice segments back with the
    # splitter
    per_segment = []
    for res, seg in zip(results, segments):
        by_rid = {c.rid: c for c in res.completions}
        per_segment.append([by_rid[r.rid] for r in seg if r.rid in by_rid])
    ordered = splitter.combine(per_segment)
    return ordered, results, total_e


class ContainerServingPool:
    def __init__(self, model: Model, params: Any, n_containers: int,
                 n_slots_per_container: int = 4, max_len: int = 512,
                 engine_factory: Callable[..., ServingEngine] | None = None,
                 concurrent: bool = True,
                 energy: EnergyProxy | None = None,
                 meshes: Sequence[Any] | None = None):
        self.n_containers = n_containers
        self.concurrent = concurrent
        self.energy = energy or EnergyProxy()
        if meshes is not None:
            if len(meshes) != n_containers:
                raise ValueError(f"{len(meshes)} meshes for "
                                 f"{n_containers} containers")
            sets = [frozenset(m.devices.flat) for m in meshes]
            for i, a in enumerate(sets):
                for b in sets[i + 1:]:
                    if a & b:
                        raise ValueError(
                            "container sub-meshes overlap: "
                            f"{sorted(d.id for d in a & b)}")
        self.meshes = meshes
        factory = engine_factory or ServingEngine
        self.engines = [
            factory(model, params, n_slots=n_slots_per_container,
                    max_len=max_len,
                    **({"mesh": meshes[i]} if meshes is not None else {}))
            for i in range(n_containers)
        ]

    # ------------------------------------------------------------------
    def _run_container(self, cid: int, seg: list[Request], out: list) -> None:
        try:
            engine = self.engines[cid]
            t0 = time.perf_counter()
            busy0, toks0 = engine.busy_s, engine.tokens_generated
            engine.submit_many(seg)
            comps = engine.run()
            out[cid] = (comps, time.perf_counter() - t0,
                        engine.busy_s - busy0,
                        engine.tokens_generated - toks0)
        except BaseException as e:      # propagate across the thread join
            out[cid] = e

    def serve_timed(self, requests: list[Request],
                    concurrent: bool | None = None
                    ) -> tuple[list[Completion], list[ContainerResult],
                               float, float]:
        """Serve a wave; returns (ordered completions, per-container
        results, wave wall seconds, wave energy joules)."""
        if concurrent is None:
            concurrent = self.concurrent
        segments = splitter.split(requests, self.n_containers)
        out: list = [None] * self.n_containers
        t0 = time.perf_counter()
        if concurrent and self.n_containers > 1:
            workers = [threading.Thread(target=self._run_container,
                                        args=(cid, seg, out), daemon=True)
                       for cid, seg in enumerate(segments)]
            for w in workers:
                w.start()
            for w in workers:
                w.join()
        else:
            for cid, seg in enumerate(segments):
                self._run_container(cid, seg, out)
        wall = time.perf_counter() - t0
        for e in out:
            if isinstance(e, BaseException):
                raise e
        ordered, results, energy = assemble_wave(out, segments, wall,
                                                 self.energy)
        return ordered, results, wall, energy

    def serve(self, requests: list[Request],
              concurrent: bool | None = None
              ) -> tuple[list[Completion], list[ContainerResult]]:
        ordered, results, _, _ = self.serve_timed(requests, concurrent)
        return ordered, results
