"""Container pool: the paper's method applied to serving.

Splits a batch of independent requests into n segments (core/splitter.py),
runs one ServingEngine replica per "container", and combines completions in
request order. Since the backend redesign the pool is a **wave shim over a
ContainerBackend** (serving/backend.py): without ``meshes`` it builds a
``ThreadBackend`` (engines overlap as worker threads on the shared device
— jax releases the GIL while XLA executes, so n engines genuinely overlap:
the "save" half of divide-and-save); with ``meshes`` (one disjoint
sub-mesh per container — ``launch/mesh.make_container_meshes``) a
``SubmeshBackend``, whose engines commit params/caches onto their own
device slices (pairwise disjointness validated at construction). For
OS-level CPU shares — one pinned process per container, the paper's
actual ``docker run --cpus`` mechanism — use
``serving/process_pool.ProcessContainerPool`` (a ``ProcessBackend`` behind
the same shim), which shares this module's per-wave accounting via
``assemble_wave``. For request-level streaming instead of waves, put a
``serving/router.Router`` in front of any of those backends.

Per-container accounting: each ContainerResult carries the container's wall
time, its busy time (wall the engine spent inside ``step()``), its emitted
token count and tokens/s (per-chunk granularity — the engine counts tokens
as each fused decode chunk lands), p50/p95 completion-latency percentiles,
and an energy estimate from ``EnergyProxy`` — the paper's fixed+dynamic
power decomposition (a baseline draw shared by the containers plus an
activity draw proportional to busy time). The proxy is what the online
scheduler optimises on hosts with no power sensor; the calibrated device
simulators in core/energy_model.py play that role for TX2/Orin figures.
An idle container in a wave (or a streamed window) yields well-defined
zeros — empty completions never crash the accounting.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Sequence

import numpy as np

from repro.core import splitter
from repro.models.model import Model
from repro.serving.backend import SubmeshBackend, ThreadBackend
from repro.serving.engine import Completion, Request, ServingEngine

# the wave shims warn ONCE per process (not per wave — benchmark loops
# call them thousands of times); tests reset this to re-arm the warning
_WAVE_SHIM_WARNED = False


def _warn_wave_shim(api: str) -> None:
    """One documented DeprecationWarning for the whole wave surface:
    ``serve_timed``/``serve_wave`` batch a complete wave and block on the
    slowest container; ``Router.submit`` + ``CompletionHandle.stream()``
    is the request-level replacement (continuous admission, typed chunk
    events, no wave barrier)."""
    global _WAVE_SHIM_WARNED
    if _WAVE_SHIM_WARNED:
        return
    _WAVE_SHIM_WARNED = True
    warnings.warn(
        f"{api} is a legacy wave shim: it blocks until the slowest "
        "container drains. Prefer Router.submit(...) and stream the "
        "returned handle (serving/router.py)", DeprecationWarning,
        stacklevel=3)


@dataclasses.dataclass(frozen=True)
class EnergyProxy:
    """E = wall·idle_w + Σ_containers busy·active_w  (paper's two-term
    power model: a package baseline plus per-container activity)."""
    idle_w: float = 40.0
    active_w: float = 7.0

    def container_energy(self, wave_wall_s: float, busy_s: float,
                         n_containers: int) -> float:
        """One container's share: its activity draw plus an equal share of
        the baseline draw over the wave."""
        return (self.active_w * busy_s
                + self.idle_w * wave_wall_s / max(n_containers, 1))


def percentiles(values: Sequence[float]) -> tuple[float, float]:
    """(p50, p95) of a sample, (0, 0) when empty — the shared guard for
    every latency-ish summary (completion latency here, time-to-first-
    chunk in the Router's windows), so an idle container or empty window
    yields well-defined zeros instead of an error."""
    if not values:
        return 0.0, 0.0
    return (float(np.percentile(values, 50)),
            float(np.percentile(values, 95)))


def latency_percentiles(completions: Sequence[Completion]
                        ) -> tuple[float, float]:
    """(p50, p95) of completion latencies — the scheduler-facing
    tail-latency summary (ROADMAP: latency percentiles)."""
    return percentiles([c.latency_s for c in completions])


@dataclasses.dataclass
class ContainerResult:
    container_id: int
    completions: list
    wall_s: float
    n_requests: int
    busy_s: float = 0.0
    energy_j: float = 0.0
    n_tokens: int = 0             # tokens emitted by this container
    tokens_per_s: float = 0.0     # n_tokens / wall_s (decode throughput)
    latency_p50_s: float = 0.0    # median completion latency
    latency_p95_s: float = 0.0    # tail completion latency


def assemble_wave(out: Sequence[tuple], segments: Sequence[Sequence[Request]],
                  wall: float, energy: EnergyProxy
                  ) -> tuple[list[Completion], list[ContainerResult], float]:
    """Shared per-wave accounting for every pool flavour (thread, process,
    sub-mesh): turn raw per-container ``(completions, wall, busy, tokens)``
    tuples into ContainerResults with energy/percentiles, and combine the
    completions back into request order (split/combine round-trip ==
    original order). Returns ``(ordered, results, wave_energy_j)``."""
    n_containers = len(segments)
    results, total_e = [], 0.0
    for cid, ((comps, c_wall, c_busy, c_toks), seg) in enumerate(
            zip(out, segments)):
        e = energy.container_energy(wall, c_busy, n_containers)
        total_e += e
        p50, p95 = latency_percentiles(comps)
        results.append(ContainerResult(
            cid, comps, c_wall, len(seg), c_busy, e, c_toks,
            c_toks / c_wall if c_wall > 0 else 0.0, p50, p95))
    # request-order combination: within a segment order completions by
    # the segment's submission order, then splice segments back with the
    # splitter
    per_segment = []
    for res, seg in zip(results, segments):
        by_rid = {c.rid: c for c in res.completions}
        per_segment.append([by_rid[r.rid] for r in seg if r.rid in by_rid])
    ordered = splitter.combine(per_segment)
    return ordered, results, total_e


class ContainerServingPool:
    def __init__(self, model: Model, params: Any, n_containers: int,
                 n_slots_per_container: int = 4, max_len: int = 512,
                 engine_factory: Callable[..., ServingEngine] | None = None,
                 concurrent: bool = True,
                 energy: EnergyProxy | None = None,
                 meshes: Sequence[Any] | None = None,
                 backend=None):
        self.n_containers = n_containers
        self.concurrent = concurrent
        self.energy = energy or EnergyProxy()
        if backend is None:
            backend_cls = SubmeshBackend if meshes is not None \
                else ThreadBackend
            backend = backend_cls(
                model, params, n_containers,
                n_slots_per_container=n_slots_per_container,
                max_len=max_len, engine_factory=engine_factory,
                meshes=meshes, concurrent=concurrent)
        elif backend.capacity != n_containers:
            raise ValueError(f"backend capacity {backend.capacity} != "
                             f"{n_containers} containers")
        self.backend = backend
        self.meshes = getattr(backend, "meshes", None)

    @property
    def engines(self):
        return self.backend.engines

    # ------------------------------------------------------------------
    def serve_timed(self, requests: list[Request],
                    concurrent: bool | None = None
                    ) -> tuple[list[Completion], list[ContainerResult],
                               float, float]:
        """Serve a wave (the wave shim: submit-all + drain); returns
        (ordered completions, per-container results, wave wall seconds,
        wave energy joules)."""
        _warn_wave_shim("ContainerServingPool.serve_timed")
        if concurrent is None:
            concurrent = self.concurrent
        segments = splitter.split(requests, self.n_containers)
        t0 = time.perf_counter()
        for cid, seg in enumerate(segments):
            self.backend.submit_many(cid, seg)
        out = self.backend.drain(concurrent=concurrent)
        wall = time.perf_counter() - t0
        ordered, results, energy = assemble_wave(out, segments, wall,
                                                 self.energy)
        return ordered, results, wall, energy

    def serve(self, requests: list[Request],
              concurrent: bool | None = None
              ) -> tuple[list[Completion], list[ContainerResult]]:
        ordered, results, _, _ = self.serve_timed(requests, concurrent)
        return ordered, results

    def close(self) -> None:
        """Release the backend (engines and their placed replicas)."""
        self.backend.close()
