"""``ContainerBackend`` — one protocol behind every container flavour.

PR 1–4 grew three parallel serving hierarchies: thread-per-container
engines (``pool.py``), pinned OS processes (``process_pool.py``) and
sub-mesh-committed engines (the mesh-aware engine paths). This module
refactors their execution machinery behind one request-level protocol so
the ``Router`` (serving/router.py) and the wave-shim pools are written
once, against:

    capacity                       # number of containers
    submit(cid, req)               # enqueue one request on a container
    poll() -> list[Event]          # advance + drain streamed events
    load(cid) -> int               # queued+active requests (dispatch)
    stats(cid) -> (busy_s, tokens) # cumulative counters (energy/windows)
    drain(concurrent) -> [...]     # wave shim: run all containers idle
    close()                        # release engines / children

``poll`` is pull-driven: callers that want progress call it, each call
advances every container that has work by at most one engine macro-step
and returns the events that materialised (see serving/events.py — one
``ChunkEvent`` per request per macro-step, a ``DoneEvent`` per
completion). ``drain`` is the wave fast-path: it runs every container to
idle (concurrently for real backends) and returns the per-container
``(completions, wall_s, busy_s, tokens)`` tuples that
``pool.assemble_wave`` has consumed since PR 4 — which is what keeps the
PR 1–4 parity suites green through the wave shim.

Three implementations:

* ``ThreadBackend`` — one ``ServingEngine`` per container in this
  process (jax releases the GIL during XLA dispatch, so engines overlap
  on the shared device); the PR 1 pool's machinery.
* ``SubmeshBackend`` — ``ThreadBackend`` whose engines are committed to
  pairwise-disjoint device sub-meshes (PR 3's physical placement; the
  disjointness validation lives here now).
* ``ProcessBackend`` — one OS process per container pinned to a disjoint
  core set before jax initialises (PR 4's ``docker run --cpus``
  mechanism). Children host a ``ServingEngine`` behind a streaming pipe
  protocol: ``("submit", [Request...])`` in, ``("events", [Event...],
  busy_s, tokens)`` out after every engine step — so chunk events cross
  the process boundary with the same shape as thread events, and the
  parent's ``stats`` are the child's own counters. Params reach children
  by seeded re-init, ``.npz`` handoff (``save_params``) or — new — a
  ``multiprocessing.shared_memory`` mapping (``share_params``) that
  skips the copy through the filesystem.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import threading
import time
from collections import deque
from typing import Any, Callable, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.testbed import assign_core_sets, spawn_pinned
from repro.serving.engine import (Completion, EngineConfig, Request,
                                  ServingEngine)
from repro.serving.events import DoneEvent, Event

_READY_POLL_S = 0.25
_IDLE_POLL_S = 0.05


@runtime_checkable
class ContainerBackend(Protocol):
    """The request-level serving protocol (see module docstring)."""

    capacity: int

    def submit(self, cid: int, req: Request) -> None: ...

    def poll(self) -> list[Event]: ...

    def load(self, cid: int) -> int: ...

    def stats(self, cid: int) -> tuple[float, int]: ...

    def drain(self, concurrent: bool = True
              ) -> list[tuple[list[Completion], float, float, int]]: ...

    def close(self) -> None: ...


def validate_disjoint_meshes(meshes: Sequence[Any],
                             n_containers: int) -> None:
    """Per-container sub-meshes must be pairwise disjoint device slices —
    that IS the isolation claim sub-mesh placement rests on."""
    if len(meshes) != n_containers:
        raise ValueError(f"{len(meshes)} meshes for "
                         f"{n_containers} containers")
    sets = [frozenset(m.devices.flat) for m in meshes]
    for i, a in enumerate(sets):
        for b in sets[i + 1:]:
            if a & b:
                raise ValueError(
                    "container sub-meshes overlap: "
                    f"{sorted(d.id for d in a & b)}")


# ---------------------------------------------------------------------------
# in-process backends (thread / submesh)
# ---------------------------------------------------------------------------
class ThreadBackend:
    """One ServingEngine per container in this process. ``poll`` advances
    active engines one macro-step each — in worker threads when more than
    one container has work, so streaming overlaps the same way waves do —
    and ``drain`` runs each engine's ``run()`` to idle (thread-per-
    container, the PR 1 wave machinery verbatim)."""

    kind = "thread"

    def __init__(self, model, params, n_containers: int,
                 n_slots_per_container: int = 4, max_len: int = 512,
                 engine_factory: Callable[..., ServingEngine] | None = None,
                 meshes: Sequence[Any] | None = None,
                 concurrent: bool = True,
                 config: EngineConfig | None = None):
        if meshes is not None:
            validate_disjoint_meshes(meshes, n_containers)
        self.capacity = n_containers
        self.meshes = meshes
        self.concurrent = concurrent
        self.config = config or EngineConfig(
            n_slots=n_slots_per_container, max_len=max_len)
        self._events: deque[Event] = deque()   # append is GIL-atomic
        self._executor = None                  # lazy; poll-step overlap
        self.engines: list[ServingEngine] = []
        for cid in range(n_containers):
            mesh_kw = {"mesh": meshes[cid]} if meshes is not None else {}
            if engine_factory is None:
                eng = ServingEngine(model, params, self.config, **mesh_kw)
            else:
                # custom factories (tests, instrumented engines) keep the
                # legacy call style; their forwarding path warns once
                eng = engine_factory(model, params,
                                     n_slots=self.config.n_slots,
                                     max_len=self.config.max_len, **mesh_kw)
            eng.container_id = cid
            eng.on_event = self._events.append
            self.engines.append(eng)

    # -- streaming ------------------------------------------------------
    def submit(self, cid: int, req: Request) -> None:
        self.engines[cid].submit(req)

    def submit_many(self, cid: int, reqs: Sequence[Request]) -> None:
        self.engines[cid].submit_many(reqs)

    def poll(self) -> list[Event]:
        active = [e for e in self.engines if e.has_work]
        if self.concurrent and len(active) > 1:
            if self._executor is None:
                # persistent workers: a stream polls once per macro-step
                # for its whole life — per-poll thread spawns would churn
                from concurrent.futures import ThreadPoolExecutor
                self._executor = ThreadPoolExecutor(
                    max_workers=self.capacity,
                    thread_name_prefix="container-step")
            futures = [self._executor.submit(e.step) for e in active]
            errs = []
            for f in futures:           # join ALL steps before raising —
                try:                    # a swallowed error would hang the
                    f.result()          # stream waiting for a DoneEvent
                except BaseException as e:
                    errs.append(e)
            if errs:
                raise errs[0]
        else:
            for eng in active:
                eng.step()
        for eng in self.engines:
            # poll-driven consumers take completions from DoneEvents;
            # nobody calls run() on a streamed engine, so drain its done
            # list (all engines — zero-budget submissions complete at
            # submit, without the engine ever becoming active) or a
            # long-lived stream accumulates one Completion per request
            # and a later wave drain() would return the stale backlog
            eng.done.clear()
        out: list[Event] = []
        while self._events:
            out.append(self._events.popleft())
        return out

    def load(self, cid: int) -> int:
        eng = self.engines[cid]
        return len(eng.queue) + sum(1 for s in eng.slots if s.active)

    def stats(self, cid: int) -> tuple[float, int]:
        eng = self.engines[cid]
        return eng.busy_s, eng.tokens_generated

    # -- wave shim ------------------------------------------------------
    def drain(self, concurrent: bool | None = None
              ) -> list[tuple[list[Completion], float, float, int]]:
        """Run every container to idle; per-container results for
        ``assemble_wave``. Wave consumers take completions, not events,
        so the event buffer is cleared afterwards (``engine.run`` emitted
        into it redundantly)."""
        if concurrent is None:
            concurrent = self.concurrent
        out: list[Any] = [None] * self.capacity

        def run_one(cid: int) -> None:
            try:
                eng = self.engines[cid]
                t0 = time.perf_counter()
                busy0, toks0 = eng.busy_s, eng.tokens_generated
                comps = eng.run()
                out[cid] = (comps, time.perf_counter() - t0,
                            eng.busy_s - busy0,
                            eng.tokens_generated - toks0)
            except BaseException as e:  # propagate across the thread join
                out[cid] = e

        if concurrent and self.capacity > 1:
            workers = [threading.Thread(target=run_one, args=(cid,),
                                        daemon=True)
                       for cid in range(self.capacity)]
            for w in workers:
                w.start()
            for w in workers:
                w.join()
        else:
            for cid in range(self.capacity):
                run_one(cid)
        self._events.clear()
        for e in out:
            if isinstance(e, BaseException):
                raise e
        return out

    def close(self) -> None:
        if self._executor is not None:
            self._executor.shutdown(wait=False)
            self._executor = None
        self._events.clear()
        self.engines = []
        self.capacity = 0


class SubmeshBackend(ThreadBackend):
    """ThreadBackend whose engines are committed to disjoint device
    sub-meshes (``launch/mesh.make_container_meshes``) — the containers
    are physical on the device axis, so the threads overlap real parallel
    hardware instead of one shared device."""

    kind = "submesh"

    def __init__(self, model, params, n_containers: int,
                 n_slots_per_container: int = 4, max_len: int = 512,
                 engine_factory: Callable[..., ServingEngine] | None = None,
                 meshes: Sequence[Any] | None = None,
                 concurrent: bool = True,
                 config: EngineConfig | None = None):
        if meshes is None:
            raise ValueError("SubmeshBackend needs per-container meshes "
                             "(launch/mesh.make_container_meshes)")
        super().__init__(model, params, n_containers,
                         n_slots_per_container=n_slots_per_container,
                         max_len=max_len, engine_factory=engine_factory,
                         meshes=meshes, concurrent=concurrent,
                         config=config)


# ---------------------------------------------------------------------------
# params handoff for process containers
# ---------------------------------------------------------------------------
def save_params(params: Any, path: str) -> str:
    """Write a params tree to ``path`` (.npz, leaves in tree order) for the
    cross-process handoff: children rebuild the tree structure from
    ``jax.eval_shape(model.init, ...)`` and unflatten these leaves — exact
    float bytes, so parity with the parent's params is preserved."""
    import jax
    leaves = jax.tree_util.tree_leaves(params)
    np.savez(path, **{f"leaf{i}": np.asarray(leaf)
                      for i, leaf in enumerate(leaves)})
    return path


def _load_params(model, path: str):
    import jax
    struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    treedef = jax.tree_util.tree_structure(struct)
    with np.load(path) as z:
        leaves = [z[f"leaf{i}"] for i in range(len(z.files))]
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclasses.dataclass(frozen=True)
class SharedParams:
    """Picklable descriptor of a ``multiprocessing.shared_memory`` params
    block: children attach by name and view each leaf at its offset —
    one parent-side copy total, no filesystem round-trip (the ROADMAP's
    leftover from the ``.npz`` handoff, which writes and re-reads every
    byte per child)."""
    shm_name: str
    specs: tuple                  # ((shape, dtype_str, offset), ...)
    nbytes: int


class ParamsShare:
    """Parent-side owner of the shared block. Keep it alive while any
    child may attach; ``close()`` unlinks the segment. Pass ``.handle``
    (the picklable SharedParams) to pools/backends."""

    def __init__(self, shm, handle: SharedParams):
        self._shm = shm
        self.handle = handle

    def close(self) -> None:
        if self._shm is None:
            return
        shm, self._shm = self._shm, None
        shm.close()
        try:
            shm.unlink()
        except FileNotFoundError:
            pass

    def __enter__(self) -> "ParamsShare":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass


def share_params(params: Any) -> ParamsShare:
    """Lay the params tree's leaves out back-to-back in one shared-memory
    segment (leaves in tree order, byte-exact, so parity with the parent's
    params is preserved — same contract as ``save_params``)."""
    import jax
    from multiprocessing import shared_memory
    leaves = [np.asarray(leaf) for leaf in jax.tree_util.tree_leaves(params)]
    specs, offset = [], 0
    for leaf in leaves:
        # leaves are aligned to their itemsize so the child-side ndarray
        # views are valid for any dtype
        align = max(leaf.dtype.itemsize, 1)
        offset = (offset + align - 1) // align * align
        specs.append((leaf.shape, leaf.dtype.str, offset))
        offset += leaf.nbytes
    shm = shared_memory.SharedMemory(create=True, size=max(offset, 1))
    for leaf, (shape, dtype, off) in zip(leaves, specs):
        dst = np.ndarray(shape, dtype=dtype, buffer=shm.buf, offset=off)
        dst[...] = leaf
    handle = SharedParams(shm.name, tuple(specs), offset)
    return ParamsShare(shm, handle)


def _load_params_shm(model, handle: SharedParams):
    """Child-side loader: attach, view each leaf, copy onto the device
    (``jnp.asarray``), detach. The segment outlives the view copies only
    in the parent, which owns the unlink."""
    import jax
    import jax.numpy as jnp
    from multiprocessing import shared_memory
    # NOTE on lifetime: spawn children inherit the parent's resource
    # tracker, so this attach registers a duplicate no-op and the parent
    # keeps sole ownership of the unlink (ParamsShare.close).
    shm = shared_memory.SharedMemory(name=handle.shm_name)
    try:
        leaves = []
        for shape, dtype, off in handle.specs:
            view = np.ndarray(shape, dtype=dtype, buffer=shm.buf, offset=off)
            # jnp.array(copy=True): jax on CPU may alias a numpy buffer
            # zero-copy, and an alias into the segment would dangle the
            # moment it is unmapped below
            leaves.append(jnp.array(view, copy=True))
        for leaf in leaves:
            leaf.block_until_ready()
    finally:
        shm.close()
    struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    treedef = jax.tree_util.tree_structure(struct)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# process backend
# ---------------------------------------------------------------------------
def _engine_config_wire(config: EngineConfig) -> dict:
    """EngineConfig as a dict of picklable primitives. Pickling the
    dataclass itself would make the child unpickle (hence import
    repro.serving.engine, hence jax) at process bootstrap — BEFORE
    ``spawn_pinned`` applies the cpuset — so the config crosses the pipe
    as plain fields with the dtype by name instead."""
    kw = dataclasses.asdict(config)
    kw["dtype"] = np.dtype(kw["dtype"]).name
    return kw


def _serving_child(conn, cid: int, cfg, params_seed: int,
                   params_path: str | None, params_shm,
                   engine_kw: dict) -> None:
    """Container body (module-level: spawn pickles it by reference).
    Affinity was already applied by ``spawn_pinned``; the jax import below
    therefore sizes XLA's threadpool from the container's cpuset.
    ``engine_kw`` is ``_engine_config_wire`` output — one EngineConfig,
    primitives only.

    Streaming protocol: ``("submit", [Request...])`` enqueues;
    after every engine macro-step (and after zero-budget submissions,
    which complete instantly) the child flushes ``("events", [Event...],
    busy_s, tokens_generated)``. The pipe is checked between steps, so a
    ``("close",)`` lands promptly even mid-stream."""
    import traceback
    try:
        import jax

        from repro.models.model import Model
        from repro.serving.engine import EngineConfig, ServingEngine

        model = Model(cfg)
        if params_shm is not None:
            params = _load_params_shm(model, params_shm)
        elif params_path:
            params = _load_params(model, params_path)
        else:
            params = model.init(jax.random.PRNGKey(params_seed))
        engine = ServingEngine(model, params, EngineConfig(**engine_kw))
        # events cross the pipe as-is: the child must stamp the parent's
        # container id or every child would claim container 0
        engine.container_id = cid
        buf: list = []
        engine.on_event = buf.append
        try:
            cores = sorted(os.sched_getaffinity(0))
        except AttributeError:              # non-Linux dev host
            cores = []
        conn.send(("ready", cores))
    except BaseException:
        conn.send(("error", traceback.format_exc()))
        return
    while True:
        try:
            if buf:
                conn.send(("events", list(buf), engine.busy_s,
                           engine.tokens_generated))
                buf.clear()
                # DoneEvents carry the completions; nobody calls run()
                # here, so drain the engine's done list or it grows
                # without bound across a long-lived stream
                engine.done.clear()
            timeout = 0 if engine.has_work else _IDLE_POLL_S
            if conn.poll(timeout):
                msg = conn.recv()
                if msg[0] == "close":
                    conn.close()
                    return
                if msg[0] == "submit":
                    engine.submit_many(msg[1])
                    continue               # flush instant completions
            if engine.has_work:
                engine.step()
        except (EOFError, BrokenPipeError):  # parent died / closed: exit
            return
        except BaseException:
            try:
                conn.send(("error", traceback.format_exc()))
            except (BrokenPipeError, OSError):
                return


class ProcessBackend:
    """One pinned OS process per container (the paper's ``--cpus``
    shares), behind the streaming ContainerBackend protocol. Children
    spawn lazily at first submit and stay warm until ``close()`` —
    engines, compiled executables and params survive across waves and
    streams, which is what makes process isolation affordable inside an
    online loop."""

    kind = "process"

    def __init__(self, cfg, n_containers: int,
                 n_slots_per_container: int = 4, max_len: int = 512,
                 total_cores: int | None = None,
                 params_seed: int = 0, params_path: str | None = None,
                 params_shm: SharedParams | None = None,
                 greedy: bool = True, seed: int = 0,
                 chunked: bool = True, chunk_tokens: int | None = None,
                 allow_shared_cores: bool = False,
                 start_timeout_s: float = 600.0,
                 config: EngineConfig | None = None):
        self.cfg = cfg
        self.capacity = n_containers
        self.config = config or EngineConfig(
            n_slots=n_slots_per_container, max_len=max_len, greedy=greedy,
            seed=seed, chunked=chunked, chunk_tokens=chunk_tokens)
        # legacy attribute surface (readers predate EngineConfig)
        self.n_slots = self.config.n_slots
        self.max_len = self.config.max_len
        self.greedy = self.config.greedy
        self.seed = self.config.seed
        self.chunked = self.config.chunked
        self.chunk_tokens = self.config.chunk_tokens
        self.params_seed = params_seed
        self.params_path = params_path
        self.params_shm = params_shm
        if params_path and params_shm:
            raise ValueError("pass params_path or params_shm, not both")
        self.start_timeout_s = start_timeout_s
        # fail fast, before any spawn: more containers than cores cannot
        # be disjoint (see core/testbed.assign_core_sets)
        self.core_sets = assign_core_sets(n_containers,
                                         total_cores=total_cores,
                                         allow_shared=allow_shared_cores)
        self.reported_core_sets: list[frozenset[int]] | None = None
        self.workers: list[tuple[Any, Any]] | None = None
        self._events: deque[Event] = deque()
        self._stats = [(0.0, 0)] * n_containers
        self._outstanding = [0] * n_containers

    # -- lifecycle ------------------------------------------------------
    def warm(self) -> None:
        """Public warm-up: spawn + handshake the children now, so a wave
        shim (or a latency-sensitive caller) can pay the spawn+compile
        cost outside its timed region."""
        self._ensure_workers()

    def _ensure_workers(self) -> None:
        """Spawn + handshake all children once; engines stay warm across
        waves (the per-count pool caches rely on this)."""
        if self.workers is not None:
            return
        ctx = mp.get_context("spawn")
        workers = []
        for cid, cores in enumerate(self.core_sets):
            proc, conn = spawn_pinned(
                _serving_child, cores,
                args=(cid, self.cfg, self.params_seed, self.params_path,
                      self.params_shm, _engine_config_wire(self.config)),
                ctx=ctx)
            workers.append((proc, conn))
        reported = []
        try:
            for cid, (proc, conn) in enumerate(workers):
                msg = self._recv(proc, conn, self.start_timeout_s)
                if msg[0] != "ready":
                    raise RuntimeError(
                        f"container {cid} failed to start:\n{msg[1]}")
                reported.append(frozenset(msg[1]))
        except BaseException:
            for proc, _ in workers:
                proc.terminate()
            raise
        self.workers = workers
        self.reported_core_sets = reported

    @staticmethod
    def _recv(proc, conn, timeout_s: float | None):
        """recv that notices a dead child instead of blocking forever."""
        deadline = (None if timeout_s is None
                    else time.perf_counter() + timeout_s)
        while not conn.poll(_READY_POLL_S):
            if not proc.is_alive():
                raise RuntimeError(
                    f"container process died (exit {proc.exitcode}) "
                    "before replying")
            if deadline is not None and time.perf_counter() > deadline:
                raise TimeoutError("container start/serve timed out")
        return conn.recv()

    def close(self) -> None:
        """Shut the warm children down (idempotent). Cached backends
        evicted by adaptive facades call this so children never leak."""
        if self.workers is None:
            return
        workers, self.workers = self.workers, None
        self._events.clear()
        self._outstanding = [0] * self.capacity
        # respawned children restart their counters at zero — stale
        # cumulatives would make the next wave's deltas negative
        self._stats = [(0.0, 0)] * self.capacity
        for _, conn in workers:
            try:
                conn.send(("close",))
            except (BrokenPipeError, OSError):
                pass
        for proc, conn in workers:
            proc.join(timeout=10)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=5)
            conn.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    # -- streaming ------------------------------------------------------
    def submit(self, cid: int, req: Request) -> None:
        self.submit_many(cid, [req])

    def submit_many(self, cid: int, reqs: Sequence[Request]) -> None:
        if not reqs:
            return
        self._ensure_workers()
        assert self.workers is not None
        _, conn = self.workers[cid]
        conn.send(("submit", list(reqs)))
        self._outstanding[cid] += len(reqs)

    def _pump(self, block_s: float = 0.0) -> bool:
        """Drain every ready child message into the event buffer; with
        ``block_s`` wait up to that long for the first one. Raises (after
        tearing the workers down — their pipes hold replies for a wave
        that no longer exists) on a child error or death."""
        if self.workers is None:
            return False
        from multiprocessing.connection import wait as conn_wait
        conns = [conn for _, conn in self.workers]
        got = False
        try:
            ready = conn_wait(conns, block_s)
            for conn in ready:
                cid = conns.index(conn)
                while conn.poll(0):
                    msg = conn.recv()
                    got = True
                    if msg[0] == "error":
                        raise RuntimeError(
                            f"container {cid} failed mid-serve:\n{msg[1]}")
                    _, events, busy, toks = msg
                    self._stats[cid] = (busy, toks)
                    for ev in events:
                        if isinstance(ev, DoneEvent):
                            self._outstanding[cid] -= 1
                        self._events.append(ev)
            if not got:
                for cid, (proc, _) in enumerate(self.workers):
                    if self._outstanding[cid] and not proc.is_alive():
                        raise RuntimeError(
                            f"container {cid} died (exit {proc.exitcode}) "
                            f"with {self._outstanding[cid]} requests in "
                            "flight")
        except EOFError as e:
            raise RuntimeError("container closed its pipe mid-serve") from e
        except BaseException:
            self.close()
            raise
        return got

    def poll(self) -> list[Event]:
        self._pump()
        out = list(self._events)
        self._events.clear()
        return out

    def load(self, cid: int) -> int:
        return self._outstanding[cid]

    def stats(self, cid: int) -> tuple[float, int]:
        return self._stats[cid]

    @property
    def outstanding(self) -> int:
        return sum(self._outstanding)

    # -- wave shim ------------------------------------------------------
    def drain(self, concurrent: bool | None = None
              ) -> list[tuple[list[Completion], float, float, int]]:
        """Pump until every in-flight request completed; per-container
        results for ``assemble_wave``. ``concurrent`` is accepted for
        protocol compatibility and ignored — processes always overlap
        (that is the point of this backend). Wall/busy/token deltas are
        measured from the buffered stats at call entry, so a warm backend
        reports per-wave numbers, not lifetime cumulatives."""
        del concurrent
        stats0 = list(self._stats)
        t0 = time.perf_counter()
        comps: list[list[Completion]] = [[] for _ in range(self.capacity)]
        last = [t0] * self.capacity
        # route events already buffered (e.g. zero-budget completions
        # flushed before drain was called) plus everything still to come
        pending = list(self._events)
        self._events.clear()
        while True:
            for ev in pending:
                if isinstance(ev, DoneEvent):
                    comps[ev.container_id].append(ev.completion)
                    last[ev.container_id] = time.perf_counter()
            if self.outstanding <= 0:
                break
            self._pump(block_s=_IDLE_POLL_S)
            pending = list(self._events)
            self._events.clear()
        return [(comps[cid], last[cid] - t0,
                 self._stats[cid][0] - stats0[cid][0],
                 self._stats[cid][1] - stats0[cid][1])
                for cid in range(self.capacity)]
