"""``ContainerBackend`` — one protocol behind every container flavour.

PR 1–4 grew three parallel serving hierarchies: thread-per-container
engines (``pool.py``), pinned OS processes (``process_pool.py``) and
sub-mesh-committed engines (the mesh-aware engine paths). This module
refactors their execution machinery behind one request-level protocol so
the ``Router`` (serving/router.py) and the wave-shim pools are written
once, against:

    capacity                       # number of containers
    submit(cid, req)               # enqueue one request on a container
    poll() -> list[Event]          # advance + drain streamed events
    load(cid) -> int               # queued+active requests (dispatch)
    stats(cid) -> (busy_s, tokens) # cumulative counters (energy/windows)
    drain(concurrent) -> [...]     # wave shim: run all containers idle
    close()                        # release engines / children

``poll`` is pull-driven: callers that want progress call it, each call
advances every container that has work by at most one engine macro-step
and returns the events that materialised (see serving/events.py — one
``ChunkEvent`` per request per macro-step, a ``DoneEvent`` per
completion). ``drain`` is the wave fast-path: it runs every container to
idle (concurrently for real backends) and returns the per-container
``(completions, wall_s, busy_s, tokens)`` tuples that
``pool.assemble_wave`` has consumed since PR 4 — which is what keeps the
PR 1–4 parity suites green through the wave shim.

Three implementations:

* ``ThreadBackend`` — one ``ServingEngine`` per container in this
  process (jax releases the GIL during XLA dispatch, so engines overlap
  on the shared device); the PR 1 pool's machinery.
* ``SubmeshBackend`` — ``ThreadBackend`` whose engines are committed to
  pairwise-disjoint device sub-meshes (PR 3's physical placement; the
  disjointness validation lives here now).
* ``ProcessBackend`` — one OS process per container pinned to a disjoint
  core set before jax initialises (PR 4's ``docker run --cpus``
  mechanism). Children host a ``ServingEngine`` behind a streaming pipe
  protocol: ``("submit", [Request...])`` in, ``("events", [Event...],
  busy_s, tokens)`` out after every engine step — so chunk events cross
  the process boundary with the same shape as thread events, and the
  parent's ``stats`` are the child's own counters. Params reach children
  by seeded re-init, ``.npz`` handoff (``save_params``) or — new — a
  ``multiprocessing.shared_memory`` mapping (``share_params``) that
  skips the copy through the filesystem.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import threading
import time
import traceback
from collections import deque
from typing import Any, Callable, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.testbed import assign_core_sets, spawn_pinned
# the child body and everything its spawn payload unpickles pre-affinity
# live in serving/child.py (import-light by contract — see its docstring)
from repro.serving.child import (_IDLE_POLL_S, SharedParams, _load_params,
                                 _load_params_shm, _serving_child)
from repro.serving.engine import (Completion, EngineConfig, Request,
                                  ServingEngine)
from repro.serving.events import (ContainerFailure, DoneEvent, Event,
                                  FailedEvent)
from repro.serving.faults import FaultInjector, FaultPlan, describe_exitcode

_READY_POLL_S = 0.25


@runtime_checkable
class ContainerBackend(Protocol):
    """The request-level serving protocol (see module docstring).

    Supervising backends additionally expose an *optional* fault-
    tolerance surface the Router discovers with ``getattr`` (so minimal
    structural backends — test substrates — keep satisfying the
    protocol): ``alive(cid) -> bool`` (dispatchable right now — dead and
    respawning containers are excluded), ``cancel(cid, rid)`` (remove a
    request wherever it is and free its cache reservation), and a
    ``failures`` list of every ``ContainerFailure`` surfaced so far.
    ``poll()`` may interleave ``ContainerFailure`` records with the
    request events — it must NOT raise for a container-scoped failure,
    only for backend-wide invariant violations."""

    capacity: int

    def submit(self, cid: int, req: Request) -> None: ...

    def poll(self) -> list[Event]: ...

    def load(self, cid: int) -> int: ...

    def stats(self, cid: int) -> tuple[float, int]: ...

    def drain(self, concurrent: bool = True
              ) -> list[tuple[list[Completion], float, float, int]]: ...

    def close(self) -> None: ...


def validate_disjoint_meshes(meshes: Sequence[Any],
                             n_containers: int) -> None:
    """Per-container sub-meshes must be pairwise disjoint device slices —
    that IS the isolation claim sub-mesh placement rests on."""
    if len(meshes) != n_containers:
        raise ValueError(f"{len(meshes)} meshes for "
                         f"{n_containers} containers")
    sets = [frozenset(m.devices.flat) for m in meshes]
    for i, a in enumerate(sets):
        for b in sets[i + 1:]:
            if a & b:
                raise ValueError(
                    "container sub-meshes overlap: "
                    f"{sorted(d.id for d in a & b)}")


# ---------------------------------------------------------------------------
# in-process backends (thread / submesh)
# ---------------------------------------------------------------------------
class ThreadBackend:
    """One ServingEngine per container in this process. ``poll`` advances
    active engines one macro-step each — in worker threads when more than
    one container has work, so streaming overlaps the same way waves do —
    and ``drain`` runs each engine's ``run()`` to idle (thread-per-
    container, the PR 1 wave machinery verbatim).

    Supervision: an engine whose ``step()`` raises is *failed*, not
    propagated — ``poll()`` appends a ``ContainerFailure`` (kind
    ``"error"``, with the in-flight rids) to the event stream and, while
    the respawn budget lasts, rebuilds the engine in place from the kept
    model/params (incarnation bumped, so a ``FaultPlan`` scoped to
    incarnation 0 does not re-fire). After ``max_respawns`` rebuilds the
    circuit breaker trips: the container stays dead, ``alive()`` is
    False, and submits to it raise. ``drain`` keeps the wave contract
    (raise on any failure) — waves have no per-request recovery path."""

    kind = "thread"

    def __init__(self, model, params, n_containers: int,
                 n_slots_per_container: int = 4, max_len: int = 512,
                 engine_factory: Callable[..., ServingEngine] | None = None,
                 meshes: Sequence[Any] | None = None,
                 concurrent: bool = True,
                 config: EngineConfig | None = None,
                 fault_plan: FaultPlan | None = None,
                 max_respawns: int = 2):
        if meshes is not None:
            validate_disjoint_meshes(meshes, n_containers)
        self.capacity = n_containers
        self.model = model
        self.params = params
        self.meshes = meshes
        self.concurrent = concurrent
        self.config = config or EngineConfig(
            n_slots=n_slots_per_container, max_len=max_len)
        self.fault_plan = fault_plan
        self.max_respawns = max_respawns
        self._engine_factory = engine_factory
        self._events: deque[Event] = deque()   # append is GIL-atomic
        self._executor = None                  # lazy; poll-step overlap
        self.failures: list[ContainerFailure] = []
        self._alive = [True] * n_containers
        self._respawns = [0] * n_containers
        self._incarnation = [0] * n_containers
        # dead engines leave cumulative busy/tokens behind; the rebuilt
        # engine restarts at zero, so stats() adds the pre-failure base
        # or window deltas would go negative across a respawn
        self._stats_base = [(0.0, 0)] * n_containers
        self.engines: list[ServingEngine] = [
            self._build_engine(cid, 0) for cid in range(n_containers)]

    def _build_engine(self, cid: int, incarnation: int) -> ServingEngine:
        mesh_kw = ({"mesh": self.meshes[cid]}
                   if self.meshes is not None else {})
        if self._engine_factory is None:
            eng = ServingEngine(self.model, self.params, self.config,
                                **mesh_kw)
        else:
            # custom factories (tests, instrumented engines) keep the
            # legacy call style; their forwarding path warns once
            eng = self._engine_factory(self.model, self.params,
                                       n_slots=self.config.n_slots,
                                       max_len=self.config.max_len,
                                       **mesh_kw)
        eng.container_id = cid
        eng.on_event = self._events.append
        if self.fault_plan is not None:
            inj = FaultInjector(self.fault_plan, cid, incarnation)
            eng.fault = inj if inj.armed else None
        return eng

    def _fail_container(self, cid: int, exc: BaseException) -> None:
        """Convert an engine-step exception into a ContainerFailure event
        and either rebuild the engine (bounded) or trip the breaker."""
        eng = self.engines[cid]
        lost = tuple(r.rid for r in eng.queue) + tuple(
            s.rid for s in eng.slots if s.active)
        tb = "".join(traceback.format_exception(
            type(exc), exc, exc.__traceback__))
        fail = ContainerFailure(
            container_id=cid, kind="error",
            message=f"engine step raised:\n{tb}",
            time_s=time.perf_counter(), lost_rids=lost)
        self.failures.append(fail)
        self._events.append(fail)
        base_b, base_t = self._stats_base[cid]
        self._stats_base[cid] = (base_b + eng.busy_s,
                                 base_t + eng.tokens_generated)
        if self._respawns[cid] < self.max_respawns:
            self._respawns[cid] += 1
            self._incarnation[cid] += 1
            # in-process "respawn": a fresh engine over the same (kept)
            # model/params — jit caches are shared process-wide, so this
            # is cheap and immediately serving
            self.engines[cid] = self._build_engine(
                cid, self._incarnation[cid])
        else:
            self._alive[cid] = False

    # -- supervision surface -------------------------------------------
    def alive(self, cid: int) -> bool:
        return self._alive[cid]

    def cancel(self, cid: int, rid: int) -> None:
        """Remove ``rid`` from container ``cid`` wherever it is (queued
        or mid-decode) and free its cache reservation. No event is
        emitted — the canceller owns the terminal event."""
        if self._alive[cid]:
            self.engines[cid].cancel(rid)

    # -- streaming ------------------------------------------------------
    def submit(self, cid: int, req: Request) -> None:
        if not self._alive[cid]:
            raise RuntimeError(f"container {cid} is circuit-broken "
                               f"(after {self._respawns[cid]} respawns)")
        self.engines[cid].submit(req)

    def submit_many(self, cid: int, reqs: Sequence[Request]) -> None:
        if not self._alive[cid]:
            raise RuntimeError(f"container {cid} is circuit-broken "
                               f"(after {self._respawns[cid]} respawns)")
        self.engines[cid].submit_many(reqs)

    def poll(self) -> list[Event]:
        active = [eng for cid, eng in enumerate(self.engines)
                  if self._alive[cid] and eng.has_work]
        failed: list[tuple[int, BaseException]] = []
        if self.concurrent and len(active) > 1:
            if self._executor is None:
                # persistent workers: a stream polls once per macro-step
                # for its whole life — per-poll thread spawns would churn
                from concurrent.futures import ThreadPoolExecutor
                self._executor = ThreadPoolExecutor(
                    max_workers=self.capacity,
                    thread_name_prefix="container-step")
            futures = [(eng, self._executor.submit(eng.step))
                       for eng in active]
            for eng, f in futures:      # join ALL steps before failing —
                try:                    # a swallowed error would hang the
                    f.result()          # stream waiting for a DoneEvent
                except BaseException as e:
                    failed.append((eng.container_id, e))
        else:
            for eng in active:
                try:
                    eng.step()
                except BaseException as e:
                    failed.append((eng.container_id, e))
        for cid, exc in failed:
            self._fail_container(cid, exc)
        for eng in self.engines:
            # poll-driven consumers take completions from DoneEvents;
            # nobody calls run() on a streamed engine, so drain its done
            # list (all engines — zero-budget submissions complete at
            # submit, without the engine ever becoming active) or a
            # long-lived stream accumulates one Completion per request
            # and a later wave drain() would return the stale backlog
            eng.done.clear()
        out: list[Event] = []
        while self._events:
            out.append(self._events.popleft())
        return out

    def load(self, cid: int) -> int:
        eng = self.engines[cid]
        return len(eng.queue) + sum(1 for s in eng.slots if s.active)

    def stats(self, cid: int) -> tuple[float, int]:
        eng = self.engines[cid]
        base_b, base_t = self._stats_base[cid]
        return base_b + eng.busy_s, base_t + eng.tokens_generated

    # -- wave shim ------------------------------------------------------
    def drain(self, concurrent: bool | None = None
              ) -> list[tuple[list[Completion], float, float, int]]:
        """Run every container to idle; per-container results for
        ``assemble_wave``. Wave consumers take completions, not events,
        so the event buffer is cleared afterwards (``engine.run`` emitted
        into it redundantly). Waves have no per-request recovery path, so
        a circuit-broken container fails the whole wave here."""
        dead = [cid for cid in range(self.capacity)
                if not self._alive[cid]]
        if dead:
            raise RuntimeError(
                f"cannot drain a wave: containers {dead} are "
                "circuit-broken (see backend.failures)")
        if concurrent is None:
            concurrent = self.concurrent
        out: list[Any] = [None] * self.capacity

        def run_one(cid: int) -> None:
            try:
                eng = self.engines[cid]
                t0 = time.perf_counter()
                busy0, toks0 = eng.busy_s, eng.tokens_generated
                comps = eng.run()
                out[cid] = (comps, time.perf_counter() - t0,
                            eng.busy_s - busy0,
                            eng.tokens_generated - toks0)
            except BaseException as e:  # propagate across the thread join
                out[cid] = e

        if concurrent and self.capacity > 1:
            workers = [threading.Thread(target=run_one, args=(cid,),
                                        daemon=True)
                       for cid in range(self.capacity)]
            for w in workers:
                w.start()
            for w in workers:
                w.join()
        else:
            for cid in range(self.capacity):
                run_one(cid)
        self._events.clear()
        for e in out:
            if isinstance(e, BaseException):
                raise e
        return out

    def close(self) -> None:
        if self._executor is not None:
            self._executor.shutdown(wait=False)
            self._executor = None
        self._events.clear()
        self.engines = []
        self.capacity = 0


class SubmeshBackend(ThreadBackend):
    """ThreadBackend whose engines are committed to disjoint device
    sub-meshes (``launch/mesh.make_container_meshes``) — the containers
    are physical on the device axis, so the threads overlap real parallel
    hardware instead of one shared device."""

    kind = "submesh"

    def __init__(self, model, params, n_containers: int,
                 n_slots_per_container: int = 4, max_len: int = 512,
                 engine_factory: Callable[..., ServingEngine] | None = None,
                 meshes: Sequence[Any] | None = None,
                 concurrent: bool = True,
                 config: EngineConfig | None = None,
                 fault_plan: FaultPlan | None = None,
                 max_respawns: int = 2):
        if meshes is None:
            raise ValueError("SubmeshBackend needs per-container meshes "
                             "(launch/mesh.make_container_meshes)")
        super().__init__(model, params, n_containers,
                         n_slots_per_container=n_slots_per_container,
                         max_len=max_len, engine_factory=engine_factory,
                         meshes=meshes, concurrent=concurrent,
                         config=config, fault_plan=fault_plan,
                         max_respawns=max_respawns)


# ---------------------------------------------------------------------------
# params handoff for process containers
# ---------------------------------------------------------------------------
def save_params(params: Any, path: str) -> str:
    """Write a params tree to ``path`` (.npz, leaves in tree order) for the
    cross-process handoff: children rebuild the tree structure from
    ``jax.eval_shape(model.init, ...)`` and unflatten these leaves — exact
    float bytes, so parity with the parent's params is preserved."""
    import jax
    leaves = jax.tree_util.tree_leaves(params)
    np.savez(path, **{f"leaf{i}": np.asarray(leaf)
                      for i, leaf in enumerate(leaves)})
    return path


class ParamsShare:
    """Parent-side owner of the shared block. Keep it alive while any
    child may attach; ``close()`` unlinks the segment. Pass ``.handle``
    (the picklable SharedParams) to pools/backends."""

    def __init__(self, shm, handle: SharedParams):
        self._shm = shm
        self.handle = handle

    def close(self) -> None:
        if self._shm is None:
            return
        shm, self._shm = self._shm, None
        shm.close()
        try:
            shm.unlink()
        except FileNotFoundError:
            pass

    def __enter__(self) -> "ParamsShare":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass


def share_params(params: Any) -> ParamsShare:
    """Lay the params tree's leaves out back-to-back in one shared-memory
    segment (leaves in tree order, byte-exact, so parity with the parent's
    params is preserved — same contract as ``save_params``)."""
    import jax
    from multiprocessing import shared_memory
    leaves = [np.asarray(leaf) for leaf in jax.tree_util.tree_leaves(params)]
    specs, offset = [], 0
    for leaf in leaves:
        # leaves are aligned to their itemsize so the child-side ndarray
        # views are valid for any dtype
        align = max(leaf.dtype.itemsize, 1)
        offset = (offset + align - 1) // align * align
        specs.append((leaf.shape, leaf.dtype.str, offset))
        offset += leaf.nbytes
    shm = shared_memory.SharedMemory(create=True, size=max(offset, 1))
    for leaf, (shape, dtype, off) in zip(leaves, specs):
        dst = np.ndarray(shape, dtype=dtype, buffer=shm.buf, offset=off)
        dst[...] = leaf
    handle = SharedParams(shm.name, tuple(specs), offset)
    return ParamsShare(shm, handle)


# ---------------------------------------------------------------------------
# process backend
# ---------------------------------------------------------------------------
def _engine_config_wire(config: EngineConfig) -> dict:
    """EngineConfig as a dict of picklable primitives. Pickling the
    dataclass itself would make the child unpickle (hence import
    repro.serving.engine, hence jax) at process bootstrap — BEFORE
    ``spawn_pinned`` applies the cpuset — so the config crosses the pipe
    as plain fields with the dtype by name instead."""
    kw = dataclasses.asdict(config)
    kw["dtype"] = np.dtype(kw["dtype"]).name
    return kw


class ProcessBackend:
    """One pinned OS process per container (the paper's ``--cpus``
    shares), behind the streaming ContainerBackend protocol. Children
    spawn lazily at first submit and stay warm until ``close()`` —
    engines, compiled executables and params survive across waves and
    streams, which is what makes process isolation affordable inside an
    online loop.

    Supervision: a child that dies (exitcode decoded via
    ``serving.faults.describe_exitcode``), reports a step error, or goes
    silent past the heartbeat timeout is *failed*, not raised — ``poll``
    surfaces a ``ContainerFailure`` carrying its in-flight rids, and
    while the respawn budget lasts a replacement child is launched
    *non-blocking* (exponential backoff; the pending handshake is
    promoted from later ``poll`` calls, so healthy containers keep
    serving through a respawn's jax import + warmup). The params handoff
    re-runs through the same path as the original spawn, so keep the
    ``.npz`` file / shared-memory segment alive while the backend is.
    After ``max_respawns`` replacements a container's circuit breaker
    trips: ``alive()`` stays False and the Router routes around it.
    ``drain`` keeps the wave contract — any failure tears down the wave
    with an exception, since waves have no per-request recovery."""

    kind = "process"

    def __init__(self, cfg, n_containers: int,
                 n_slots_per_container: int = 4, max_len: int = 512,
                 total_cores: int | None = None,
                 params_seed: int = 0, params_path: str | None = None,
                 params_shm: SharedParams | None = None,
                 greedy: bool = True, seed: int = 0,
                 chunked: bool = True, chunk_tokens: int | None = None,
                 allow_shared_cores: bool = False,
                 start_timeout_s: float = 600.0,
                 config: EngineConfig | None = None,
                 fault_plan: FaultPlan | None = None,
                 max_respawns: int = 2,
                 respawn_backoff_s: float = 0.25,
                 heartbeat_s: float = 0.5,
                 heartbeat_timeout_s: float | None = 60.0):
        self.cfg = cfg
        self.capacity = n_containers
        self.config = config or EngineConfig(
            n_slots=n_slots_per_container, max_len=max_len, greedy=greedy,
            seed=seed, chunked=chunked, chunk_tokens=chunk_tokens)
        # legacy attribute surface (readers predate EngineConfig)
        self.n_slots = self.config.n_slots
        self.max_len = self.config.max_len
        self.greedy = self.config.greedy
        self.seed = self.config.seed
        self.chunked = self.config.chunked
        self.chunk_tokens = self.config.chunk_tokens
        self.params_seed = params_seed
        self.params_path = params_path
        self.params_shm = params_shm
        if params_path and params_shm:
            raise ValueError("pass params_path or params_shm, not both")
        self.start_timeout_s = start_timeout_s
        self.fault_plan = fault_plan
        self.max_respawns = max_respawns
        self.respawn_backoff_s = respawn_backoff_s
        self.heartbeat_s = heartbeat_s
        self.heartbeat_timeout_s = (
            heartbeat_timeout_s if heartbeat_s > 0 else None)
        # fail fast, before any spawn: more containers than cores cannot
        # be disjoint (see core/testbed.assign_core_sets)
        self.core_sets = assign_core_sets(n_containers,
                                         total_cores=total_cores,
                                         allow_shared=allow_shared_cores)
        self.reported_core_sets: list[frozenset[int]] | None = None
        # workers[cid] is (proc, conn) while serving, None while dead or
        # respawning (the pending handshake lives in _spawning[cid])
        self.workers: list[tuple[Any, Any] | None] | None = None
        self._events: deque[Event] = deque()
        self.failures: list[ContainerFailure] = []
        # rid-sets, not counts: a lost container must say WHICH requests
        # died with it, and cancel() must be race-safe against a
        # completion already in the pipe
        self._inflight: list[set[int]] = [set() for _ in range(n_containers)]
        self._alive = [True] * n_containers
        self._respawns = [0] * n_containers
        self._incarnation = [0] * n_containers
        self._backoff = [respawn_backoff_s] * n_containers
        self._next_spawn = [0.0] * n_containers
        self._spawning: list[tuple[Any, Any] | None] = [None] * n_containers
        self._last_msg = [0.0] * n_containers
        # child counters restart at zero each incarnation; stats() adds
        # the accumulated pre-failure base so window deltas stay monotone
        self._stats_child = [(0.0, 0)] * n_containers
        self._stats_base = [(0.0, 0)] * n_containers

    # -- lifecycle ------------------------------------------------------
    def warm(self) -> None:
        """Public warm-up: spawn + handshake the children now, so a wave
        shim (or a latency-sensitive caller) can pay the spawn+compile
        cost outside its timed region."""
        self._ensure_workers()

    def _spawn_one(self, cid: int, incarnation: int) -> tuple[Any, Any]:
        ctx = mp.get_context("spawn")
        return spawn_pinned(
            _serving_child, self.core_sets[cid],
            args=(cid, self.cfg, self.params_seed, self.params_path,
                  self.params_shm, _engine_config_wire(self.config),
                  incarnation, self.fault_plan, self.heartbeat_s),
            ctx=ctx)

    def _ensure_workers(self) -> None:
        """Spawn + handshake all children once; engines stay warm across
        waves (the per-count pool caches rely on this). The INITIAL spawn
        stays fail-fast (blocking handshake, raise on any startup error)
        — supervision begins once a container has served."""
        if self.workers is not None:
            return
        workers = [self._spawn_one(cid, 0) for cid in range(self.capacity)]
        reported = []
        try:
            for cid, (proc, conn) in enumerate(workers):
                msg = self._recv(proc, conn, self.start_timeout_s)
                if msg[0] != "ready":
                    raise RuntimeError(
                        f"container {cid} failed to start:\n{msg[1]}")
                reported.append(frozenset(msg[1]))
        except BaseException:
            for proc, _ in workers:
                proc.terminate()
            raise
        self.workers = list(workers)
        self.reported_core_sets = reported
        now = time.perf_counter()
        self._alive = [True] * self.capacity
        self._last_msg = [now] * self.capacity

    @staticmethod
    def _recv(proc, conn, timeout_s: float | None):
        """recv that notices a dead child instead of blocking forever."""
        deadline = (None if timeout_s is None
                    else time.perf_counter() + timeout_s)
        while not conn.poll(_READY_POLL_S):
            if not proc.is_alive():
                raise RuntimeError(
                    f"container process died (exit {proc.exitcode}) "
                    "before replying")
            if deadline is not None and time.perf_counter() > deadline:
                raise TimeoutError("container start/serve timed out")
        return conn.recv()

    def close(self) -> None:
        """Shut the warm children down (idempotent), including any
        respawn still mid-handshake — nothing may orphan. Cached backends
        evicted by adaptive facades call this so children never leak."""
        if self.workers is None:
            return
        workers, self.workers = self.workers, None
        spawning, self._spawning = (self._spawning,
                                    [None] * self.capacity)
        self._events.clear()
        self._inflight = [set() for _ in range(self.capacity)]
        # reopened (lazily respawned) children restart their counters at
        # zero — stale cumulatives would make the next wave's deltas
        # negative
        self._stats_child = [(0.0, 0)] * self.capacity
        self._stats_base = [(0.0, 0)] * self.capacity
        self._alive = [True] * self.capacity
        self._respawns = [0] * self.capacity
        self._incarnation = [0] * self.capacity
        self._backoff = [self.respawn_backoff_s] * self.capacity
        self._next_spawn = [0.0] * self.capacity
        for w in workers:
            if w is None:
                continue
            try:
                w[1].send(("close",))
            except (BrokenPipeError, OSError):
                pass
        for w in workers:
            if w is None:
                continue
            proc, conn = w
            proc.join(timeout=10)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=5)
            conn.close()
        for sp in spawning:
            if sp is None:
                continue
            proc, conn = sp
            proc.terminate()
            proc.join(timeout=5)
            conn.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    # -- supervision ----------------------------------------------------
    def alive(self, cid: int) -> bool:
        """Dispatchable right now. True before first spawn (children are
        lazy); False while dead, respawning, or circuit-broken."""
        return self._alive[cid]

    def cancel(self, cid: int, rid: int) -> None:
        """Forget ``rid`` parent-side and ask the child to drop it. Safe
        against the race where its DoneEvent is already in the pipe: the
        rid is discarded (not asserted present), the child's cancel of a
        finished request is a no-op, and the stale DoneEvent is still
        delivered (the canceller's event routing must tolerate it)."""
        self._inflight[cid].discard(rid)
        w = self.workers[cid] if self.workers is not None else None
        if w is not None and self._alive[cid]:
            try:
                w[1].send(("cancel", rid))
            except (BrokenPipeError, OSError):
                pass                    # death is _pump's to notice

    def _fail(self, cid: int, kind: str, message: str,
              exitcode: int | None = None) -> None:
        """Record one container failure: emit the typed event (with the
        lost rids), fold the dead incarnation's counters into the stats
        base, reap the child, and schedule a bounded respawn."""
        now = time.perf_counter()
        lost = tuple(sorted(self._inflight[cid]))
        self._inflight[cid] = set()
        base_b, base_t = self._stats_base[cid]
        child_b, child_t = self._stats_child[cid]
        self._stats_base[cid] = (base_b + child_b, base_t + child_t)
        self._stats_child[cid] = (0.0, 0)
        fail = ContainerFailure(
            container_id=cid, kind=kind,
            message=f"container {cid} {kind}: {message}",
            time_s=now, exitcode=exitcode, lost_rids=lost)
        self.failures.append(fail)
        self._events.append(fail)
        self._alive[cid] = False
        w = self.workers[cid] if self.workers is not None else None
        if w is not None:
            proc, conn = w
            self.workers[cid] = None
            try:
                conn.close()
            except OSError:
                pass
            if proc.is_alive():
                proc.terminate()
            proc.join(timeout=5)
        if self._respawns[cid] < self.max_respawns:
            self._next_spawn[cid] = now + self._backoff[cid]
            self._backoff[cid] = min(self._backoff[cid] * 2, 30.0)

    def _record_start_failure(self, cid: int, detail: str,
                              exitcode: int | None) -> None:
        now = time.perf_counter()
        fail = ContainerFailure(
            container_id=cid, kind="start",
            message=f"container {cid} respawn failed to start: {detail}",
            time_s=now, exitcode=exitcode, lost_rids=())
        self.failures.append(fail)
        self._events.append(fail)
        self._next_spawn[cid] = now + self._backoff[cid]
        self._backoff[cid] = min(self._backoff[cid] * 2, 30.0)

    def _service_respawns(self) -> None:
        """Non-blocking respawn driver, run on every pump: launch
        replacements whose backoff expired, promote pending handshakes
        that completed — healthy containers never wait on a respawning
        one's jax import + engine build."""
        if self.workers is None:
            return
        now = time.perf_counter()
        for cid in range(self.capacity):
            if self._alive[cid]:
                continue
            sp = self._spawning[cid]
            if sp is not None:
                proc, conn = sp
                msg = None
                try:
                    if conn.poll(0):
                        msg = conn.recv()
                except (EOFError, OSError):
                    msg = ("error", "handshake pipe closed")
                if msg is not None and msg[0] == "ready":
                    self._spawning[cid] = None
                    self.workers[cid] = (proc, conn)
                    if self.reported_core_sets is not None:
                        self.reported_core_sets[cid] = frozenset(msg[1])
                    self._alive[cid] = True
                    self._last_msg[cid] = now
                    self._backoff[cid] = self.respawn_backoff_s
                elif msg is not None or not proc.is_alive():
                    self._spawning[cid] = None
                    detail = (msg[1] if msg is not None
                              else describe_exitcode(proc.exitcode))
                    exitcode = proc.exitcode
                    if proc.is_alive():
                        proc.terminate()
                    proc.join(timeout=5)
                    try:
                        conn.close()
                    except OSError:
                        pass
                    self._record_start_failure(cid, detail, exitcode)
                continue
            if (self._respawns[cid] >= self.max_respawns
                    or now < self._next_spawn[cid]):
                continue                # circuit-broken, or backing off
            self._respawns[cid] += 1
            self._incarnation[cid] += 1
            self._spawning[cid] = self._spawn_one(
                cid, self._incarnation[cid])

    # -- streaming ------------------------------------------------------
    def submit(self, cid: int, req: Request) -> None:
        self.submit_many(cid, [req])

    def submit_many(self, cid: int, reqs: Sequence[Request]) -> None:
        if not reqs:
            return
        self._ensure_workers()
        assert self.workers is not None
        if not self._alive[cid]:
            raise RuntimeError(
                f"container {cid} is not serving (dead, respawning or "
                "circuit-broken — check alive() before dispatch)")
        # inflight BEFORE send: if the pipe breaks mid-send the rids ride
        # the ContainerFailure's lost_rids and the Router's normal retry
        # path recovers them — no separate submit-error path
        self._inflight[cid].update(r.rid for r in reqs)
        _, conn = self.workers[cid]
        try:
            conn.send(("submit", list(reqs)))
        except (BrokenPipeError, OSError) as e:
            self._fail(cid, "dead", f"submit pipe broke: {e}")

    def _route_ready(self, cid: int, conn) -> bool:
        """Drain every buffered message from one serving child. Never
        raises: a closed pipe just ends the drain (death is the liveness
        scan's to classify, with the exitcode in hand)."""
        got = False
        while True:
            try:
                if not conn.poll(0):
                    return got
                msg = conn.recv()
            except (EOFError, OSError):
                return got
            got = True
            self._last_msg[cid] = time.perf_counter()
            if msg[0] == "hb":
                continue
            if msg[0] == "error":
                self._fail(cid, "error",
                           f"engine step raised:\n{msg[1]}",
                           exitcode=None)
                return got
            _, events, busy, toks = msg
            self._stats_child[cid] = (busy, toks)
            for ev in events:
                if isinstance(ev, (DoneEvent, FailedEvent)):
                    self._inflight[cid].discard(ev.rid)
                self._events.append(ev)

    def _pump(self, block_s: float = 0.0) -> bool:
        """Drain every ready child message into the event buffer; with
        ``block_s`` wait up to that long for the first one. Container
        failures (death, step error, heartbeat silence) become
        ``ContainerFailure`` events in the buffer — never exceptions —
        and replacements are serviced, all without blocking healthy
        containers."""
        if self.workers is None:
            return False
        self._service_respawns()
        conn_map = {w[1]: cid for cid, w in enumerate(self.workers)
                    if w is not None and self._alive[cid]}
        if conn_map and block_s > 0:
            from multiprocessing.connection import wait as conn_wait
            conn_wait(list(conn_map), block_s)
        got = False
        for conn, cid in list(conn_map.items()):
            got |= self._route_ready(cid, conn)
        now = time.perf_counter()
        for cid in range(self.capacity):
            w = self.workers[cid]
            if w is None or not self._alive[cid]:
                continue
            proc, conn = w
            if not proc.is_alive():
                # the child may have flushed replies (even its "error"
                # report) right before dying — consume them first so no
                # completed request is counted lost
                self._route_ready(cid, conn)
                if self._alive[cid]:
                    self._fail(
                        cid, "dead",
                        "child process exited mid-serve "
                        f"({describe_exitcode(proc.exitcode)}) with "
                        f"{len(self._inflight[cid])} requests in flight",
                        exitcode=proc.exitcode)
            elif (self.heartbeat_timeout_s is not None
                  and now - self._last_msg[cid] > self.heartbeat_timeout_s):
                self._fail(
                    cid, "hung",
                    f"no message for {now - self._last_msg[cid]:.1f}s "
                    f"(heartbeat timeout {self.heartbeat_timeout_s:g}s)")
        return got

    def poll(self) -> list[Event]:
        self._pump()
        out = list(self._events)
        self._events.clear()
        return out

    def load(self, cid: int) -> int:
        return len(self._inflight[cid])

    def stats(self, cid: int) -> tuple[float, int]:
        base_b, base_t = self._stats_base[cid]
        child_b, child_t = self._stats_child[cid]
        return base_b + child_b, base_t + child_t

    @property
    def outstanding(self) -> int:
        return sum(len(s) for s in self._inflight)

    # -- wave shim ------------------------------------------------------
    def drain(self, concurrent: bool | None = None
              ) -> list[tuple[list[Completion], float, float, int]]:
        """Pump until every in-flight request completed; per-container
        results for ``assemble_wave``. ``concurrent`` is accepted for
        protocol compatibility and ignored — processes always overlap
        (that is the point of this backend). Wall/busy/token deltas are
        measured from the buffered stats at call entry, so a warm backend
        reports per-wave numbers, not lifetime cumulatives.

        Waves have no per-request recovery: any ``ContainerFailure``
        surfaced while draining tears the wave down with an exception
        (children closed — their pipes hold replies for a wave that no
        longer exists) instead of hanging on requests that died with
        their container."""
        del concurrent
        n_fail0 = len(self.failures)
        stats0 = [self.stats(cid) for cid in range(self.capacity)]
        t0 = time.perf_counter()
        comps: list[list[Completion]] = [[] for _ in range(self.capacity)]
        last = [t0] * self.capacity
        # route events already buffered (e.g. zero-budget completions
        # flushed before drain was called) plus everything still to come
        pending = list(self._events)
        self._events.clear()
        while True:
            for ev in pending:
                if isinstance(ev, DoneEvent):
                    comps[ev.container_id].append(ev.completion)
                    last[ev.container_id] = time.perf_counter()
            if len(self.failures) > n_fail0:
                fail = self.failures[-1]
                self.close()
                raise RuntimeError(f"wave failed: {fail.message}")
            if self.outstanding <= 0:
                break
            self._pump(block_s=_IDLE_POLL_S)
            pending = list(self._events)
            self._events.clear()
        return [(comps[cid], last[cid] - t0,
                 self.stats(cid)[0] - stats0[cid][0],
                 self.stats(cid)[1] - stats0[cid][1])
                for cid in range(self.capacity)]
