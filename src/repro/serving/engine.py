"""Continuous-batching serving engine with fused multi-token decode.

Slot-based: the engine owns a KV cache with ``n_slots`` sequences. Queued
requests are admitted with **batched bucket admission**: all waiting
prompts that fall in the same padded-length bucket (up to the number of
free slots) are prefilled in ONE compiled call — per-row ``logits_at``
indices make ragged real lengths inside a bucket exact — then all active
slots decode in lockstep HLO with per-slot positions (the cache/ring masks
make ragged depths correct — see models/attention.py). Finished slots are
refilled from the queue mid-decode: continuous batching.

With ``EngineConfig(cache="paged")`` the dense rows are replaced by the
block/paged KV cache (models/cache.py + serving/cache.py): admission
reserves ``ceil(tokens / block_size)`` physical pages per request out of
a shared pool, so in-flight concurrency is bounded by the BLOCK budget,
not ``n_slots``, and ragged prompts pay no cache padding (prefill still
pads its compute batch to ``PROMPT_BUCKETS`` to bound compiled shapes).
Admission is strict FIFO with no bucket barrier: consecutive queue heads
sharing an admit key batch into one prefill, and a head that doesn't fit
stalls admission rather than being scanned past. Greedy decode through
the paged path is bit-identical to the dense baseline — masked (scratch
/ garbage) positions contribute an exact 0.0 to the attention
accumulator, the parity the paged tests pin down.

Decode runs in **macro-steps**: each ``step()`` admits, then runs one
fused chunk of up to ``chunk_tokens`` decode iterations entirely on
device (``Model.decode_chunk`` — a ``lax.scan`` with sampling and stop
conditions in-graph), paying one XLA dispatch and one host transfer per
chunk instead of per token. The chunk jit **donates the KV cache** (as
does the admission row-scatter), so decode never copies the cache —
after a step the previous cache buffers are invalid, which is why the
engine always replaces ``self.cache`` with the returned tree. Chunk
length defaults to the roofline cost model
(``core/roofline.decode_chunk_tokens``) and is clamped each step by the
shortest ``remaining`` among active slots (and their ``max_len``
headroom) so no decode iteration is wasted on a finished slot.
``chunked=False`` keeps the one-dispatch-per-token path as a measurable
baseline (see benchmarks/decode_throughput.py).

The engine is step-driven and non-blocking at the scheduling level:
``step()`` performs at most one admission round plus one decode chunk and
returns whether work remains, so a pool can interleave many engines (one
per container) from worker threads — jax releases the GIL during device
dispatch, which is what makes the concurrent container pool in
serving/pool.py actually overlap. ``busy_s`` accumulates the wall time the
engine spent inside ``step()`` and feeds the pool's energy proxy;
``tokens_generated`` counts emitted tokens at the same per-chunk
granularity, so pools can surface per-container tokens/s.

Engines sharing one ``Model`` share jitted prefill/decode executables
(module-level cache) so an n-container pool compiles each shape once, not
n times (jit re-specialises per device placement under that cache, so
engines on different sub-meshes stay correct).

An engine can be **pinned to a sub-mesh**: pass ``mesh`` (one of the
disjoint per-container meshes from ``launch/mesh.make_container_meshes``)
and the engine instantiates ``ShardingRules`` on it and commits its params
and KV cache onto that device slice with ONE ``jax.device_put`` replication
at construction — reused across every wave the pool serves. All jitted
calls then execute on the sub-mesh (committed inputs pin the computation),
cache donation included, and outputs never leave the slice; replicated
placement keeps the container bit-identical to the single-device baseline
(see launch/sharding.ShardingRules.container_placement).

This is the per-container serving loop; core/splitter.py +
serving/pool.py run n of these over disjoint resource shares — the paper's
method end-to-end.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
import warnings
import weakref
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.roofline import decode_chunk_tokens
from repro.models.cache import PagedLayout
from repro.models.model import Model
from repro.serving.cache import DenseCache, PagedCache
from repro.serving.events import ChunkEvent, DoneEvent, FailedEvent


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    extras: dict = dataclasses.field(default_factory=dict)
    # seconds the request may spend in the serving stack before it is
    # cancelled (None = no deadline). The Router stamps its own clock at
    # submit; the engine re-stamps on arrival, so engine-side expiry is
    # a resource-freeing approximation and the Router's check is the
    # authoritative end-to-end one.
    deadline_s: float | None = None
    # SLO class name and tenant id (serving/router.py + workload/slo.py):
    # the Router's priority-ordered dispatch, per-class shed thresholds
    # and per-tenant quotas key on these; the engine itself ignores both.
    priority: str = "default"
    tenant: str = ""


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list
    prompt_len: int
    latency_s: float = 0.0
    # prompt positions satisfied by prefix-cache hits (0 without
    # prefix_cache): the Router aggregates these into WindowStats so the
    # scheduler observes the EFFECTIVE post-hit prefill load
    prefix_hit_tokens: int = 0


# THE prompt-length bucket table. The engine's padded batch admission and
# the router's bucket-aware tie-breaking must agree on it, so it lives
# here once — a paged engine admits at real lengths (no buckets in the
# cache), but its prefill COMPUTE still pads to these buckets to bound
# the number of compiled prefill shapes.
PROMPT_BUCKETS = (16, 32, 64, 128, 256, 512, 1024, 2048)


def _bucket(n: int, buckets=PROMPT_BUCKETS) -> int:
    for b in buckets:
        if n <= b:
            return b
    # past the table: round up to the next power of two, so ragged long
    # prompts share prefill executables instead of each distinct length
    # compiling its own (a compile spike mid-serving)
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Frozen, picklable configuration for one ServingEngine.

    ``cache="dense"`` is the bit-parity baseline: ``n_slots`` private
    ``(max_len, ...)`` cache rows. ``cache="paged"`` switches every
    pageable layer group to the block cache (models/cache.py): a pool of
    ``max_blocks`` shared pages of ``block_size`` tokens, per-sequence
    block tables, and up to ``max_seqs`` resident sequences — in-flight
    concurrency is then bounded by the block budget, not ``n_slots``.

    Defaults keep ``max_blocks`` at the dense footprint
    (``n_slots × max_len / block_size``): same HBM, strictly more
    admissible short requests.
    """
    n_slots: int = 4
    max_len: int = 512
    cache: str = "dense"
    block_size: int = 16
    max_blocks: int | None = None
    max_seqs: int | None = None
    # prefix sharing (paged only): index full prompt blocks by content
    # hash, map new requests' leading blocks onto cache hits (copy-on-
    # write), and prefill only the residual suffix. Architectures the
    # suffix path can't serve bit-exactly (SSM/hybrid state, sliding
    # windows, MLA latents, int8 pages, non-rope positions) silently
    # degrade to no sharing — outputs stay identical either way.
    prefix_cache: bool = False
    dtype: Any = jnp.float32
    greedy: bool = True
    seed: int = 0
    batch_admit: bool = True
    chunked: bool = True
    chunk_tokens: int | None = None

    def __post_init__(self):
        if self.cache not in ("dense", "paged"):
            raise ValueError(f"cache must be 'dense' or 'paged', "
                             f"got {self.cache!r}")
        if self.cache == "paged" and self.max_len % self.block_size:
            raise ValueError(
                f"max_len={self.max_len} must be a multiple of "
                f"block_size={self.block_size} (a sequence's logical "
                "blocks must tile the horizon exactly)")
        if self.prefix_cache and self.cache != "paged":
            raise ValueError("prefix_cache requires cache='paged' (hits "
                             "are shared physical pages)")

    @property
    def resolved_max_blocks(self) -> int:
        if self.max_blocks is not None:
            return self.max_blocks
        return max(1, self.n_slots * self.max_len // self.block_size)

    @property
    def resolved_max_seqs(self) -> int:
        return (self.max_seqs if self.max_seqs is not None
                else self.resolved_max_blocks)

    @property
    def n_rows(self) -> int:
        """Resident-sequence capacity = batch dim of the engine cache."""
        return (self.resolved_max_seqs if self.cache == "paged"
                else self.n_slots)


@dataclasses.dataclass
class _Slot:
    active: bool = False
    rid: int = -1
    pos: int = 0                  # next position to write
    prompt_len: int = 0           # true prompt length, recorded at admission
    remaining: int = 0
    generated: list = dataclasses.field(default_factory=list)
    started: float = 0.0          # perf_counter stamp (monotonic)
    deadline: float | None = None  # absolute perf_counter expiry stamp
    hit_tokens: int = 0           # prefix-cache hit positions (sharing)


# jitted executables shared by every engine built on the same Model —
# populated lazily, keyed by (kind, *static shape info)
_JIT_CACHE: "weakref.WeakKeyDictionary[Model, dict]" = \
    weakref.WeakKeyDictionary()


def _shared_jits(model: Model) -> dict:
    cache = _JIT_CACHE.get(model)
    if cache is None:
        cache = _JIT_CACHE.setdefault(model, {})
    return cache


class ServingEngine:
    # streaming hook: backends set ``on_event`` to receive a ChunkEvent
    # per request per macro-step (built from the chunk's existing host
    # transfer — streaming adds no device syncs) and a DoneEvent per
    # completion; ``container_id`` stamps the emitting container into
    # every event. ``fault`` is the test-only FaultInjector hook
    # (serving/faults.py) consulted at the top of every step and at each
    # paged block allocation. Class-level defaults keep every existing
    # engine_factory signature working unchanged.
    on_event: Callable[[Any], None] | None = None
    container_id: int = 0
    fault: Any = None

    def __init__(self, model: Model, params: Any,
                 config: EngineConfig | None = None, *,
                 mesh=None, rules=None, **legacy_kw):
        if legacy_kw:
            if config is not None:
                raise TypeError(
                    "pass either an EngineConfig or legacy keyword "
                    f"arguments, not both (got {sorted(legacy_kw)})")
            warnings.warn(
                "ServingEngine(model, params, n_slots=..., ...) keyword "
                "arguments are deprecated; pass "
                "ServingEngine(model, params, EngineConfig(...)) instead",
                DeprecationWarning, stacklevel=2)
            config = EngineConfig(**legacy_kw)
        if config is None:
            config = EngineConfig()
        self.config = config
        self.model = model
        self.params = params
        self.n_slots = config.n_slots
        self.max_len = config.max_len
        self.paged = config.cache == "paged"
        self.layout = (PagedLayout(config.block_size,
                                   config.resolved_max_blocks)
                       if self.paged else None)
        n_rows = config.n_rows
        dtype, layout = config.dtype, self.layout
        self.mesh = mesh
        self.rules = rules
        if mesh is not None and rules is None:
            from repro.launch.sharding import ShardingRules
            self.rules = ShardingRules(mesh, train=False, fsdp=False)
        if self.rules is not None:
            # the one per-container placement: params committed onto this
            # container's device slice (reused across waves), and the KV
            # cache allocated directly ON the slice (out_shardings) rather
            # than materialised on the default device and copied over —
            # pool construction must not route n caches through device 0
            self.params = jax.device_put(
                params, self.rules.container_placement(params))
            cache_struct = jax.eval_shape(
                lambda: model.init_cache(n_rows, config.max_len, dtype,
                                         layout=layout))
            tree = jax.jit(
                lambda: model.init_cache(n_rows, config.max_len, dtype,
                                         layout=layout),
                out_shardings=self.rules.container_placement(cache_struct))()
        else:
            tree = model.init_cache(n_rows, config.max_len, dtype,
                                    layout=layout)
        self.device_set = (self.rules.device_set if self.rules is not None
                           else frozenset())
        self.slots = [_Slot() for _ in range(n_rows)]
        self.queue: deque[Request] = deque()
        self.done: list[Completion] = []
        self.greedy = config.greedy
        self.batch_admit = config.batch_admit
        self.chunked = config.chunked
        self.chunk_tokens = (
            config.chunk_tokens if config.chunk_tokens is not None
            else decode_chunk_tokens(
                model.cfg, n_rows,
                context_tokens=config.max_len if self.paged else 0))
        self._key = jax.random.PRNGKey(config.seed)
        self._jits = _shared_jits(model)
        if "decode" not in self._jits:
            self._jits["decode"] = jax.jit(model.decode_step)
        self._decode = self._jits["decode"]
        # which axis of each cache leaf is the batch/slot axis (None for
        # scalar or batch-free leaves) — inferred once from shape structs so
        # row insertion never has to guess from runtime shapes (which is
        # ambiguous when a prefill batch happens to equal n_slots). Always
        # derived from the DENSE layout: it describes the prefill
        # mini-cache rows both backends scatter from.
        ml = config.max_len
        one = jax.eval_shape(lambda: model.init_cache(1, ml, dtype))
        two = jax.eval_shape(lambda: model.init_cache(2, ml, dtype))
        self._batch_axes = jax.tree.map(
            lambda a, b: next((i for i, (x, y) in
                               enumerate(zip(a.shape, b.shape)) if x != y),
                              None), one, two)
        # prefix-sharing eligibility: the suffix-prefill path is bit-exact
        # only for full-horizon rope GQA over all-paged groups — SSM /
        # hybrid state, sliding windows (gemma locals, mixtral), MLA
        # latents, int8 pages and learned positions (whisper) fall back
        # to the plain paged path (hit_tokens stays 0, outputs identical)
        cfg = model.cfg
        self._share = (self.paged and config.prefix_cache
                       and model.fam in ("dense", "moe")
                       and not cfg.mla
                       and cfg.sliding_window == 0
                       and cfg.kv_cache_dtype != "int8"
                       and cfg.pos_embed == "rope")
        if self.paged:
            self.cache_backend = PagedCache(tree, n_rows, layout, ml,
                                            self._batch_axes, self._jits,
                                            prefix_cache=self._share)
        else:
            self.cache_backend = DenseCache(tree, n_rows,
                                            self._batch_axes, self._jits)
        self._deadline_abs: dict[int, float] = {}  # rid -> expiry (queued)
        self.steps = 0                # step() calls that found work
        self.chunks = 0               # fused decode chunks dispatched
        self.tokens_generated = 0     # tokens emitted (prefill + decode)
        self.prefill_tokens_executed = 0  # real positions run in prefill
        self.prefix_hit_tokens_total = 0  # positions served from hits
        self.busy_s = 0.0             # wall time spent inside step()
        self.peak_active = 0          # max concurrently active rows seen
        self.budget_exhausted = False  # last run() hit max_steps with work

    @property
    def cache(self) -> Any:
        """The device cache tree (owned by the cache backend)."""
        return self.cache_backend.tree

    @cache.setter
    def cache(self, tree: Any) -> None:
        self.cache_backend.tree = tree

    # ------------------------------------------------------------------
    def _emit_chunk(self, rid: int, tokens, now: float) -> None:
        if self.on_event is not None:
            self.on_event(ChunkEvent(rid, self.container_id,
                                     tuple(tokens), now))

    def _emit_done(self, comp: Completion, now: float) -> None:
        if self.on_event is not None:
            self.on_event(DoneEvent(comp.rid, self.container_id, comp, now))

    def _emit_fail(self, rid: int, kind: str, reason: str,
                   now: float) -> None:
        if self.on_event is not None:
            self.on_event(FailedEvent(rid, self.container_id, kind,
                                      reason, now))

    def submit(self, req: Request) -> None:
        if req.max_new_tokens <= 0:
            # zero-budget requests complete empty without touching the
            # device: seeding a slot would emit the prefill sample, one
            # token the request never asked for. Handled at submission so
            # the admission fast path never rescans the queue for them.
            comp = Completion(req.rid, [], len(req.prompt))
            self.done.append(comp)
            self._emit_done(comp, time.perf_counter())
            return
        if req.deadline_s is not None:
            self._deadline_abs[req.rid] = (time.perf_counter()
                                           + req.deadline_s)
        self.queue.append(req)

    def submit_many(self, reqs) -> None:
        for r in reqs:
            self.submit(r)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(s.active for s in self.slots)

    @property
    def _pad_ok(self) -> bool:
        """Right-padding a prompt is harmless only for non-recurrent,
        non-windowed caches (pad K/V slots stay masked until overwritten;
        SSM states and ring windows would absorb the garbage)."""
        cfg = self.model.cfg
        return not (cfg.is_ssm or cfg.sliding_window > 0)

    def _prefill_fn(self, n_seqs: int, bl: int):
        key = ("prefill", n_seqs, bl, self.max_len)
        if key not in self._jits:
            m, ml = self.model, self.max_len

            def fn(params, batch, logits_idx):
                cache = m.init_cache(n_seqs, ml)
                return m.prefill(params, batch, cache, logits_at=logits_idx)
            self._jits[key] = jax.jit(fn)
        return self._jits[key]

    def _suffix_prefill_fn(self, n_seqs: int, bl: int, offset: int):
        """Residual-suffix prefill executable: ``offset`` is static (it
        fixes the rope positions and the context width), ``bl`` is the
        PROMPT_BUCKETS-padded suffix width — suffix shapes reuse the same
        bucket table as full prefill, so compiled-shape count stays
        bounded."""
        key = ("prefill_sfx", n_seqs, bl, offset, self.max_len)
        if key not in self._jits:
            m = self.model

            def fn(params, batch, ctx, logits_idx):
                cache = m.init_cache(n_seqs, bl)
                return m.prefill_suffix(params, batch, cache, ctx, offset,
                                        logits_at=logits_idx)
            self._jits[key] = jax.jit(fn)
        return self._jits[key]

    def _chunk_fn(self, n_tokens: int):
        """Fused decode executable for a chunk of ``n_tokens`` steps; the
        engine cache is donated (arg 1), so the KV rings update in place."""
        key = ("chunk", n_tokens, self.max_len, self.greedy,
               "paged" if self.paged else "dense")
        if key not in self._jits:
            m, ml, greedy = self.model, self.max_len, self.greedy

            def fn(params, cache, state):
                return m.decode_chunk(params, cache, state, n_tokens,
                                      max_len=ml, greedy=greedy)
            self._jits[key] = jax.jit(fn, donate_argnums=(1,))
        return self._jits[key]

    def _insert_rows(self, src_cache: Any, slot_ids: list[int]) -> None:
        """Scatter prefill cache rows into their slots via the cache
        backend (dense: moveaxis row scatter; paged: block-table scatter).
        The engine cache is donated into the jitted scatter either way,
        so admission updates the cache in place too."""
        self.cache_backend.insert(src_cache, slot_ids)

    # ------------------------------------------------------------------
    def _admit_key(self, req: Request):
        """Requests sharing a key can prefill as one padded batch."""
        plen = len(req.prompt)
        bl = _bucket(plen) if self._pad_ok else plen
        return (bl, tuple(sorted(req.extras)))

    def _take_bucket(self, n_free: int) -> list[Request]:
        """Pop the head request plus every queued request in its bucket
        (preserving queue order of the rest), up to ``n_free``."""
        key = self._admit_key(self.queue[0])
        take: list[Request] = []
        rest: deque[Request] = deque()
        while self.queue and len(take) < n_free:
            r = self.queue.popleft()
            (take if self._admit_key(r) == key else rest).append(r)
        rest.extend(self.queue)
        self.queue = rest
        return take

    def _admit(self) -> None:
        if self.paged:
            self._admit_paged()
            return
        free = [i for i, s in enumerate(self.slots) if not s.active]
        while free and self.queue:
            reqs = (self._take_bucket(len(free)) if self.batch_admit
                    else [self.queue.popleft()])
            slot_ids = [free.pop(0) for _ in reqs]
            self._admit_batch(slot_ids, reqs)

    def _cache_tokens(self, req: Request) -> int:
        """Cache positions a request can ever touch: vision prefix +
        prompt + decoded tokens, clamped to the horizon (decode stops at
        max_len - 1 regardless of budget)."""
        nv = self.model.cfg.n_vision_tokens or 0
        return min(nv + len(req.prompt) + req.max_new_tokens, self.max_len)

    def _block_hashes(self, req: Request) -> list[bytes]:
        """Content hash per FULL prompt block: a chained blake2b over
        (vision-token count, extras, then each block's token ids), so a
        block hash commits to everything at and before it — equal hashes
        imply bit-identical cached K/V (prefill K/V is batch- and
        padding-invariant; the parity tests pin this)."""
        bs = self.config.block_size
        nv = self.model.cfg.n_vision_tokens or 0
        W = nv + len(req.prompt)
        seed = hashlib.blake2b(digest_size=16)
        seed.update(np.int64(nv).tobytes())
        for k in sorted(req.extras):
            seed.update(k.encode())
            seed.update(np.ascontiguousarray(
                np.asarray(req.extras[k])).tobytes())
        prev = seed.digest()
        prompt = np.ascontiguousarray(np.asarray(req.prompt), np.int32)
        out: list[bytes] = []
        for i in range(W // bs):
            hh = hashlib.blake2b(prev, digest_size=16)
            hh.update(prompt[max(i * bs - nv, 0):
                             max((i + 1) * bs - nv, 0)].tobytes())
            prev = hh.digest()
            out.append(prev)
        return out

    def _peek_plan(self, req: Request):
        """Sharing plan for one request: ``(H, hit_hashes, full_hashes)``
        where ``H`` is the prefix-hit token count. Capped one block below
        the prompt end (at least one residual token must run so the
        prefill sample exists) and zeroed when the hit would not cover
        the vision prefix (the suffix embed path is text-only)."""
        bs = self.config.block_size
        nv = self.model.cfg.n_vision_tokens or 0
        W = nv + len(req.prompt)
        full = self._block_hashes(req)
        hits = self.cache_backend.peek_hit_blocks(full)
        H = min(len(hits), (W - 1) // bs) * bs
        if H < nv:
            H = 0
        return H, full[:H // bs], full

    def _key_for(self, req: Request, plan):
        """Paged admit key: requests batch into one prefill dispatch only
        when their padded width matches — for prefix hits that is the
        SUFFIX bucket, and the hit length H is folded in so every row of
        a suffix batch shares one context width and rope offset (logits
        are batch-size-sensitive at the last ulp, so hit and miss
        requests must not share a dispatch)."""
        if plan is None or plan[0] == 0:
            return self._admit_key(req)
        nv = self.model.cfg.n_vision_tokens or 0
        n_sfx = nv + len(req.prompt) - plan[0]
        return (_bucket(n_sfx), tuple(sorted(req.extras)), plan[0])

    def _admit_paged(self) -> None:
        """Block-budget admission, strict FIFO and bucket-barrier-free:
        pop the queue head while a free row AND enough free blocks exist,
        batching the maximal run of consecutive heads that share an admit
        key (one padded prefill dispatch per run — padding here is
        COMPUTE-only; cache memory is reserved at the request's real
        token count, so ragged prompts pay no cache padding). A head that
        does not fit stops admission — no scanning past it for smaller
        requests, so nothing starves.

        A failed reservation only ends the round once no deferred free is
        left to reclaim: rows released DURING the round (an instant
        finish inside ``_admit_batch``, a racing cancel) park blocks in
        the backend's pending list, and refusing while those are
        reclaimable would stall admission a whole macro-step on a pool
        that actually has room (the ``can_admit`` deferred-free bug)."""
        cb = self.cache_backend
        cb.flush()   # scrub freed rows' tables, reclaim their blocks
        free = [i for i, s in enumerate(self.slots) if not s.active]
        while free and self.queue:
            head_plan = self._peek_plan(self.queue[0]) if self._share \
                else None
            key = self._key_for(self.queue[0], head_plan)
            take: list[Request] = []
            slot_ids: list[int] = []
            plans: list = []
            blocked: bool | str = False
            limit = len(free) if self.batch_admit else 1
            while self.queue and free and len(take) < limit:
                req = self.queue[0]
                plan = self._peek_plan(req) if self._share else None
                if self._key_for(req, plan) != key:
                    break
                if self.fault is not None and self.fault.refuse_alloc():
                    blocked = "fault"    # injected pool exhaustion
                    break
                hashes = plan[1] if plan is not None else ()
                if not cb.alloc(free[0], self._cache_tokens(req),
                                block_hashes=hashes):
                    blocked = True
                    break
                slot_ids.append(free.pop(0))
                take.append(self.queue.popleft())
                plans.append(plan)
            if take:
                self._admit_batch(slot_ids, take, plans)
            if blocked == "fault":
                return
            if blocked and not cb._pending:
                # genuinely exhausted: FIFO holds the head until a real
                # completion frees blocks
                return
            if not take and not blocked:
                return
            # an instant finish inside _admit_batch parks its row in the
            # backend's pending list; flush so the recomputed free list
            # only offers rows whose reservation is actually released
            if cb._pending:
                cb.flush()
            free = [i for i, s in enumerate(self.slots) if not s.active]

    def _admit_batch(self, slot_ids: list[int], reqs: list[Request],
                     plans: list | None = None) -> None:
        n = len(reqs)
        nv = self.model.cfg.n_vision_tokens or 0
        H = plans[0][0] if plans and plans[0] is not None else 0
        if H:
            # residual-suffix prefill: every row shares hit length H (in
            # the admit key), so one gathered context of width exactly H
            # serves the batch. Gather BEFORE insert — insert donates the
            # tree the gather reads.
            bl = _bucket(nv + len(reqs[0].prompt) - H)
            padded = np.zeros((n, bl), np.int32)
            logits_idx = np.zeros((n,), np.int32)
            for j, r in enumerate(reqs):
                sfx = np.asarray(r.prompt)[H - nv:]
                padded[j, :len(sfx)] = sfx
                logits_idx[j] = len(sfx) - 1
            batch = {"tokens": jnp.asarray(padded)}
            ctx = self.cache_backend.gather_prefix(slot_ids, H)
            logits, src_cache = self._suffix_prefill_fn(n, bl, H)(
                self.params, batch, ctx, jnp.asarray(logits_idx))
            self.cache_backend.insert(src_cache, slot_ids, offset=H)
            self.prefill_tokens_executed += sum(
                nv + len(r.prompt) - H for r in reqs)
            self.prefix_hit_tokens_total += n * H
        else:
            bl, _ = self._admit_key(reqs[0])
            padded = np.zeros((n, bl), np.int32)
            logits_idx = np.zeros((n,), np.int32)
            for j, r in enumerate(reqs):
                plen = len(r.prompt)
                padded[j, :plen] = r.prompt   # right-pad into the bucket
                logits_idx[j] = nv + plen - 1
            batch = {"tokens": jnp.asarray(padded)}
            for k in reqs[0].extras:
                batch[k] = jnp.asarray(np.stack([np.asarray(r.extras[k])
                                                 for r in reqs]))
            logits, src_cache = self._prefill_fn(n, bl)(
                self.params, batch, jnp.asarray(logits_idx))
            self._insert_rows(src_cache, slot_ids)
            self.prefill_tokens_executed += sum(
                nv + len(r.prompt) for r in reqs)
        if self._share and plans:
            # index the new rows' full prompt blocks (hit rows extend the
            # chain past their hit; already-indexed hashes are skipped)
            for i, pl in zip(slot_ids, plans):
                self.cache_backend.register_prefix(i, pl[2])
        first = self._pick(logits)
        now = time.perf_counter()
        for j, (i, r) in enumerate(zip(slot_ids, reqs)):
            slot = self.slots[i]
            slot.active = True
            slot.rid = r.rid
            slot.pos = nv + len(r.prompt)     # next write position
            slot.prompt_len = len(r.prompt)
            slot.remaining = r.max_new_tokens - 1
            slot.generated = [int(first[j])]
            slot.started = now
            slot.deadline = self._deadline_abs.pop(r.rid, None)
            slot.hit_tokens = H
            self.tokens_generated += 1
            # the prefill sample is the request's first streamed chunk —
            # its arrival is the time-to-first-chunk the Router windows
            self._emit_chunk(r.rid, (int(first[j]),), now)
        self.peak_active = max(self.peak_active,
                               sum(1 for s in self.slots if s.active))
        for i in slot_ids:
            if self.slots[i].active and self.slots[i].remaining <= 0:
                self._finish(i)

    def _pick(self, logits: jax.Array) -> np.ndarray:
        if self.greedy:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self._key, sub = jax.random.split(self._key)
        return np.asarray(jax.random.categorical(sub, logits))

    def cancel(self, rid: int) -> bool:
        """Remove a request from the engine — queued or mid-decode — and
        free its cache reservation (paged: via the deferred
        ``CacheBackend.free``/``flush`` path, so block conservation is
        exact). Emits NO event: the canceller (Router deadline/retry
        logic, or an explicit backend ``cancel``) owns the request's
        terminal event. Returns whether the request was found."""
        self._deadline_abs.pop(rid, None)
        for r in self.queue:
            if r.rid == rid:
                self.queue = deque(q for q in self.queue if q.rid != rid)
                return True
        for i, s in enumerate(self.slots):
            if s.active and s.rid == rid:
                self.cache_backend.free(i)
                self.slots[i] = _Slot()
                return True
        return False

    def _expire_deadlines(self) -> None:
        """Cancel every queued/active request whose deadline passed,
        emitting a typed FailedEvent per expiry. Runs at the top of each
        step, so expiry frees slots and paged blocks before admission
        (the freed blocks are reclaimed by the admission flush)."""
        now = time.perf_counter()
        if self._deadline_abs:
            expired = {rid for rid, t in self._deadline_abs.items()
                       if now > t}
            if expired:
                self.queue = deque(r for r in self.queue
                                   if r.rid not in expired)
                for rid in expired:
                    del self._deadline_abs[rid]
                    self._emit_fail(rid, "deadline",
                                    "deadline expired while queued", now)
        for i, s in enumerate(self.slots):
            if s.active and s.deadline is not None and now > s.deadline:
                self._emit_fail(s.rid, "deadline",
                                f"deadline expired mid-decode after "
                                f"{len(s.generated)} tokens", now)
                self.cache_backend.free(i)
                self.slots[i] = _Slot()

    def _finish(self, i: int) -> None:
        s = self.slots[i]
        # prompt_len recorded at admission: s.pos here is prompt length
        # PLUS generated tokens (plus n_vision_tokens), not the prompt
        now = time.perf_counter()
        comp = Completion(s.rid, s.generated, s.prompt_len, now - s.started,
                          prefix_hit_tokens=s.hit_tokens)
        self.done.append(comp)
        self._emit_done(comp, now)
        # release the row's cache reservation (paged: deferred until the
        # next admission flush so the device table is scrubbed first)
        self.cache_backend.free(i)
        self.slots[i] = _Slot()

    # ------------------------------------------------------------------
    def _decode_chunk(self, active: list[int]) -> None:
        """One fused macro-step: decode up to ``chunk_tokens`` tokens for
        every active slot in a single dispatch, then materialise the token
        block with a single host transfer."""
        exact = max(1, min(
            self.chunk_tokens,
            min(self.slots[i].remaining for i in active),
            min(self.max_len - 1 - self.slots[i].pos for i in active)))
        # round down to a power of two: still never a scan iteration past
        # the shortest remaining budget, but the shared jit cache compiles
        # at most log2(max_chunk) scan lengths instead of one per distinct
        # clamp value (ragged budgets would otherwise trigger a compile
        # spike mid-serving on each new length)
        n_tokens = 1 << (exact.bit_length() - 1)
        n_rows = len(self.slots)
        tok = np.zeros((n_rows,), np.int32)
        pos = np.zeros((n_rows,), np.int32)
        rem = np.zeros((n_rows,), np.int32)
        act = np.zeros((n_rows,), bool)
        for i in active:
            s = self.slots[i]
            tok[i], pos[i], rem[i], act[i] = (s.generated[-1], s.pos,
                                              s.remaining, True)
        state = {"tokens": jnp.asarray(tok), "pos": jnp.asarray(pos),
                 "remaining": jnp.asarray(rem), "active": jnp.asarray(act),
                 "key": self._key}
        block, emitted, state, self.cache = self._chunk_fn(n_tokens)(
            self.params, self.cache, state)
        self._key = state["key"]
        block, emitted = jax.device_get((block, emitted))
        now = time.perf_counter()
        for i in active:
            s = self.slots[i]
            c = int(emitted[i])
            new = block[i, :c].tolist()
            s.generated.extend(new)
            s.pos += c
            s.remaining -= c
            self.tokens_generated += c
            if new:
                # one ChunkEvent per request per macro-step, built from
                # the block that the single host transfer above already
                # materialised — streaming costs no extra syncs
                self._emit_chunk(s.rid, new, now)
            if s.remaining <= 0 or s.pos >= self.max_len - 1:
                self._finish(i)
        self.chunks += 1

    def _decode_token(self, active: list[int]) -> None:
        """Per-token baseline path: one dispatch + one host sync per
        generated token, undonated cache (full copy per step) — kept so
        the fused path's win stays measurable (benchmarks)."""
        n_rows = len(self.slots)
        tokens = np.zeros((n_rows, 1), np.int32)
        pos = np.zeros((n_rows,), np.int32)
        for i in active:
            s = self.slots[i]
            tokens[i, 0] = s.generated[-1]
            pos[i] = s.pos
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache, jnp.asarray(pos))
        nxt = self._pick(logits)
        now = time.perf_counter()
        for i in active:
            s = self.slots[i]
            s.generated.append(int(nxt[i]))
            s.pos += 1
            s.remaining -= 1
            self.tokens_generated += 1
            self._emit_chunk(s.rid, (int(nxt[i]),), now)
            if s.remaining <= 0 or s.pos >= self.max_len - 1:
                self._finish(i)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One engine macro-iteration: admit new requests, then one decode
        chunk (or one decode step in per-token mode). Returns whether the
        engine still has work (so pools can drive many engines round-robin
        without blocking on any one of them). Every call that found work —
        including admit-only ones — counts against ``run``'s budget."""
        if not self.has_work:
            return False
        self.steps += 1
        if self.fault is not None:
            self.fault.on_step(self.steps)   # may raise InjectedFault
        t0 = time.perf_counter()
        self._expire_deadlines()
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s.active]
        if active:
            if self.chunked:
                self._decode_chunk(active)
            else:
                self._decode_token(active)
        self.busy_s += time.perf_counter() - t0
        return self.has_work

    def run(self, max_steps: int = 10_000) -> list[Completion]:
        """Drive until idle (or ``max_steps`` ``step()`` calls *for this
        call* — every call counts, so admit-only iterations cannot spin
        past the budget) and drain the finished completions — engines are
        reused across serves by the pool, so neither the step budget nor
        the done list may accumulate across calls.

        Exhausting the budget with work still queued is flagged loudly
        (``budget_exhausted`` plus a RuntimeWarning) instead of silently
        returning a partial wave — callers that batch-serve would
        otherwise drop the stragglers without any signal."""
        start = self.steps
        while self.has_work and self.steps - start < max_steps:
            self.step()
        self.budget_exhausted = self.has_work
        if self.budget_exhausted:
            n_active = sum(1 for s in self.slots if s.active)
            warnings.warn(
                f"ServingEngine.run() exhausted max_steps={max_steps} with "
                f"{len(self.queue)} queued and {n_active} active requests "
                "remaining; returning partial completions "
                "(engine.budget_exhausted is set)", RuntimeWarning,
                stacklevel=2)
        out, self.done = self.done, []
        return out
