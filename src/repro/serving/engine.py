"""Continuous-batching serving engine.

Slot-based: the engine owns a KV cache with ``n_slots`` sequences. Requests
are prefilled one-at-a-time into a free slot (prompt lengths padded to
power-of-two buckets to bound recompiles), then all active slots decode in
lockstep HLO with per-slot positions (the cache/ring masks make ragged
depths correct — see models/attention.py). Finished slots are refilled from
the queue mid-decode: continuous batching.

This is the per-container serving loop; core/splitter.py +
serving/pool.py run n of these over disjoint resource shares — the paper's
method end-to-end.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    extras: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list
    prompt_len: int
    latency_s: float = 0.0


def _bucket(n: int, buckets=(16, 32, 64, 128, 256, 512, 1024, 2048)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return n


@dataclasses.dataclass
class _Slot:
    active: bool = False
    rid: int = -1
    pos: int = 0                  # next position to write
    remaining: int = 0
    generated: list = dataclasses.field(default_factory=list)
    started: float = 0.0


class ServingEngine:
    def __init__(self, model: Model, params: Any, n_slots: int = 4,
                 max_len: int = 512, dtype=jnp.float32,
                 greedy: bool = True, seed: int = 0):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = model.init_cache(n_slots, max_len, dtype)
        self.slots = [_Slot() for _ in range(n_slots)]
        self.queue: deque[Request] = deque()
        self.done: list[Completion] = []
        self.greedy = greedy
        self._key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(model.decode_step)
        self._prefill_cache = {}
        self.steps = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    @property
    def _pad_ok(self) -> bool:
        """Right-padding a prompt is harmless only for non-recurrent,
        non-windowed caches (pad K/V slots stay masked until overwritten;
        SSM states and ring windows would absorb the garbage)."""
        cfg = self.model.cfg
        return not (cfg.is_ssm or cfg.sliding_window > 0)

    def _prefill_fn(self, plen: int, bl: int):
        key = (plen, bl)
        if key not in self._prefill_cache:
            m = self.model
            nv = m.cfg.n_vision_tokens or 0

            def fn(params, batch):
                cache = m.init_cache(1, self.max_len)
                return m.prefill(params, batch, cache,
                                 logits_at=nv + plen - 1)
            self._prefill_cache[key] = jax.jit(fn)
        return self._prefill_cache[key]

    def _insert_cache(self, src_cache: Any, slot: int) -> None:
        def ins(e, s):
            ax = next((i for i, (a, b) in enumerate(zip(e.shape, s.shape))
                       if a != b), None)
            if ax is None:
                return s if e.shape == s.shape and e.ndim == 0 else e
            return jax.lax.dynamic_update_slice_in_dim(
                e, s.astype(e.dtype), slot, axis=ax)
        self.cache = jax.tree.map(ins, self.cache, src_cache)

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            req = self.queue.popleft()
            plen = len(req.prompt)
            bl = _bucket(plen) if self._pad_ok else plen
            padded = np.zeros((1, bl), np.int32)
            padded[0, :plen] = req.prompt      # right-pad into the bucket
            batch = {"tokens": jnp.asarray(padded)}
            for k, v in req.extras.items():
                batch[k] = jnp.asarray(v)[None]
            logits, src_cache = self._prefill_fn(plen, bl)(self.params, batch)
            self._insert_cache(src_cache, i)
            first = self._pick(logits)[0]
            nv = self.model.cfg.n_vision_tokens or 0
            slot.active = True
            slot.rid = req.rid
            slot.pos = nv + plen               # next write position
            slot.remaining = req.max_new_tokens - 1
            slot.generated = [int(first)]
            slot.started = time.time()
            if slot.remaining <= 0:
                self._finish(i)

    def _pick(self, logits: jax.Array) -> np.ndarray:
        if self.greedy:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self._key, sub = jax.random.split(self._key)
        return np.asarray(jax.random.categorical(sub, logits))

    def _finish(self, i: int) -> None:
        s = self.slots[i]
        self.done.append(Completion(s.rid, s.generated, s.pos,
                                    time.time() - s.started))
        self.slots[i] = _Slot()

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine iteration: admit new requests, one decode step."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s.active]
        if not active:
            return
        tokens = np.zeros((self.n_slots, 1), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        for i, s in enumerate(self.slots):
            if s.active:
                tokens[i, 0] = s.generated[-1]
                pos[i] = s.pos
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache, jnp.asarray(pos))
        nxt = self._pick(logits)
        for i in active:
            s = self.slots[i]
            s.generated.append(int(nxt[i]))
            s.pos += 1
            s.remaining -= 1
            if s.remaining <= 0 or s.pos >= self.max_len - 1:
                self._finish(i)
        self.steps += 1

    def run(self, max_steps: int = 10_000) -> list[Completion]:
        while (self.queue or any(s.active for s in self.slots)) \
                and self.steps < max_steps:
            self.step()
        return self.done
