"""Process-per-container serving pool — real OS-level CPU shares.

The paper's mechanism is literally ``docker run --cpus=C/n``: each
container is an OS-level share of the device, not a thread in a shared
runtime. ``ContainerServingPool`` overlaps engines with threads (useful as
the shared-device baseline, and required for sub-mesh placement where one
process owns the whole pod); ``ProcessContainerPool`` runs the paper's
actual isolation: one **OS process per container**, pinned to a disjoint
core set via ``os.sched_setaffinity`` *before* jax initialises, so XLA's
threadpool is sized by — and confined to — the container's cpuset
(``core/testbed.assign_core_sets`` + ``spawn_pinned``, the same harness
the video-detection testbed uses, here hosting a full ``ServingEngine``
over any registered model config).

Parent/child protocol, over one pipe per container:

  * the parent serializes the wave's request segments (numpy prompts
    pickle across the spawn boundary); children reply with completions
    plus wall/busy/token counts, so the existing ``ContainerResult`` /
    ``EnergyProxy`` / percentile accounting (``pool.assemble_wave``) works
    unchanged;
  * children build params from a **seeded config** (``model.init`` on the
    pickled ArchConfig — bit-identical to the parent's on the same host),
    or load them from an ``.npz`` handoff (``save_params`` below) when the
    parent holds params that no seed reproduces (finetuned / large);
  * children stay **warm**: engines, their compiled executables, and the
    params survive across waves, so a pool cached per count (see
    ``AdaptiveServingPool(isolation="process")``) pays spawn + compile
    once, at first use — after that a converged scheduler's waves cost
    the same as thread-pool waves.

Spawn cost is real (fresh interpreter + jax import + first-wave compile,
seconds per child): prefer this pool for sustained serving under CPU
contention, the thread pool for one-shot waves or when sub-mesh device
placement is the isolation that matters (see README "Process
containers").
"""
from __future__ import annotations

import multiprocessing as mp
import os
import time
from typing import Any, Sequence

import numpy as np

from repro.core import splitter
from repro.core.testbed import assign_core_sets, spawn_pinned
from repro.serving.engine import Completion, Request
from repro.serving.pool import ContainerResult, EnergyProxy, assemble_wave

_READY_POLL_S = 0.25


def save_params(params: Any, path: str) -> str:
    """Write a params tree to ``path`` (.npz, leaves in tree order) for the
    cross-process handoff: children rebuild the tree structure from
    ``jax.eval_shape(model.init, ...)`` and unflatten these leaves — exact
    float bytes, so parity with the parent's params is preserved."""
    import jax
    leaves = jax.tree_util.tree_leaves(params)
    np.savez(path, **{f"leaf{i}": np.asarray(leaf)
                      for i, leaf in enumerate(leaves)})
    return path


def _load_params(model, path: str):
    import jax
    struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    treedef = jax.tree_util.tree_structure(struct)
    with np.load(path) as z:
        leaves = [z[f"leaf{i}"] for i in range(len(z.files))]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _serving_child(conn, cfg, params_seed: int, params_path: str | None,
                   n_slots: int, max_len: int, greedy: bool, seed: int,
                   chunked: bool, chunk_tokens: int | None) -> None:
    """Container body (module-level: spawn pickles it by reference).
    Affinity was already applied by ``spawn_pinned``; the jax import below
    therefore sizes XLA's threadpool from the container's cpuset."""
    import traceback
    try:
        import jax

        from repro.models.model import Model
        from repro.serving.engine import ServingEngine

        model = Model(cfg)
        params = (_load_params(model, params_path) if params_path
                  else model.init(jax.random.PRNGKey(params_seed)))
        engine = ServingEngine(model, params, n_slots=n_slots,
                               max_len=max_len, greedy=greedy, seed=seed,
                               chunked=chunked, chunk_tokens=chunk_tokens)
        try:
            cores = sorted(os.sched_getaffinity(0))
        except AttributeError:              # non-Linux dev host
            cores = []
        conn.send(("ready", cores))
    except BaseException:
        conn.send(("error", traceback.format_exc()))
        return
    while True:
        try:
            msg = conn.recv()
        except EOFError:                    # parent died / closed: exit
            return
        if msg[0] == "close":
            conn.close()
            return
        try:                                # ("serve", [Request, ...])
            t0 = time.perf_counter()
            busy0, toks0 = engine.busy_s, engine.tokens_generated
            engine.submit_many(msg[1])
            comps = engine.run()
            conn.send(("done", comps, time.perf_counter() - t0,
                       engine.busy_s - busy0,
                       engine.tokens_generated - toks0))
        except BaseException:
            conn.send(("error", traceback.format_exc()))


class ProcessContainerPool:
    """API-compatible with ``ContainerServingPool.serve_timed()`` but with
    one pinned OS process per container (the paper's ``--cpus`` shares).

    Children rebuild params as ``model.init(PRNGKey(params_seed))`` from
    the pickled ``cfg`` — pass ``params_path`` (written by ``save_params``)
    instead when the serving params are not seed-reproducible. Workers
    spawn lazily on first serve and stay warm until ``close()``.
    """

    def __init__(self, cfg, n_containers: int,
                 n_slots_per_container: int = 4, max_len: int = 512,
                 total_cores: int | None = None,
                 params_seed: int = 0, params_path: str | None = None,
                 energy: EnergyProxy | None = None,
                 greedy: bool = True, seed: int = 0,
                 chunked: bool = True, chunk_tokens: int | None = None,
                 allow_shared_cores: bool = False,
                 start_timeout_s: float = 600.0):
        self.cfg = cfg
        self.n_containers = n_containers
        self.n_slots = n_slots_per_container
        self.max_len = max_len
        self.energy = energy or EnergyProxy()
        self.params_seed = params_seed
        self.params_path = params_path
        self.greedy = greedy
        self.seed = seed
        self.chunked = chunked
        self.chunk_tokens = chunk_tokens
        self.start_timeout_s = start_timeout_s
        # fail fast, before any spawn: more containers than cores cannot be
        # disjoint (see core/testbed.assign_core_sets)
        self.core_sets = assign_core_sets(n_containers,
                                          total_cores=total_cores,
                                          allow_shared=allow_shared_cores)
        self.reported_core_sets: list[frozenset[int]] | None = None
        self._workers: list[tuple[Any, Any]] | None = None

    # ------------------------------------------------------------------
    def _ensure_workers(self) -> None:
        """Spawn + handshake all children once; engines stay warm across
        waves (the pool cache in AdaptiveServingPool relies on this)."""
        if self._workers is not None:
            return
        ctx = mp.get_context("spawn")
        workers = []
        for cores in self.core_sets:
            proc, conn = spawn_pinned(
                _serving_child, cores,
                args=(self.cfg, self.params_seed, self.params_path,
                      self.n_slots, self.max_len, self.greedy, self.seed,
                      self.chunked, self.chunk_tokens), ctx=ctx)
            workers.append((proc, conn))
        reported = []
        try:
            for cid, (proc, conn) in enumerate(workers):
                msg = self._recv(proc, conn, self.start_timeout_s)
                if msg[0] != "ready":
                    raise RuntimeError(
                        f"container {cid} failed to start:\n{msg[1]}")
                reported.append(frozenset(msg[1]))
        except BaseException:
            for proc, _ in workers:
                proc.terminate()
            raise
        self._workers = workers
        self.reported_core_sets = reported

    @staticmethod
    def _recv(proc, conn, timeout_s: float | None):
        """recv that notices a dead child instead of blocking forever."""
        deadline = (None if timeout_s is None
                    else time.perf_counter() + timeout_s)
        while not conn.poll(_READY_POLL_S):
            if not proc.is_alive():
                raise RuntimeError(
                    f"container process died (exit {proc.exitcode}) "
                    "before replying")
            if deadline is not None and time.perf_counter() > deadline:
                raise TimeoutError("container start/serve timed out")
        return conn.recv()

    # ------------------------------------------------------------------
    def serve_timed(self, requests: list[Request],
                    concurrent: bool | None = None
                    ) -> tuple[list[Completion], list[ContainerResult],
                               float, float]:
        """Serve a wave; same contract as ContainerServingPool.serve_timed.
        ``concurrent`` is accepted for API compatibility and ignored —
        processes always overlap (that is the point of this pool)."""
        del concurrent
        self._ensure_workers()
        assert self._workers is not None
        segments = splitter.split(requests, self.n_containers)
        t0 = time.perf_counter()
        for (proc, conn), seg in zip(self._workers, segments):
            conn.send(("serve", seg))
        out: list = [None] * self.n_containers
        try:
            for cid, (proc, conn) in enumerate(self._workers):
                msg = self._recv(proc, conn, None)
                if msg[0] == "error":
                    raise RuntimeError(
                        f"container {cid} failed mid-serve:\n{msg[1]}")
                out[cid] = tuple(msg[1:])   # (comps, wall, busy, tokens)
        except BaseException:
            # a failed wave leaves sibling replies queued in their pipes;
            # a "warm" pool in that state would pair wave K's completions
            # with wave K+1's segments forever — tear the workers down so
            # the next serve starts from a clean spawn
            self.close()
            raise
        wall = time.perf_counter() - t0
        ordered, results, energy = assemble_wave(out, segments, wall,
                                                 self.energy)
        return ordered, results, wall, energy

    def serve(self, requests: list[Request],
              concurrent: bool | None = None
              ) -> tuple[list[Completion], list[ContainerResult]]:
        ordered, results, _, _ = self.serve_timed(requests, concurrent)
        return ordered, results

    # ------------------------------------------------------------------
    def close(self) -> None:
        """Shut the warm children down (idempotent). Cached pools evicted
        by AdaptiveServingPool call this so child processes never leak."""
        if self._workers is None:
            return
        workers, self._workers = self._workers, None
        for _, conn in workers:
            try:
                conn.send(("close",))
            except (BrokenPipeError, OSError):
                pass
        for proc, conn in workers:
            proc.join(timeout=10)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=5)
            conn.close()

    def __enter__(self) -> "ProcessContainerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
