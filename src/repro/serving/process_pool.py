"""Process-per-container serving pool — real OS-level CPU shares.

The paper's mechanism is literally ``docker run --cpus=C/n``: each
container is an OS-level share of the device, not a thread in a shared
runtime. Since the backend redesign this module is a thin **wave shim**:
the execution machinery (pinned children, streaming pipe protocol,
params handoff) lives in ``serving/backend.ProcessBackend``;
``ProcessContainerPool`` keeps the PR 4 wave API — ``serve_timed`` =
submit-all + drain, with ``ContainerResult`` / ``EnergyProxy`` /
percentile accounting reconstructed by ``pool.assemble_wave`` — so the
PR 4 parity suites and benchmarks run unmodified. For request-level
streaming over the same children, put a ``serving/router.Router`` in
front of a ``ProcessBackend`` instead.

Params reach the children three ways (see backend.py): seeded re-init
from the pickled config (bit-identical on the same host), an ``.npz``
handoff (``save_params``) for non-reproducible params, or — cheapest —
a ``multiprocessing.shared_memory`` mapping (``share_params``) that
skips the filesystem copy entirely; children view the parent's bytes in
place and copy them straight onto their device.

Spawn cost is real (fresh interpreter + jax import + first-wave compile,
seconds per child): prefer this pool for sustained serving under CPU
contention, the thread pool for one-shot waves or when sub-mesh device
placement is the isolation that matters (see README "Process
containers").
"""
from __future__ import annotations

import time

from repro.core import splitter
from repro.serving.backend import (ParamsShare, ProcessBackend, SharedParams,
                                   save_params, share_params)
from repro.serving.engine import Completion, Request
from repro.serving.pool import (ContainerResult, EnergyProxy, _warn_wave_shim,
                                assemble_wave)

__all__ = ["ProcessContainerPool", "save_params", "share_params",
           "ParamsShare", "SharedParams"]


class ProcessContainerPool:
    """API-compatible with ``ContainerServingPool.serve_timed()`` but with
    one pinned OS process per container (the paper's ``--cpus`` shares).

    Children rebuild params as ``model.init(PRNGKey(params_seed))`` from
    the pickled ``cfg`` — pass ``params_path`` (written by ``save_params``)
    or ``params_shm`` (a ``share_params`` handle; the caller owns the
    share's lifetime) when the serving params are not seed-reproducible.
    Workers spawn lazily on first serve and stay warm until ``close()``.
    """

    def __init__(self, cfg, n_containers: int,
                 n_slots_per_container: int = 4, max_len: int = 512,
                 total_cores: int | None = None,
                 params_seed: int = 0, params_path: str | None = None,
                 params_shm: SharedParams | None = None,
                 energy: EnergyProxy | None = None,
                 greedy: bool = True, seed: int = 0,
                 chunked: bool = True, chunk_tokens: int | None = None,
                 allow_shared_cores: bool = False,
                 start_timeout_s: float = 600.0,
                 backend: ProcessBackend | None = None):
        self.cfg = cfg
        self.n_containers = n_containers
        self.energy = energy or EnergyProxy()
        if backend is None:
            backend = ProcessBackend(
                cfg, n_containers,
                n_slots_per_container=n_slots_per_container,
                max_len=max_len, total_cores=total_cores,
                params_seed=params_seed, params_path=params_path,
                params_shm=params_shm, greedy=greedy, seed=seed,
                chunked=chunked, chunk_tokens=chunk_tokens,
                allow_shared_cores=allow_shared_cores,
                start_timeout_s=start_timeout_s)
        elif backend.capacity != n_containers:
            raise ValueError(f"backend capacity {backend.capacity} != "
                             f"{n_containers} containers")
        self.backend = backend

    # -- compat views onto the backend ---------------------------------
    @property
    def core_sets(self):
        return self.backend.core_sets

    @property
    def reported_core_sets(self):
        return self.backend.reported_core_sets

    @property
    def _workers(self):
        return self.backend.workers

    # ------------------------------------------------------------------
    def serve_timed(self, requests: list[Request],
                    concurrent: bool | None = None
                    ) -> tuple[list[Completion], list[ContainerResult],
                               float, float]:
        """Serve a wave; same contract as ContainerServingPool.serve_timed.
        ``concurrent`` is accepted for API compatibility and ignored —
        processes always overlap (that is the point of this pool)."""
        _warn_wave_shim("ProcessContainerPool.serve_timed")
        del concurrent
        self.backend.warm()     # spawn cost stays outside the wave wall
        segments = splitter.split(requests, self.n_containers)
        t0 = time.perf_counter()
        for cid, seg in enumerate(segments):
            self.backend.submit_many(cid, seg)
        out = self.backend.drain()
        wall = time.perf_counter() - t0
        ordered, results, energy = assemble_wave(out, segments, wall,
                                                 self.energy)
        return ordered, results, wall, energy

    def serve(self, requests: list[Request],
              concurrent: bool | None = None
              ) -> tuple[list[Completion], list[ContainerResult]]:
        ordered, results, _, _ = self.serve_timed(requests, concurrent)
        return ordered, results

    # ------------------------------------------------------------------
    def close(self) -> None:
        """Shut the warm children down (idempotent). Cached pools evicted
        by AdaptiveServingPool call this so child processes never leak."""
        self.backend.close()

    def __enter__(self) -> "ProcessContainerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
