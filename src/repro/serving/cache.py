"""Cache backends for the serving engine: dense slot rows or paged blocks.

``CacheBackend`` is the protocol the engine programs against — block
accounting (``alloc``/``append``/``free``), prefill row insertion
(``insert``) and the device tree itself (``view``). Two implementations:

* ``DenseCache`` — the original ``(n_slots, max_len)`` row layout, kept as
  the bit-parity baseline (same pattern as ``chunked=False``). alloc/free
  are no-ops: a row IS the reservation.
* ``PagedCache`` — block/paged layout (models/cache.py): a shared pool of
  ``max_blocks`` physical pages plus a per-row block table. Admission
  reserves ``ceil(tokens / block_size)`` blocks per request — the real
  token count, not a power-of-two bucket — so in-flight concurrency is
  bounded by the block budget, not by ``n_slots``.

Both backends own the HOST-side accounting only; the device tree flows
through the engine's jits (donated) and is re-attached via the ``tree``
attribute. Paged bookkeeping invariants:

* every table entry outside a row's live reservation points at the
  SCRATCH page (index ``max_blocks``), so lockstep decode writes for
  idle rows land in the sink instead of a live block;
* ``free`` defers: freed rows park in a pending list and their device
  table rows are cleared to scratch (one jitted scatter in ``flush``,
  called at the top of each admission round) BEFORE the blocks return to
  the allocator — otherwise a frozen row could scribble on a block that
  admission just handed to a new sequence.

``BlockAllocator`` is the pure-Python free-list underneath (hypothesis
property tests pin down no-leak / no-alias round-trips).
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cache import PagedLayout, is_paged_group


class BlockAllocator:
    """Free-list over ``n_blocks`` physical page indices. ``alloc`` is
    all-or-nothing (None when short — callers must not partially admit);
    ``free`` rejects double-frees and foreign indices."""

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free: list[int] = list(range(n_blocks))
        self._used: set[int] = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        blocks = [self._free.pop() for _ in range(n)]
        self._used.update(blocks)
        return blocks

    def free(self, blocks) -> None:
        for b in blocks:
            if b not in self._used:
                raise ValueError(f"free of unallocated block {b}")
            self._used.discard(b)
            self._free.append(b)


@runtime_checkable
class CacheBackend(Protocol):
    """What the serving engine needs from a KV-cache implementation."""
    n_rows: int
    tree: Any

    def can_admit(self, n_tokens: int) -> bool:
        """Would ``alloc`` for a ``n_tokens``-position sequence succeed?"""
        ...

    def alloc(self, row: int, n_tokens: int) -> bool:
        """Reserve cache space covering ``n_tokens`` positions for
        ``row``. False (and no side effects) when the budget is short."""
        ...

    def append(self, row: int, n_tokens: int = 1) -> bool:
        """Extend ``row``'s reservation by ``n_tokens`` positions."""
        ...

    def free(self, row: int) -> None:
        """Release ``row``'s reservation (may defer until ``flush``)."""
        ...

    def flush(self) -> None:
        """Make deferred frees effective (device table scrub included)."""
        ...

    def insert(self, src_cache: Any, rows: list[int]) -> None:
        """Scatter a prefill mini-cache (dense layout, one row per admitted
        request) into the engine cache at ``rows``."""
        ...

    def view(self) -> Any:
        """The device cache tree the model consumes."""
        ...


# ---------------------------------------------------------------------------
# dense baseline
# ---------------------------------------------------------------------------
class DenseCache:
    """Row-per-slot baseline: capacity IS ``n_rows``, so block accounting
    degenerates to always-true and ``insert`` is the original moveaxis
    row scatter (donated, in place)."""

    def __init__(self, tree: Any, n_rows: int, batch_axes: Any, jits: dict):
        self.tree = tree
        self.n_rows = n_rows
        self._axes = batch_axes
        self._jits = jits

    def can_admit(self, n_tokens: int) -> bool:
        return True

    def alloc(self, row: int, n_tokens: int) -> bool:
        return True

    def append(self, row: int, n_tokens: int = 1) -> bool:
        return True

    def free(self, row: int) -> None:
        return None

    def flush(self) -> None:
        return None

    def insert(self, src_cache: Any, rows: list[int]) -> None:
        key = ("insert", "dense")
        if key not in self._jits:
            axes = self._axes

            def ins_fn(cache, src, idx):
                def ins(e, s, ax):
                    if ax is None:
                        return e
                    em = jnp.moveaxis(e, ax, 0)
                    sm = jnp.moveaxis(s.astype(e.dtype), ax, 0)
                    return jnp.moveaxis(em.at[idx].set(sm), 0, ax)
                return jax.tree.map(ins, cache, src, axes)
            self._jits[key] = jax.jit(ins_fn, donate_argnums=(0,))
        self.tree = self._jits[key](self.tree, src_cache,
                                    jnp.asarray(rows))

    def view(self) -> Any:
        return self.tree


# ---------------------------------------------------------------------------
# paged backend
# ---------------------------------------------------------------------------
def _tree_has_paged_group(tree: Any) -> bool:
    if isinstance(tree, dict):
        if is_paged_group(tree):
            return True
        return any(_tree_has_paged_group(v) for v in tree.values())
    return False


# (pages key, dense prefill-cache key) pairs a paged group can hold
_PAGE_PAIRS = (("k_pages", "k"), ("v_pages", "v"),
               ("k_scale_pages", "k_scale"), ("v_scale_pages", "v_scale"),
               ("ckv_pages", "ckv"), ("k_rope_pages", "k_rope"))


class PagedCache:
    """Block-table cache backend. Host state: a free-list allocator over
    the shared physical pages (ONE logical block spans every pageable
    layer — per-layer tables are replicas) and per-row block lists."""

    def __init__(self, tree: Any, n_rows: int, layout: PagedLayout,
                 max_len: int, batch_axes: Any, jits: dict):
        self.tree = tree
        self.n_rows = n_rows
        self.layout = layout
        self.max_len = max_len
        self._axes = batch_axes
        self._jits = jits
        self.allocator = BlockAllocator(layout.max_blocks)
        self._blocks: list[list[int]] = [[] for _ in range(n_rows)]
        self._tokens: list[int] = [0] * n_rows
        self._pending: list[int] = []          # rows freed, not yet scrubbed
        self._has_paged = _tree_has_paged_group(tree)

    # -- accounting ----------------------------------------------------
    @property
    def n_live_blocks(self) -> int:
        """Blocks currently reserved by rows (pending-free rows included
        until ``flush`` returns theirs to the allocator). At every point
        ``allocator.n_free + n_live_blocks == max_blocks`` — the exact
        conservation the chaos/cancellation tests assert."""
        return sum(len(b) for b in self._blocks)

    def _cap(self, n_tokens: int) -> int:
        return min(n_tokens, self.max_len)

    def can_admit(self, n_tokens: int) -> bool:
        return (self.allocator.n_free >=
                self.layout.n_blocks(self._cap(n_tokens)))

    def alloc(self, row: int, n_tokens: int) -> bool:
        if self._blocks[row] or row in self._pending:
            raise ValueError(f"row {row} already holds a reservation")
        blocks = self.allocator.alloc(
            self.layout.n_blocks(self._cap(n_tokens)))
        if blocks is None:
            return False
        self._blocks[row] = blocks
        self._tokens[row] = self._cap(n_tokens)
        return True

    def append(self, row: int, n_tokens: int = 1) -> bool:
        new_total = self._tokens[row] + n_tokens
        if new_total > self.max_len:
            return False
        need = (self.layout.n_blocks(new_total)
                - self.layout.n_blocks(self._tokens[row]))
        if need > 0:
            blocks = self.allocator.alloc(need)
            if blocks is None:
                return False
            start = len(self._blocks[row])
            self._blocks[row].extend(blocks)
            if self._has_paged:
                self._write_table(row, start, blocks)
        self._tokens[row] = new_total
        return True

    def free(self, row: int) -> None:
        # idempotent: cancel/expire and completion may race to release
        # the same row (deadline expiry in the Router vs the engine
        # finishing the slot) — freeing an already-pending row twice
        # would double-free its blocks at the next flush
        if not self._blocks[row] or row in self._pending:
            return
        # deferred: the device table row must be scrubbed to scratch
        # before these blocks can be re-issued (see flush)
        self._pending.append(row)

    def flush(self) -> None:
        if not self._pending:
            return
        rows, self._pending = self._pending, []
        if self._has_paged:
            self.tree = self._clear_fn()(self.tree,
                                         jnp.asarray(rows, jnp.int32))
        for row in rows:
            self.allocator.free(self._blocks[row])
            self._blocks[row] = []
            self._tokens[row] = 0

    # -- device-tree transforms ----------------------------------------
    def _table_rows(self, rows: list[int]) -> np.ndarray:
        nblk = self.max_len // self.layout.block_size
        out = np.full((len(rows), nblk), self.layout.scratch_page, np.int32)
        for j, row in enumerate(rows):
            blocks = self._blocks[row]
            out[j, :len(blocks)] = blocks
        return out

    def _clear_fn(self):
        key = ("paged_clear",)
        if key not in self._jits:
            scratch = self.layout.scratch_page

            def walk(t, rows):
                if isinstance(t, dict) and is_paged_group(t):
                    table = t["table"]
                    sdims = table.ndim - 2
                    tf = table.reshape((-1,) + table.shape[sdims:])
                    tf = tf.at[:, rows, :].set(scratch)
                    return {**t, "table": tf.reshape(table.shape)}
                if isinstance(t, dict):
                    return {k: walk(v, rows) for k, v in t.items()}
                return t

            self._jits[key] = jax.jit(lambda tree, rows: walk(tree, rows),
                                      donate_argnums=(0,))
        return self._jits[key]

    def _write_table(self, row: int, start: int, blocks: list[int]) -> None:
        """Point logical block indices [start, start+len) of ``row`` at
        ``blocks`` on device (append path — admission goes via insert)."""
        key = ("paged_append",)
        if key not in self._jits:
            def walk(t, row_, idxs, pages):
                if isinstance(t, dict) and is_paged_group(t):
                    table = t["table"]
                    sdims = table.ndim - 2
                    tf = table.reshape((-1,) + table.shape[sdims:])
                    tf = tf.at[:, row_, idxs].set(pages)
                    return {**t, "table": tf.reshape(table.shape)}
                if isinstance(t, dict):
                    return {k: walk(v, row_, idxs, pages)
                            for k, v in t.items()}
                return t

            self._jits[key] = jax.jit(
                lambda tree, row_, idxs, pages:
                    walk(tree, row_, idxs, pages), donate_argnums=(0,))
        idxs = jnp.arange(start, start + len(blocks), dtype=jnp.int32)
        self.tree = self._jits[key](self.tree, jnp.int32(row), idxs,
                                    jnp.asarray(blocks, jnp.int32))

    def insert(self, src_cache: Any, rows: list[int]) -> None:
        """Scatter the dense prefill mini-cache into the paged tree: every
        position of each source row lands at ``(table[p // bs], p % bs)``
        — positions beyond the row's reservation hit the scratch page, so
        bucket-padded prefill garbage goes to the sink, while live
        positions are copied verbatim (the bit-parity guarantee)."""
        key = ("insert", "paged")
        if key not in self._jits:
            axes = self._axes

            def group_ins(dst, src, rows_, table_rows):
                out = dict(dst)
                table = dst["table"]
                sdims = table.ndim - 2
                tf = table.reshape((-1,) + table.shape[sdims:])
                tf = tf.at[:, rows_, :].set(table_rows[None])
                out["table"] = tf.reshape(table.shape)
                for dk, sk in _PAGE_PAIRS:
                    if dk not in dst:
                        continue
                    pages, s = dst[dk], src[sk]
                    bs = pages.shape[sdims + 1]
                    W = s.shape[sdims + 1]
                    pos = jnp.arange(W)
                    pp = table_rows[:, pos // bs]            # (n, W)
                    off = jnp.broadcast_to(pos % bs, pp.shape)
                    pf = pages.reshape((-1,) + pages.shape[sdims:])
                    sf = s.astype(pages.dtype).reshape(
                        (-1,) + s.shape[sdims:])
                    scat = jax.vmap(
                        lambda pg, sr: pg.at[pp, off].set(sr))(pf, sf)
                    out[dk] = scat.reshape(pages.shape)
                return out

            def walk(dst, src, ax, rows_, table_rows):
                if isinstance(dst, dict) and is_paged_group(dst):
                    return group_ins(dst, src, rows_, table_rows)
                if isinstance(dst, dict):
                    return {k: walk(dst[k], src[k], ax[k], rows_,
                                    table_rows) for k in dst}
                if ax is None:
                    return dst
                em = jnp.moveaxis(dst, ax, 0)
                sm = jnp.moveaxis(src.astype(dst.dtype), ax, 0)
                return jnp.moveaxis(em.at[rows_].set(sm), 0, ax)

            self._jits[key] = jax.jit(
                lambda tree, src, rows_, table_rows:
                    walk(tree, src, axes, rows_, table_rows),
                donate_argnums=(0,))
        self.tree = self._jits[key](self.tree, src_cache,
                                    jnp.asarray(rows, jnp.int32),
                                    jnp.asarray(self._table_rows(rows)))

    def view(self) -> Any:
        return self.tree
