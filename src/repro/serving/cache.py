"""Cache backends for the serving engine: dense slot rows or paged blocks.

``CacheBackend`` is the protocol the engine programs against — block
accounting (``alloc``/``append``/``free``), prefill row insertion
(``insert``) and the device tree itself (``view``). Two implementations:

* ``DenseCache`` — the original ``(n_slots, max_len)`` row layout, kept as
  the bit-parity baseline (same pattern as ``chunked=False``). alloc/free
  are no-ops: a row IS the reservation.
* ``PagedCache`` — block/paged layout (models/cache.py): a shared pool of
  ``max_blocks`` physical pages plus a per-row block table. Admission
  reserves ``ceil(tokens / block_size)`` blocks per request — the real
  token count, not a power-of-two bucket — so in-flight concurrency is
  bounded by the block budget, not by ``n_slots``.

Both backends own the HOST-side accounting only; the device tree flows
through the engine's jits (donated) and is re-attached via the ``tree``
attribute. Paged bookkeeping invariants:

* every table entry outside a row's live reservation points at the
  SCRATCH page (index ``max_blocks``), so lockstep decode writes for
  idle rows land in the sink instead of a live block;
* ``free`` defers: freed rows park in a pending list and their device
  table rows are cleared to scratch (one jitted scatter in ``flush``,
  called at the top of each admission round) BEFORE the blocks return to
  the allocator — otherwise a frozen row could scribble on a block that
  admission just handed to a new sequence.

Prefix sharing (``prefix_cache=True``): full prompt blocks are indexed
by content hash, and admission maps a request's leading blocks onto
cache hits — several rows' tables point at the SAME physical page, and
only the residual suffix runs prefill. The machinery:

* ``BlockAllocator`` is refcounted: ``alloc`` hands out fresh blocks at
  refcount 1, ``share`` bumps, ``release`` drops and returns whatever
  hit zero. Conservation becomes ``n_free + n_live == n_blocks`` where
  ``n_live`` counts DISTINCT allocated blocks (each once, however many
  refs it carries).
* the cache holds its OWN reference on every indexed block, so a hit
  block survives its registering row. Blocks whose only remaining
  reference is the cache's sit in an LRU; admission evicts from it
  under pressure BEFORE refusing (``_reserve``).
* a write into a block with refcount > 1 forks it copy-on-write
  (``append``/``_cow_fork``): fresh block, device page copy, table
  repoint — the other holders never observe the write. The engine's
  admission keeps hits strictly below the first written position, so
  the fork is a defensive invariant (property-tested), not a hot path.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cache import PagedLayout, is_paged_group


class BlockAllocator:
    """Refcounted free-list over ``n_blocks`` physical page indices.
    ``alloc`` is all-or-nothing (None when short — callers must not
    partially admit) and hands out blocks at refcount 1; ``share`` adds
    a reference to already-live blocks; ``release`` drops one reference
    per block and returns the blocks that reached zero (rejecting
    underflows and foreign indices). ``free`` is the historical alias
    for ``release`` — for the single-reference blocks the non-sharing
    engine deals in, they are the same operation."""

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free: list[int] = list(range(n_blocks))
        self._ref: dict[int, int] = {}

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        """Distinct allocated blocks — each counted ONCE regardless of
        how many references it carries, so ``n_free + n_live`` always
        equals ``n_blocks`` (the conservation the property tests pin)."""
        return len(self._ref)

    def ref(self, block: int) -> int:
        """Current reference count (0 for free/foreign blocks)."""
        return self._ref.get(block, 0)

    def alloc(self, n: int) -> list[int] | None:
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._ref[b] = 1
        return blocks

    def share(self, blocks) -> None:
        """Add one reference to each of ``blocks`` (must be live)."""
        for b in blocks:
            if b not in self._ref:
                raise ValueError(f"share of unallocated block {b}")
        for b in blocks:
            self._ref[b] += 1

    def release(self, blocks) -> list[int]:
        """Drop one reference per block; blocks reaching zero return to
        the free list (and are reported back to the caller)."""
        for b in blocks:
            if b not in self._ref:
                raise ValueError(f"free of unallocated block {b}")
        freed = []
        for b in blocks:
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                self._free.append(b)
                freed.append(b)
        return freed

    def free(self, blocks) -> None:
        self.release(blocks)


@runtime_checkable
class CacheBackend(Protocol):
    """What the serving engine needs from a KV-cache implementation."""
    n_rows: int
    tree: Any

    def can_admit(self, n_tokens: int) -> bool:
        """Could a ``n_tokens``-position reservation be satisfied once
        every reclaimable block (deferred frees awaiting ``flush``,
        evictable prefix-cache residents) is counted? A True here means
        the engine should flush/evict and retry rather than stall."""
        ...

    def alloc(self, row: int, n_tokens: int) -> bool:
        """Reserve cache space covering ``n_tokens`` positions for
        ``row``. False when the budget is short (the only side effect
        permitted on failure is evicting unreferenced cached blocks)."""
        ...

    def append(self, row: int, n_tokens: int = 1) -> bool:
        """Extend ``row``'s reservation by ``n_tokens`` positions."""
        ...

    def free(self, row: int) -> None:
        """Release ``row``'s reservation (may defer until ``flush``)."""
        ...

    def flush(self) -> None:
        """Make deferred frees effective (device table scrub included)."""
        ...

    def insert(self, src_cache: Any, rows: list[int],
               offset: int = 0) -> None:
        """Scatter a prefill mini-cache (dense layout, one row per admitted
        request) into the engine cache at ``rows``, starting at position
        ``offset`` (nonzero when a shared prefix already owns [0, offset))."""
        ...

    def view(self) -> Any:
        """The device cache tree the model consumes."""
        ...


# ---------------------------------------------------------------------------
# dense baseline
# ---------------------------------------------------------------------------
class DenseCache:
    """Row-per-slot baseline: capacity IS ``n_rows``, so block accounting
    degenerates to always-true and ``insert`` is the original moveaxis
    row scatter (donated, in place)."""

    def __init__(self, tree: Any, n_rows: int, batch_axes: Any, jits: dict):
        self.tree = tree
        self.n_rows = n_rows
        self._axes = batch_axes
        self._jits = jits

    def can_admit(self, n_tokens: int) -> bool:
        return True

    def alloc(self, row: int, n_tokens: int) -> bool:
        return True

    def append(self, row: int, n_tokens: int = 1) -> bool:
        return True

    def free(self, row: int) -> None:
        return

    def flush(self) -> None:
        return

    def _insert_fn(self):
        """Jitted row-scatter executable (donates the engine cache)."""
        key = ("insert", "dense")
        if key not in self._jits:
            axes = self._axes

            def ins_fn(cache, src, idx):
                def ins(e, s, ax):
                    if ax is None:
                        return e
                    em = jnp.moveaxis(e, ax, 0)
                    sm = jnp.moveaxis(s.astype(e.dtype), ax, 0)
                    return jnp.moveaxis(em.at[idx].set(sm), 0, ax)
                return jax.tree.map(ins, cache, src, axes)
            self._jits[key] = jax.jit(ins_fn, donate_argnums=(0,))
        return self._jits[key]

    def insert(self, src_cache: Any, rows: list[int],
               offset: int = 0) -> None:
        if offset:
            raise ValueError("DenseCache rows always start at position 0")
        self.tree = self._insert_fn()(self.tree, src_cache,
                                      jnp.asarray(rows))

    def view(self) -> Any:
        return self.tree


# ---------------------------------------------------------------------------
# paged backend
# ---------------------------------------------------------------------------
def _tree_has_paged_group(tree: Any) -> bool:
    if isinstance(tree, dict):
        if is_paged_group(tree):
            return True
        return any(_tree_has_paged_group(v) for v in tree.values())
    return False


# (pages key, dense prefill-cache key) pairs a paged group can hold
_PAGE_PAIRS = (("k_pages", "k"), ("v_pages", "v"),
               ("k_scale_pages", "k_scale"), ("v_scale_pages", "v_scale"),
               ("ckv_pages", "ckv"), ("k_rope_pages", "k_rope"))


class PagedCache:
    """Block-table cache backend. Host state: a refcounted allocator over
    the shared physical pages (ONE logical block spans every pageable
    layer — per-layer tables are replicas), per-row block lists, and —
    with ``prefix_cache`` — a content-hash index over full prompt blocks
    plus an LRU of cache-only residents."""

    def __init__(self, tree: Any, n_rows: int, layout: PagedLayout,
                 max_len: int, batch_axes: Any, jits: dict,
                 prefix_cache: bool = False):
        self.tree = tree
        self.n_rows = n_rows
        self.layout = layout
        self.max_len = max_len
        self._axes = batch_axes
        self._jits = jits
        self.allocator = BlockAllocator(layout.max_blocks)
        self._blocks: list[list[int]] = [[] for _ in range(n_rows)]
        self._tokens: list[int] = [0] * n_rows
        self._pending: list[int] = []          # rows freed, not yet scrubbed
        self._has_paged = _tree_has_paged_group(tree)
        self.prefix_cache = prefix_cache
        # content-hash index over full prompt blocks (both directions),
        # and the LRU of blocks whose ONLY reference is the cache's own
        # (oldest first — eviction order under admission pressure)
        self._hash_to_block: dict[bytes, int] = {}
        self._block_hash: dict[int, bytes] = {}
        self._lru: OrderedDict[int, None] = OrderedDict()
        self._hits: list[int] = [0] * n_rows   # prefix-hit tokens per row

    # -- accounting ----------------------------------------------------
    @property
    def n_live_blocks(self) -> int:
        """Distinct blocks currently held — by rows (pending-free rows
        included until ``flush``) or by the prefix index. At every point
        ``allocator.n_free + n_live_blocks == max_blocks`` — the exact
        conservation the chaos/cancellation tests assert."""
        held = {b for blocks in self._blocks for b in blocks}
        held.update(self._block_hash)
        return len(held)

    def hit_tokens(self, row: int) -> int:
        """Prompt positions of ``row`` satisfied by prefix-cache hits."""
        return self._hits[row]

    @property
    def n_cached_blocks(self) -> int:
        """Prefix-indexed blocks currently resident (shared or LRU)."""
        return len(self._block_hash)

    def _cap(self, n_tokens: int) -> int:
        return min(n_tokens, self.max_len)

    def can_admit(self, n_tokens: int) -> bool:
        # count every RECLAIMABLE block, not just the free list: blocks
        # parked behind a deferred free (rows in _pending) come back at
        # the next flush, and cache-only LRU residents are evictable —
        # only blocks held by live rows are truly unavailable
        pending = set(self._pending)
        held = {b for row, blocks in enumerate(self._blocks)
                if blocks and row not in pending
                for b in blocks}
        return (self.layout.max_blocks - len(held)
                >= self.layout.n_blocks(self._cap(n_tokens)))

    # -- prefix index ----------------------------------------------------
    def peek_hit_blocks(self, block_hashes) -> list[int]:
        """Longest indexed chain of leading prompt-block hashes. Purely
        a lookup — callers must alloc before the index can change."""
        hits: list[int] = []
        for h in block_hashes:
            b = self._hash_to_block.get(h)
            if b is None:
                break
            hits.append(b)
        return hits

    def register_prefix(self, row: int, block_hashes) -> None:
        """Index ``row``'s leading full prompt blocks by content hash.
        The cache takes its OWN reference on each newly indexed block so
        it outlives the row; hashes (or blocks) already indexed are
        skipped — a duplicate prompt admitted in the same cold batch
        keeps its private copy rather than aliasing after the fact."""
        if not self.prefix_cache:
            return
        blocks = self._blocks[row]
        for i, h in enumerate(block_hashes):
            if i >= len(blocks):
                break
            b = blocks[i]
            if h in self._hash_to_block or b in self._block_hash:
                continue
            self.allocator.share([b])
            self._hash_to_block[h] = b
            self._block_hash[b] = h

    def _evict(self, block: int) -> None:
        """Drop a cache-only resident: unindex and release the cache's
        reference (pages need no scrub — no live table points here, and
        attention never reads past a row's written positions)."""
        self._lru.pop(block)
        h = self._block_hash.pop(block)
        del self._hash_to_block[h]
        self.allocator.release([block])

    def _reserve(self, n: int, protect=()) -> bool:
        """Ensure ``n`` free blocks, evicting LRU residents (oldest
        first, never one in ``protect``) before giving up."""
        while self.allocator.n_free < n:
            victim = next((b for b in self._lru if b not in protect), None)
            if victim is None:
                return False
            self._evict(victim)
        return True

    # -- reservations ----------------------------------------------------
    def alloc(self, row: int, n_tokens: int, block_hashes=()) -> bool:
        """Reserve blocks covering ``n_tokens`` positions for ``row``.
        With ``block_hashes`` (leading full prompt-block hashes), the
        indexed prefix maps onto existing pages — the row SHARES them —
        and only the remainder draws fresh blocks. On pressure, LRU
        residents are evicted before refusing."""
        if self._blocks[row] or row in self._pending:
            raise ValueError(f"row {row} already holds a reservation")
        total = self.layout.n_blocks(self._cap(n_tokens))
        hits = (self.peek_hit_blocks(block_hashes)[:total]
                if self.prefix_cache else [])
        if not self._reserve(total - len(hits), protect=set(hits)):
            return False
        fresh = self.allocator.alloc(total - len(hits))
        if fresh is None:
            return False
        if hits:
            self.allocator.share(hits)
            for b in hits:
                self._lru.pop(b, None)     # row-referenced: not evictable
        self._blocks[row] = hits + fresh
        self._tokens[row] = self._cap(n_tokens)
        self._hits[row] = len(hits) * self.layout.block_size
        return True

    def append(self, row: int, n_tokens: int = 1) -> bool:
        old = self._tokens[row]
        new_total = old + n_tokens
        if new_total > self.max_len:
            return False
        # copy-on-write: positions [old, new_total) land in logical
        # blocks old//bs .. (new_total-1)//bs — fork any that are shared
        # (refcount > 1: another row, or the prefix index) before writing
        bs = self.layout.block_size
        for idx in range(old // bs,
                         min((new_total - 1) // bs + 1,
                             len(self._blocks[row]))):
            if (self.allocator.ref(self._blocks[row][idx]) > 1
                    and not self._cow_fork(row, idx)):
                return False
        need = (self.layout.n_blocks(new_total)
                - self.layout.n_blocks(old))
        if need > 0:
            if not self._reserve(need, protect=set(self._blocks[row])):
                return False
            blocks = self.allocator.alloc(need)
            if blocks is None:
                return False
            start = len(self._blocks[row])
            self._blocks[row].extend(blocks)
            if self._has_paged:
                self._write_table(row, start, blocks)
        self._tokens[row] = new_total
        return True

    def _cow_fork(self, row: int, idx: int) -> bool:
        """Give ``row`` a private copy of its shared logical block
        ``idx``: fresh block, device page copy, table repoint, then drop
        the row's reference on the original."""
        old = self._blocks[row][idx]
        if not self._reserve(1, protect=set(self._blocks[row])):
            return False
        fresh = self.allocator.alloc(1)
        if fresh is None:
            return False
        new = fresh[0]
        if self._has_paged:
            self.tree = self._copy_fn()(self.tree, jnp.int32(old),
                                        jnp.int32(new))
            self._write_table(row, idx, [new])
        self._blocks[row][idx] = new
        self.allocator.release([old])
        if old in self._block_hash and self.allocator.ref(old) == 1:
            self._lru[old] = None          # cache-only again: evictable
        return True

    def free(self, row: int) -> None:
        # idempotent: cancel/expire and completion may race to release
        # the same row (deadline expiry in the Router vs the engine
        # finishing the slot) — freeing an already-pending row twice
        # would double-free its blocks at the next flush
        if not self._blocks[row] or row in self._pending:
            return
        # deferred: the device table row must be scrubbed to scratch
        # before these blocks can be re-issued (see flush)
        self._pending.append(row)

    def flush(self) -> None:
        if not self._pending:
            return
        rows, self._pending = self._pending, []
        if self._has_paged:
            self.tree = self._clear_fn()(self.tree,
                                         jnp.asarray(rows, jnp.int32))
        for row in rows:
            self.allocator.release(self._blocks[row])
            # indexed blocks survive on the cache's own reference; once
            # that is the LAST one they become LRU-evictable
            for b in self._blocks[row]:
                if b in self._block_hash and self.allocator.ref(b) == 1:
                    self._lru[b] = None
                    self._lru.move_to_end(b)
            self._blocks[row] = []
            self._tokens[row] = 0
            self._hits[row] = 0

    # -- device-tree transforms ----------------------------------------
    def _table_rows(self, rows: list[int]) -> np.ndarray:
        nblk = self.max_len // self.layout.block_size
        out = np.full((len(rows), nblk), self.layout.scratch_page, np.int32)
        for j, row in enumerate(rows):
            blocks = self._blocks[row]
            out[j, :len(blocks)] = blocks
        return out

    def _clear_fn(self):
        key = ("paged_clear",)
        if key not in self._jits:
            scratch = self.layout.scratch_page

            def walk(t, rows):
                if isinstance(t, dict) and is_paged_group(t):
                    table = t["table"]
                    sdims = table.ndim - 2
                    tf = table.reshape((-1,) + table.shape[sdims:])
                    tf = tf.at[:, rows, :].set(scratch)
                    return {**t, "table": tf.reshape(table.shape)}
                if isinstance(t, dict):
                    return {k: walk(v, rows) for k, v in t.items()}
                return t

            self._jits[key] = jax.jit(lambda tree, rows: walk(tree, rows),
                                      donate_argnums=(0,))
        return self._jits[key]

    def _copy_fn(self):
        """Physical page copy ``src -> dst`` across every pageable layer
        (the device half of a copy-on-write fork)."""
        key = ("paged_copy",)
        if key not in self._jits:
            def walk(t, src, dst):
                if isinstance(t, dict) and is_paged_group(t):
                    out = dict(t)
                    # stack depth from the TABLE (always 2 trailing dims):
                    # page arrays have a group-dependent trailing rank
                    # (attention 4, int8 scales / MLA latents 3), so
                    # deriving it from the pages themselves would index
                    # the LAYER axis as the page axis for 3-dim groups
                    sdims = t["table"].ndim - 2
                    for dk, _ in _PAGE_PAIRS:
                        if dk not in t:
                            continue
                        pages = t[dk]
                        pf = pages.reshape((-1,) + pages.shape[sdims:])
                        pf = pf.at[:, dst].set(pf[:, src])
                        out[dk] = pf.reshape(pages.shape)
                    return out
                if isinstance(t, dict):
                    return {k: walk(v, src, dst) for k, v in t.items()}
                return t

            self._jits[key] = jax.jit(
                lambda tree, src, dst: walk(tree, src, dst),
                donate_argnums=(0,))
        return self._jits[key]

    def _append_fn(self):
        """Jitted table-write executable (donates the tree): points a
        row's logical block indices at physical pages on device."""
        key = ("paged_append",)
        if key not in self._jits:
            def walk(t, row_, idxs, pages):
                if isinstance(t, dict) and is_paged_group(t):
                    table = t["table"]
                    sdims = table.ndim - 2
                    tf = table.reshape((-1,) + table.shape[sdims:])
                    tf = tf.at[:, row_, idxs].set(pages)
                    return {**t, "table": tf.reshape(table.shape)}
                if isinstance(t, dict):
                    return {k: walk(v, row_, idxs, pages)
                            for k, v in t.items()}
                return t

            self._jits[key] = jax.jit(
                lambda tree, row_, idxs, pages:
                    walk(tree, row_, idxs, pages), donate_argnums=(0,))
        return self._jits[key]

    def _write_table(self, row: int, start: int, blocks: list[int]) -> None:
        """Point logical block indices [start, start+len) of ``row`` at
        ``blocks`` on device (append path — admission goes via insert)."""
        idxs = jnp.arange(start, start + len(blocks), dtype=jnp.int32)
        self.tree = self._append_fn()(self.tree, jnp.int32(row), idxs,
                                      jnp.asarray(blocks, jnp.int32))

    def _gather_fn(self):
        """Jitted prefix-gather executable — a pure READ, deliberately
        undonated (the tree must survive for the insert that follows)."""
        key = ("paged_gather",)
        if key not in self._jits:
            bs = self.layout.block_size

            def walk(t, table_rows, pos):
                if isinstance(t, dict) and is_paged_group(t):
                    out = {}
                    # table-derived stack depth, as in _copy_fn: page
                    # arrays have group-dependent trailing rank
                    sdims = t["table"].ndim - 2
                    for dk, sk in _PAGE_PAIRS:
                        if dk not in t:
                            continue
                        pages = t[dk]
                        pf = pages.reshape((-1,) + pages.shape[sdims:])
                        pp = table_rows[:, pos // bs]        # (n, H)
                        g = pf[:, pp, pos % bs]   # (S, n, H, kv, hd)
                        out[sk] = g.reshape(pages.shape[:sdims]
                                            + g.shape[1:])
                    return out
                if isinstance(t, dict):
                    return {k: walk(v, table_rows, pos)
                            for k, v in t.items()}
                return None

            self._jits[key] = jax.jit(
                lambda tree, table_rows, pos: walk(tree, table_rows, pos))
        return self._jits[key]

    def gather_prefix(self, rows: list[int], n_tokens: int) -> Any:
        """Read the first ``n_tokens`` cached positions of ``rows`` out
        of the paged pool as dense per-group K/V — the attention context
        a suffix prefill consumes. Pure read (no donation): call BEFORE
        ``insert`` consumes the tree."""
        return self._gather_fn()(self.tree,
                                 jnp.asarray(self._table_rows(rows)),
                                 jnp.arange(n_tokens))

    def _insert_fn(self):
        """Jitted prefill-scatter executable (donates the tree)."""
        key = ("insert", "paged")
        if key not in self._jits:
            axes = self._axes
            scratch = self.layout.scratch_page

            def group_ins(dst, src, rows_, table_rows, offset_):
                out = dict(dst)
                table = dst["table"]
                sdims = table.ndim - 2
                tf = table.reshape((-1,) + table.shape[sdims:])
                tf = tf.at[:, rows_, :].set(table_rows[None])
                out["table"] = tf.reshape(table.shape)
                nblk = table_rows.shape[1]
                for dk, sk in _PAGE_PAIRS:
                    if dk not in dst:
                        continue
                    pages, s = dst[dk], src[sk]
                    bs = pages.shape[sdims + 1]
                    W = s.shape[sdims + 1]
                    pos = jnp.arange(W) + offset_
                    bi = pos // bs
                    # offset + bucket padding can run past the table:
                    # clamp those positions to the scratch sink (jax
                    # would silently clamp the gather to the LAST table
                    # entry — a live block — instead)
                    pp = jnp.where(bi[None, :] < nblk,
                                   table_rows[:, jnp.minimum(bi, nblk - 1)],
                                   scratch)
                    off = jnp.broadcast_to(pos % bs, pp.shape)
                    pf = pages.reshape((-1,) + pages.shape[sdims:])
                    sf = s.astype(pages.dtype).reshape(
                        (-1,) + s.shape[sdims:])
                    scat = jax.vmap(
                        lambda pg, sr, pp=pp, off=off:
                            pg.at[pp, off].set(sr))(pf, sf)
                    out[dk] = scat.reshape(pages.shape)
                return out

            def walk(dst, src, ax, rows_, table_rows, offset_):
                if isinstance(dst, dict) and is_paged_group(dst):
                    return group_ins(dst, src, rows_, table_rows, offset_)
                if isinstance(dst, dict):
                    return {k: walk(dst[k], src[k], ax[k], rows_,
                                    table_rows, offset_) for k in dst}
                if ax is None:
                    return dst
                em = jnp.moveaxis(dst, ax, 0)
                sm = jnp.moveaxis(src.astype(dst.dtype), ax, 0)
                return jnp.moveaxis(em.at[rows_].set(sm), 0, ax)

            self._jits[key] = jax.jit(
                lambda tree, src, rows_, table_rows, offset_:
                    walk(tree, src, axes, rows_, table_rows, offset_),
                donate_argnums=(0,))
        return self._jits[key]

    def insert(self, src_cache: Any, rows: list[int],
               offset: int = 0) -> None:
        """Scatter the dense prefill mini-cache into the paged tree: every
        position of each source row lands at ``(table[p // bs], p % bs)``
        — positions beyond the row's reservation hit the scratch page, so
        bucket-padded prefill garbage goes to the sink, while live
        positions are copied verbatim (the bit-parity guarantee). A
        nonzero ``offset`` shifts the landing positions: the suffix path
        writes residual K/V behind ``offset`` shared-prefix positions."""
        self.tree = self._insert_fn()(self.tree, src_cache,
                                      jnp.asarray(rows, jnp.int32),
                                      jnp.asarray(self._table_rows(rows)),
                                      jnp.int32(offset))

    def view(self) -> Any:
        return self.tree
