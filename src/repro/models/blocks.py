"""Transformer/SSM block definitions and their init / forward / decode paths.

A "block" = one residual layer. Block kinds:
  * ``attn_mlp``  — pre-norm attention + MLP (dense archs, optional window)
  * ``attn_moe``  — pre-norm attention (GQA or MLA) + MoE
  * ``ssm``       — pre-norm Mamba2
  * ``cross``     — decoder layer with self-attn + cross-attn + MLP (Whisper)
  * ``encoder``   — non-causal attention + MLP (Whisper encoder)

Each kind has matching ``init_*``, ``*_fwd`` (full sequence), ``*_decode``
(one token + cache) and cache-init functions, so model.py can scan stacks of
them uniformly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import cache as cache_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (constrain_batch, init_mlp, init_norm,
                                 mlp_fwd, norm_fwd)


# ---------------------------------------------------------------------------
# attn + mlp (dense)
# ---------------------------------------------------------------------------
def init_attn_mlp(key, cfg: ArchConfig, dtype, use_mla: bool | None = None) -> dict:
    k1, k2 = jax.random.split(key)
    use_mla = cfg.mla if use_mla is None else use_mla
    a = attn.init_mla(k1, cfg, dtype) if use_mla else attn.init_attn(k1, cfg, dtype)
    return {
        "ln1": init_norm(cfg, cfg.d_model, dtype),
        "attn": a,
        "ln2": init_norm(cfg, cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype, cfg.mlp_gated),
    }


def _attn_fwd(p, cfg: ArchConfig, xin, *, window: int, causal: bool = True):
    if "w_dkv" in p:  # MLA params
        return attn.mla_prefill(p, cfg, xin)
    return attn.attn_prefill(p, cfg, xin, window=window, causal=causal)


def attn_mlp_fwd(p, cfg: ArchConfig, x, *, window: int = 0,
                 causal: bool = True):
    h = _attn_fwd(p["attn"], cfg, norm_fwd(cfg, p["ln1"], x),
                  window=window, causal=causal)
    x = x + checkpoint_name(h, "attn_out")
    x = x + checkpoint_name(
        mlp_fwd(p["mlp"], norm_fwd(cfg, p["ln2"], x), cfg.act), "mlp_out")
    return constrain_batch(x)


def attn_mlp_prefill(p, cfg: ArchConfig, x, cache, *, window: int = 0):
    xin = norm_fwd(cfg, p["ln1"], x)
    if "w_dkv" in p["attn"]:
        h = attn.mla_prefill(p["attn"], cfg, xin)
        cache = _mla_fill_cache(p["attn"], cfg, xin, cache)
    else:
        h, cache = attn.attn_prefill_into_cache(
            p["attn"], cfg, xin, cache, window=window)
    x = x + h
    x = x + mlp_fwd(p["mlp"], norm_fwd(cfg, p["ln2"], x), cfg.act)
    return constrain_batch(x), cache


def attn_mlp_suffix_prefill(p, cfg: ArchConfig, x, cache, ctx_k, ctx_v,
                            offset: int):
    """Residual-suffix prefill (prefix sharing): attention runs against
    [cached prefix K/V, suffix K/V]. GQA only — the engine's sharing
    gate never routes MLA here."""
    xin = norm_fwd(cfg, p["ln1"], x)
    h, cache = attn.attn_suffix_prefill_into_cache(
        p["attn"], cfg, xin, cache, ctx_k, ctx_v, offset)
    x = x + h
    x = x + mlp_fwd(p["mlp"], norm_fwd(cfg, p["ln2"], x), cfg.act)
    return constrain_batch(x), cache


def attn_mlp_decode(p, cfg: ArchConfig, x, cache, pos):
    xin = norm_fwd(cfg, p["ln1"], x)
    if "w_dkv" in p["attn"]:
        h, cache = attn.mla_decode(p["attn"], cfg, xin, cache, pos)
    else:
        h, cache = attn.attn_decode(p["attn"], cfg, xin, cache, pos)
    x = x + h
    x = x + mlp_fwd(p["mlp"], norm_fwd(cfg, p["ln2"], x), cfg.act)
    return constrain_batch(x), cache


# ---------------------------------------------------------------------------
# attn + moe (Mixtral / DeepSeek)
# ---------------------------------------------------------------------------
def init_attn_moe(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    a = attn.init_mla(k1, cfg, dtype) if cfg.mla else attn.init_attn(k1, cfg, dtype)
    return {
        "ln1": init_norm(cfg, cfg.d_model, dtype),
        "attn": a,
        "ln2": init_norm(cfg, cfg.d_model, dtype),
        "moe": moe_lib.init_moe(k2, cfg, dtype),
    }


def attn_moe_fwd(p, cfg: ArchConfig, x, *, window: int = 0,
                 train: bool = False):
    xin = norm_fwd(cfg, p["ln1"], x)
    if cfg.mla:
        h = attn.mla_prefill(p["attn"], cfg, xin)
    else:
        h = attn.attn_prefill(p["attn"], cfg, xin, window=window)
    x = x + checkpoint_name(h, "attn_out")
    mo, aux = moe_lib.moe_fwd(p["moe"], cfg, norm_fwd(cfg, p["ln2"], x),
                              cfg.act, train=train)
    return constrain_batch(x + checkpoint_name(mo, "moe_out")), aux


def attn_moe_prefill(p, cfg: ArchConfig, x, cache, *, window: int = 0):
    xin = norm_fwd(cfg, p["ln1"], x)
    if cfg.mla:
        # MLA prefill + cache fill: recompute latents for the cache
        h = attn.mla_prefill(p["attn"], cfg, xin)
        cache = _mla_fill_cache(p["attn"], cfg, xin, cache)
    else:
        h, cache = attn.attn_prefill_into_cache(p["attn"], cfg, xin, cache,
                                                window=window)
    x = x + h
    mo, _ = moe_lib.moe_fwd(p["moe"], cfg, norm_fwd(cfg, p["ln2"], x), cfg.act)
    return constrain_batch(x + mo), cache


def attn_moe_suffix_prefill(p, cfg: ArchConfig, x, cache, ctx_k, ctx_v,
                            offset: int):
    """Residual-suffix prefill for MoE blocks (non-MLA only — the
    engine's sharing gate excludes latent caches)."""
    xin = norm_fwd(cfg, p["ln1"], x)
    h, cache = attn.attn_suffix_prefill_into_cache(
        p["attn"], cfg, xin, cache, ctx_k, ctx_v, offset)
    x = x + h
    mo, _ = moe_lib.moe_fwd(p["moe"], cfg, norm_fwd(cfg, p["ln2"], x),
                            cfg.act)
    return constrain_batch(x + mo), cache


def _mla_fill_cache(pa, cfg: ArchConfig, xin, cache):
    from repro.models.attention import apply_rope
    from repro.models.layers import rmsnorm_fwd
    B, S, _ = xin.shape
    r = cfg.kv_lora_rank
    positions = jnp.arange(S)[None, :]
    dkv = jnp.einsum("bsd,dr->bsr", xin, pa["w_dkv"])
    ckv = rmsnorm_fwd(pa["kv_norm"], dkv[..., :r], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., None, r:], positions, cfg.rope_theta)[:, :, 0]
    L = cache["ckv"].shape[1]
    take = min(L, S)
    new_ckv = cache["ckv"].at[:, :take].set(ckv[:, :take])
    new_kr = cache["k_rope"].at[:, :take].set(k_rope[:, :take])
    return {"ckv": new_ckv, "k_rope": new_kr}


def attn_moe_decode(p, cfg: ArchConfig, x, cache, pos):
    xin = norm_fwd(cfg, p["ln1"], x)
    if cfg.mla:
        h, cache = attn.mla_decode(p["attn"], cfg, xin, cache, pos)
    else:
        h, cache = attn.attn_decode(p["attn"], cfg, xin, cache, pos)
    x = x + h
    mo, _ = moe_lib.moe_fwd(p["moe"], cfg, norm_fwd(cfg, p["ln2"], x), cfg.act)
    return constrain_batch(x + mo), cache


# ---------------------------------------------------------------------------
# ssm (Mamba2)
# ---------------------------------------------------------------------------
def init_ssm_block(key, cfg: ArchConfig, dtype) -> dict:
    return {
        "ln": init_norm(cfg, cfg.d_model, dtype),
        "mamba": ssm_lib.init_mamba2(key, cfg, dtype),
    }


def ssm_fwd(p, cfg: ArchConfig, x):
    return constrain_batch(
        x + checkpoint_name(
            ssm_lib.mamba2_fwd(p["mamba"], cfg, norm_fwd(cfg, p["ln"], x)),
            "ssm_out"))


def ssm_prefill(p, cfg: ArchConfig, x):
    """SSM prefill builds its cache from scratch (conv tail + final state)."""
    h, cache = ssm_lib.mamba2_fwd(p["mamba"], cfg, norm_fwd(cfg, p["ln"], x),
                                  return_cache=True)
    return constrain_batch(x + h), cache


def ssm_decode(p, cfg: ArchConfig, x, cache, pos):
    del pos  # SSM state is position-free
    h, cache = ssm_lib.mamba2_decode(p["mamba"], cfg,
                                     norm_fwd(cfg, p["ln"], x), cache)
    return constrain_batch(x + h), cache


# ---------------------------------------------------------------------------
# whisper encoder / decoder layers
# ---------------------------------------------------------------------------
def init_encoder_block(key, cfg: ArchConfig, dtype) -> dict:
    return init_attn_mlp(key, cfg, dtype)


def encoder_fwd(p, cfg: ArchConfig, x):
    return attn_mlp_fwd(p, cfg, x, causal=False)


def init_cross_block(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_norm(cfg, cfg.d_model, dtype),
        "self_attn": attn.init_attn(k1, cfg, dtype),
        "ln2": init_norm(cfg, cfg.d_model, dtype),
        "cross_attn": attn.init_attn(k2, cfg, dtype),
        "ln3": init_norm(cfg, cfg.d_model, dtype),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype, cfg.mlp_gated),
    }


def cross_fwd(p, cfg: ArchConfig, x, memory):
    x = x + attn.attn_prefill(p["self_attn"], cfg,
                              norm_fwd(cfg, p["ln1"], x))
    mem_kv = attn.cross_attn_memory(p["cross_attn"], cfg, memory)
    x = x + attn.cross_attn_prefill(p["cross_attn"], cfg,
                                    norm_fwd(cfg, p["ln2"], x), mem_kv)
    x = x + mlp_fwd(p["mlp"], norm_fwd(cfg, p["ln3"], x), cfg.act)
    return constrain_batch(x)


def cross_prefill(p, cfg: ArchConfig, x, memory, cache):
    h, self_cache = attn.attn_prefill_into_cache(
        p["self_attn"], cfg, norm_fwd(cfg, p["ln1"], x), cache["self"])
    x = x + h
    mem_kv = attn.cross_attn_memory(p["cross_attn"], cfg, memory)
    x = x + attn.cross_attn_prefill(p["cross_attn"], cfg,
                                    norm_fwd(cfg, p["ln2"], x), mem_kv)
    x = x + mlp_fwd(p["mlp"], norm_fwd(cfg, p["ln3"], x), cfg.act)
    return constrain_batch(x), {"self": self_cache, "mem_k": mem_kv[0],
                                "mem_v": mem_kv[1]}


def cross_decode(p, cfg: ArchConfig, x, cache, pos):
    h, self_cache = attn.attn_decode(p["self_attn"], cfg,
                                     norm_fwd(cfg, p["ln1"], x),
                                     cache["self"], pos)
    x = x + h
    mem_kv = (cache["mem_k"], cache["mem_v"])
    x = x + attn.cross_attn_decode(p["cross_attn"], cfg,
                                   norm_fwd(cfg, p["ln2"], x), mem_kv)
    x = x + mlp_fwd(p["mlp"], norm_fwd(cfg, p["ln3"], x), cfg.act)
    return constrain_batch(x), {"self": self_cache, "mem_k": cache["mem_k"],
                                "mem_v": cache["mem_v"]}


# ---------------------------------------------------------------------------
# cache constructors
# ---------------------------------------------------------------------------
def init_block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                     dtype, *, window: int = 0,
                     layout: cache_lib.PagedLayout | None = None):
    """``layout`` switches pageable groups to the block/paged cache.
    SSM states, genuinely sliding windows (W < max_len) and cross-attn
    encoder memories have no block-table equivalent and stay dense."""
    if kind == "ssm":
        return ssm_lib.init_mamba2_cache(cfg, batch, dtype)
    if kind == "mla":
        if layout is not None:
            return cache_lib.init_paged_mla_cache(cfg, batch, max_len,
                                                  dtype, layout)
        return attn.init_mla_cache(cfg, batch, max_len, dtype)
    if kind == "cross":
        self_cache = (cache_lib.init_paged_attn_cache(cfg, batch, max_len,
                                                      dtype, layout)
                      if layout is not None
                      else attn.init_attn_cache(cfg, batch, max_len, dtype))
        return {
            "self": self_cache,
            "mem_k": jnp.zeros((batch, cfg.encoder_seq, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
            "mem_v": jnp.zeros((batch, cfg.encoder_seq, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
        }
    if layout is not None and cache_lib.pageable(window, max_len):
        return cache_lib.init_paged_attn_cache(cfg, batch, max_len, dtype,
                                               layout)
    return attn.init_attn_cache(cfg, batch, max_len, dtype, window=window)
