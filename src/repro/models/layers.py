"""Core layer primitives: norms, rotary embeddings, MLPs, embeddings.

Pure functional: every layer is an ``init_*`` returning a params dict and a
``*_fwd`` consuming it. No flax; params are nested dicts of jnp arrays.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import get_abstract_mesh
from repro.configs.base import ArchConfig


def truncated_normal(key, shape, dtype, scale):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# activation sharding constraints (no-ops without a mesh context)
# ---------------------------------------------------------------------------
def constrain(x: jax.Array, *spec) -> jax.Array:
    """``with_sharding_constraint`` that degrades to identity when no mesh
    is set (CPU tests) and silently drops axes that are absent from the
    ambient mesh or don't divide the corresponding dim. ``spec`` entries are
    axis names, tuples of names, or None — one per array dim (trailing dims
    may be omitted)."""
    mesh = get_abstract_mesh()
    if not mesh.axis_names:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    parts = []
    for i, s in enumerate(spec):
        names = s if isinstance(s, tuple) else ((s,) if s else ())
        names = tuple(n for n in names if n in sizes)
        total = 1
        for n in names:
            total *= sizes[n]
        if names and x.shape[i] % total == 0 and x.shape[i] >= total:
            parts.append(names if len(names) > 1 else names[0])
        else:
            parts.append(None)
    from jax.sharding import PartitionSpec as _P
    return jax.lax.with_sharding_constraint(x, _P(*parts))


def constrain_batch(x: jax.Array) -> jax.Array:
    """Activation-stream constraint: batch over ("pod","data")."""
    return constrain(x, ("pod", "data"))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_fwd(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dtype)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_fwd(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings (partial factor + theta per config)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float, partial: float = 1.0) -> jax.Array:
    rot_dim = int(head_dim * partial) // 2 * 2
    # (rot_dim // 2,)
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2,
                                       dtype=jnp.float32) / rot_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               partial: float = 1.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta, partial)
    rot_dim = inv.shape[0] * 2
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, rot//2)
    cos = jnp.cos(ang)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype, gated: bool = True) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    p = {
        "w_up": truncated_normal(k2, (d_model, d_ff), dtype, s_in),
        "w_down": truncated_normal(k3, (d_ff, d_model), dtype, s_out),
    }
    if gated:
        p["w_gate"] = truncated_normal(k1, (d_model, d_ff), dtype, s_in)
    return p


def mlp_fwd(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    actfn = jax.nn.silu if act == "silu" else jax.nn.gelu
    up = jnp.einsum("...d,df->...f", x, p["w_up"])
    if "w_gate" in p:
        gate = actfn(jnp.einsum("...d,df->...f", x, p["w_gate"]))
        h = gate * up
    else:
        h = actfn(up)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------
def init_embed(key, vocab: int, d_model: int, dtype) -> dict:
    # 0.02 scale keeps tied-head logits O(1) at init
    return {"table": truncated_normal(key, (vocab, d_model), dtype, 0.02)}


def embed_fwd(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def init_linear(key, d_in: int, d_out: int, dtype, bias: bool = False) -> dict:
    p = {"w": truncated_normal(key, (d_in, d_out), dtype, d_in ** -0.5)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear_fwd(p: dict, x: jax.Array) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def init_norm(cfg: ArchConfig, d: int, dtype) -> dict:
    if cfg.norm_type == "layernorm":
        return init_layernorm(d, dtype)
    return init_rmsnorm(d, dtype)


def norm_fwd(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    if "bias" in p:
        return layernorm_fwd(p, x, cfg.norm_eps)
    return rmsnorm_fwd(p, x, cfg.norm_eps)
