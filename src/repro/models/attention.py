"""Attention: GQA (+qk_norm, sliding-window, cross) and DeepSeek MLA.

Two execution paths per variant:
  * ``*_prefill`` — full-sequence attention (causal / windowed / cross), used
    for training forward passes and serving prefill. Dispatches to the
    flash-attention op (Pallas on TPU, jnp oracle elsewhere).
  * ``*_decode`` — one new token against a ring-buffer KV cache.

Cache layout (per layer):
  ``{"k": (B, W, Hkv, hd), "v": (B, W, Hkv, hd)}`` with ``W`` the cache
  window (= sliding window for local layers, = max_len for global ones).
  Keys are stored post-RoPE at their absolute positions; slot ``s`` holds
  absolute position ``p_s = pos - ((pos - s) mod W)`` which the decode mask
  reconstructs, so no position tensor needs to be cached.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import get_abstract_mesh, shard_map
from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.models.layers import (apply_rope, init_rmsnorm, rmsnorm_fwd,
                                 truncated_normal)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_attn(key, cfg: ArchConfig, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": truncated_normal(k1, (d, h, hd), dtype, s),
        "wk": truncated_normal(k2, (d, kv, hd), dtype, s),
        "wv": truncated_normal(k3, (d, kv, hd), dtype, s),
        "wo": truncated_normal(k4, (h, hd, d), dtype, (h * hd) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def init_mla(key, cfg: ArchConfig, dtype) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    r, dr, dn, dv = (cfg.kv_lora_rank, cfg.qk_rope_head_dim,
                     cfg.qk_nope_head_dim, cfg.v_head_dim)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "wq": truncated_normal(ks[0], (d, h, dn + dr), dtype, s),
        "w_dkv": truncated_normal(ks[1], (d, r + dr), dtype, s),  # latent + shared rope key
        "w_uk": truncated_normal(ks[2], (r, h, dn), dtype, r ** -0.5),
        "w_uv": truncated_normal(ks[3], (r, h, dv), dtype, r ** -0.5),
        "wo": truncated_normal(ks[4], (h, dv, d), dtype, (h * dv) ** -0.5),
        "kv_norm": init_rmsnorm(r, dtype),
    }


# ---------------------------------------------------------------------------
# GQA prefill / full forward
# ---------------------------------------------------------------------------
def _qkv(p, cfg: ArchConfig, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm_fwd(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_fwd(p["k_norm"], k, cfg.norm_eps)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.partial_rotary_factor)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.partial_rotary_factor)
    return q, k, v


def attn_prefill(p: dict, cfg: ArchConfig, x: jax.Array, *,
                 window: int = 0, positions: jax.Array | None = None,
                 causal: bool = True) -> jax.Array:
    """x: (B, S, d) -> (B, S, d). window>0 enables sliding-window masking."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, cfg, x, positions)
    out = kops.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=cfg.attn_logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_attn_prefill(p: dict, cfg: ArchConfig, x: jax.Array,
                       memory_kv: tuple[jax.Array, jax.Array]) -> jax.Array:
    """Decoder cross-attention; memory k/v precomputed from encoder output.
    Softcap is applied here AND in cross_attn_decode — the two paths must
    stay numerically symmetric (decode == teacher-forced forward)."""
    k, v = memory_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    out = kops.flash_attention(q, k, v, causal=False, window=0,
                               softcap=cfg.attn_logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_attn_decode(p: dict, cfg: ArchConfig, x: jax.Array,
                      memory_kv: tuple[jax.Array, jax.Array]) -> jax.Array:
    """Decode-time cross-attention: x is (B, 1, d) — one query token — so
    dispatch to the flash-decode kernel (memory streamed once) instead of
    the prefill kernel's square tiling."""
    k, v = memory_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])[:, 0]
    out = kops.decode_cross_attention(q, k, v,
                                      softcap=cfg.attn_logit_softcap)
    return jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]


def cross_attn_memory(p: dict, cfg: ArchConfig, memory: jax.Array):
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
    return k, v


# ---------------------------------------------------------------------------
# GQA decode with ring-buffer cache
# ---------------------------------------------------------------------------
def init_attn_cache(cfg: ArchConfig, batch: int, max_len: int, dtype,
                    window: int = 0) -> dict:
    W = min(window, max_len) if window else max_len
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": jnp.zeros((batch, W, kv, hd), jnp.int8),
            "v": jnp.zeros((batch, W, kv, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, W, kv), jnp.float32),
            "v_scale": jnp.zeros((batch, W, kv), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, W, kv, hd), dtype),
        "v": jnp.zeros((batch, W, kv, hd), dtype),
    }


def _quant_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(token, head) absmax int8 quantisation. x: (..., hd)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1) / 127.0, 1e-8)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def _seq_parallel_decode(cfg: ArchConfig, q, k, v, valid,
                         k_scale=None, v_scale=None):
    """Decode attention against a cache whose SEQUENCE dim is sharded over
    "model" (the rule when kv-heads don't divide the model axis). GSPMD
    cannot block-slice a seq-sharded cache, so the locality is asserted
    with shard_map: each model shard runs a partial flash-decode over its
    local KV slice and the (max, normaliser, accumulator) statistics are
    merged with one tiny all-gather — distributed flash-decoding, the
    TPU-native layout of the paper's "split the work" idea at decode time.
    """
    from jax.sharding import PartitionSpec as P

    from repro.kernels import ref as kref

    mesh = get_abstract_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes)) if mesh.axis_names \
        else {}
    msize = sizes.get("model", 1)
    dax = tuple(a for a in ("pod", "data") if sizes.get(a, 1) > 1)
    dsize = 1
    for a in dax:
        dsize *= sizes[a]
    B, W = valid.shape
    kv = k.shape[2]
    dentry = dax if len(dax) > 1 else (dax[0] if dax else None)
    b_ok = dsize > 1 and B % dsize == 0

    # which axis shards the cache SEQUENCE dim (mirrors the cache rules in
    # launch/sharding.py): "model" when kv-heads don't divide it; the data
    # axes when the batch is idle (long-context, B=1)
    kv_div = msize > 1 and kv % msize == 0
    if msize > 1 and not kv_div and W % msize == 0 and (b_ok or dsize == 1):
        seq_axes: tuple | str = "model"
        bentry, hentry = (dentry if b_ok else None), None
    elif dsize > 1 and not b_ok and W % dsize == 0:
        seq_axes = dentry
        bentry, hentry = None, ("model" if kv_div else None)
    else:
        return kops.decode_attention(q, k, v, valid,
                                     softcap=cfg.attn_logit_softcap,
                                     k_scale=k_scale, v_scale=v_scale)

    use_scales = k_scale is not None

    def kernel(q_l, k_l, v_l, valid_l, ks_l, vs_l):
        acc, m, l = kref.decode_attention_partial(
            q_l, k_l, v_l, valid_l, softcap=cfg.attn_logit_softcap,
            k_scale=ks_l if use_scales else None,
            v_scale=vs_l if use_scales else None)
        # flash-decoding merge: one pmax + two psums of (B, H)-sized stats
        m_tot = jax.lax.pmax(m, seq_axes)
        w = jnp.exp(m - m_tot)
        num = jax.lax.psum(w[..., None] * acc, seq_axes)
        den = jnp.maximum(jax.lax.psum(w * l, seq_axes), 1e-30)
        return (num / den[..., None]).astype(q_l.dtype)

    qspec = P(bentry, hentry)                      # (B, H, K)
    cspec = P(bentry, seq_axes, hentry)            # (B, W, kv, hd)
    vspec = P(bentry, seq_axes)                    # (B, W)
    sspec = P(bentry, seq_axes, hentry)            # (B, W, kv)
    scale_args = ((k_scale, v_scale) if use_scales
                  else (jnp.zeros((B, W, kv), jnp.float32),) * 2)
    return shard_map(
        kernel,
        in_specs=(qspec, cspec, cspec, vspec, sspec, sspec),
        out_specs=qspec)(q, k, v, valid, *scale_args)


def _ring_positions(W: int, pos: jax.Array) -> jax.Array:
    """Absolute position stored in each ring slot after writing at ``pos``.

    pos: (B,) -> (B, W); negative entries were never written.
    """
    slots = jnp.arange(W)[None, :]
    pos = pos[:, None]
    return pos - jnp.mod(pos - slots, W)


def attn_decode(p: dict, cfg: ArchConfig, x: jax.Array, cache: dict,
                pos: jax.Array) -> tuple[jax.Array, dict]:
    """x: (B, 1, d); pos: (B,) int32 — per-sequence position of the new
    token (continuous batching decodes slots at different depths)."""
    B = x.shape[0]
    positions = pos[:, None].astype(jnp.int32)
    q, k, v = _qkv(p, cfg, x, positions)
    bidx = jnp.arange(B)
    if "k_pages" in cache:
        # paged layout: write the new token into its block-table page
        # (idle rows point at the scratch page) and run the paged
        # flash-decode gather. Only full-horizon layers are paged, so
        # slot == position and validity is simply position < pos+1 —
        # the same mask the dense ring produces when W == max_len.
        bs = cache["k_pages"].shape[1]
        pidx = cache["table"][bidx, pos // bs]               # (B,)
        off = jnp.mod(pos, bs)
        lengths = (pos + 1).astype(jnp.int32)
        if cfg.kv_cache_dtype == "int8":
            kq, ks = _quant_kv(k[:, 0])
            vq, vs = _quant_kv(v[:, 0])
            kp = cache["k_pages"].at[pidx, off].set(kq)
            vp = cache["v_pages"].at[pidx, off].set(vq)
            ksp = cache["k_scale_pages"].at[pidx, off].set(ks)
            vsp = cache["v_scale_pages"].at[pidx, off].set(vs)
            out = kops.paged_decode_attention(
                q[:, 0], kp, vp, cache["table"], lengths,
                softcap=cfg.attn_logit_softcap,
                k_scale_pages=ksp, v_scale_pages=vsp)
            y = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
            return y, {"table": cache["table"], "k_pages": kp,
                       "v_pages": vp, "k_scale_pages": ksp,
                       "v_scale_pages": vsp}
        kp = cache["k_pages"].at[pidx, off].set(k[:, 0])
        vp = cache["v_pages"].at[pidx, off].set(v[:, 0])
        out = kops.paged_decode_attention(q[:, 0], kp, vp, cache["table"],
                                          lengths,
                                          softcap=cfg.attn_logit_softcap)
        y = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
        return y, {"table": cache["table"], "k_pages": kp, "v_pages": vp}
    W = cache["k"].shape[1]
    slot = jnp.mod(pos, W)                                   # (B,)
    valid = _ring_positions(W, pos) >= 0                     # (B, W)
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quant_kv(k[:, 0])
        vq, vs = _quant_kv(v[:, 0])
        ck = cache["k"].at[bidx, slot].set(kq)
        cv = cache["v"].at[bidx, slot].set(vq)
        cks = cache["k_scale"].at[bidx, slot].set(ks)
        cvs = cache["v_scale"].at[bidx, slot].set(vs)
        out = _seq_parallel_decode(cfg, q[:, 0], ck, cv, valid,
                                   k_scale=cks, v_scale=cvs)
        y = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
        return y, {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
    ck = cache["k"].at[bidx, slot].set(k[:, 0])
    cv = cache["v"].at[bidx, slot].set(v[:, 0])
    out = _seq_parallel_decode(cfg, q[:, 0], ck, cv, valid)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
    return y, {"k": ck, "v": cv}


def attn_prefill_into_cache(p: dict, cfg: ArchConfig, x: jax.Array,
                            cache: dict, *, window: int = 0) -> tuple[jax.Array, dict]:
    """Run prefill and leave the (last W) keys/values in the ring cache."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, cfg, x, positions)
    out = kops.flash_attention(q, k, v, causal=True, window=window,
                               softcap=cfg.attn_logit_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    W = cache["k"].shape[1]
    # write positions [max(0, S-W), S) into slots (p % W)
    take = min(W, S)
    src_k, src_v = k[:, S - take:], v[:, S - take:]
    slots = jnp.mod(jnp.arange(S - take, S), W)
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quant_kv(src_k)
        vq, vs = _quant_kv(src_v)
        return y, {"k": cache["k"].at[:, slots].set(kq),
                   "v": cache["v"].at[:, slots].set(vq),
                   "k_scale": cache["k_scale"].at[:, slots].set(ks),
                   "v_scale": cache["v_scale"].at[:, slots].set(vs)}
    ck = cache["k"].at[:, slots].set(src_k)
    cv = cache["v"].at[:, slots].set(src_v)
    return y, {"k": ck, "v": cv}


def attn_suffix_prefill_into_cache(p: dict, cfg: ArchConfig, x: jax.Array,
                                   cache: dict, ctx_k: jax.Array,
                                   ctx_v: jax.Array,
                                   offset: int) -> tuple[jax.Array, dict]:
    """Prefill only the residual suffix behind ``offset`` already-cached
    positions (prefix sharing): queries are the suffix tokens at their
    absolute rope positions, keys/values are [cached prefix, suffix].
    Causal masking right-aligns queries against the key axis, so the
    context width must equal ``offset`` EXACTLY — padding belongs on the
    suffix side only. Returns the suffix K/V as the mini-cache (width ==
    S: the whole ring is the suffix). Full-horizon rope attention only —
    the engine's sharing gate excludes windows, MLA and int8 caches."""
    B, S, _ = x.shape
    positions = offset + jnp.arange(S)[None, :]
    q, k, v = _qkv(p, cfg, x, positions)
    ck = jnp.concatenate([ctx_k.astype(k.dtype), k], axis=1)
    cv = jnp.concatenate([ctx_v.astype(v.dtype), v], axis=1)
    out = kops.flash_attention(q, ck, cv, causal=True, window=0,
                               softcap=cfg.attn_logit_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": k.astype(cache["k"].dtype),
               "v": v.astype(cache["v"].dtype)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent KV cache, absorbed decode
# ---------------------------------------------------------------------------
def mla_prefill(p: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    B, S, _ = x.shape
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    positions = jnp.arange(S)[None, :]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    ckv, k_rope = dkv[..., : cfg.kv_lora_rank], dkv[..., cfg.kv_lora_rank:]
    ckv = rmsnorm_fwd(p["kv_norm"], ckv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # shared head

    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, cfg.n_heads, dr))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = kops.flash_attention(q_full, k, v, causal=True, window=0)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> dict:
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_decode(p: dict, cfg: ArchConfig, x: jax.Array, cache: dict,
               pos: jax.Array) -> tuple[jax.Array, dict]:
    """Absorbed MLA decode: attention runs in the latent space. pos: (B,)."""
    B = x.shape[0]
    dn, dr, r = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.kv_lora_rank
    positions = pos[:, None].astype(jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    ckv_new = rmsnorm_fwd(p["kv_norm"], dkv[..., :r], cfg.norm_eps)
    k_rope_new = apply_rope(dkv[..., None, r:], positions, cfg.rope_theta)[:, :, 0]

    bidx = jnp.arange(B)
    if "ckv_pages" in cache:
        # paged latent cache: scatter the new latent/rope-key into the
        # block-table page, then gather the logical view and reuse the
        # dense MLA context kernel — masked (garbage) positions still
        # contribute an exact 0.0, so this bit-matches the dense path.
        bs = cache["ckv_pages"].shape[1]
        table = cache["table"]
        pidx = table[bidx, pos // bs]
        off = jnp.mod(pos, bs)
        ckv_pages = cache["ckv_pages"].at[pidx, off].set(ckv_new[:, 0])
        kr_pages = cache["k_rope_pages"].at[pidx, off].set(k_rope_new[:, 0])
        S = table.shape[1] * bs
        ckv = ckv_pages[table].reshape(B, S, r)
        k_rope = kr_pages[table].reshape(B, S, dr)
        valid = jnp.arange(S)[None, :] <= pos[:, None]
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])[:, 0]
        ctx_lat = kops.mla_decode_ctx(q_lat, q_rope[:, 0], ckv, k_rope,
                                      valid,
                                      scale=(dn + dr) ** -0.5).astype(
                                          ckv_pages.dtype)
        out = jnp.einsum("bhr,rhk->bhk", ctx_lat, p["w_uv"])
        y = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
        return y, {"table": table, "ckv_pages": ckv_pages,
                   "k_rope_pages": kr_pages}
    ckv = cache["ckv"].at[bidx, pos].set(ckv_new[:, 0])
    k_rope = cache["k_rope"].at[bidx, pos].set(k_rope_new[:, 0])

    # absorb W_uk into q: attention runs in the latent space (the Pallas
    # kernel reads each ckv tile once for score AND context — kernels/
    # mla_decode.py; jnp oracle on CPU)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])[:, 0]  # (B,H,r)
    S = ckv.shape[1]
    valid = jnp.arange(S)[None, :] <= pos[:, None]           # (B, S)
    ctx_lat = kops.mla_decode_ctx(q_lat, q_rope[:, 0], ckv, k_rope, valid,
                                  scale=(dn + dr) ** -0.5).astype(ckv.dtype)
    out = jnp.einsum("bhr,rhk->bhk", ctx_lat, p["w_uv"])
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
    return y, {"ckv": ckv, "k_rope": k_rope}
